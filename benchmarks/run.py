"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,us_per_call,derived`` CSV lines per benchmark plus each
benchmark's own detailed CSV, and aggregates every benchmark's structured
result — including the per-pass ``PassReport`` timings the compiler
records — into a machine-readable ``BENCH_<date>.json`` at the repo root,
so the perf trajectory across PRs is diffable.  Mapping to the paper:
    layers        — Fig. 4   (latency/resources vs unroll, 5 layer types)
    tool_runtime  — Fig. 2/5 (compiler runtime vs trip count)
    braggnn       — §4.2/Fig. 6 (end-to-end case study)
    precision     — Fig. 7   (trained-weight exponents, accuracy sweep)
    roofline      — §Roofline (TPU adaptation; reads dry-run artifacts)
    serving       — deployment: sustained QPS / tail latency / warm boot
    trigger       — hard-real-time trigger: sustained fps / deadline-miss %
                    / drop % / p99 decision latency + part budget check
    compile_scaling — compile-time curve conv2d -> BraggNN -> transformer

Re-running the same day merges into the existing ``BENCH_<date>.json``:
sections whose benchmark was skipped (``--only``) carry forward from the
earlier run instead of being dropped.

When :mod:`repro.obs` is enabled (``REPRO_OBS=1``), the run's metrics
snapshot (cache hits/misses, padding waste, queue-depth histograms, ...)
is embedded under the report's ``"obs"`` key.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

from repro import obs

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

log = obs.get_logger(__name__)


def _timed(name, results, fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt:.0f},ok")
    sys.stdout.flush()
    results[name] = {"wall_us": round(dt), "result": out}
    return out


def _jsonable(obj):
    """Best-effort conversion of benchmark outputs to JSON values."""
    import numpy as np
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


#: bench_braggnn result fields mirrored into the top-level ``compiler``
#: section: the machine-readable compile-time/throughput trajectory.
_COMPILER_FIELDS = ("build_s", "trace_s", "passes_s", "schedule_s",
                    "pass_ops_per_s", "passes_skipped", "ops_raw", "ops_opt")


def write_report(results: dict, args, out_path=None) -> pathlib.Path:
    """Aggregate all results into ``BENCH_<date>.json`` at the repo root.

    An existing same-day report is MERGED, not clobbered: per-benchmark
    entries and derived sections from benchmarks not re-run this
    invocation (``--only``) are carried forward.
    """
    date = time.strftime("%Y-%m-%d")
    path = pathlib.Path(out_path) if out_path else \
        REPO_ROOT / f"BENCH_{date}.json"
    old = {}
    if path.exists():
        try:
            old = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            old = {}
    # surface per-pass PassReport wall times and compiler throughput as
    # first-class keys so the perf trajectory of the compiler itself is
    # machine-readable across PRs
    pass_times = dict(old.get("pass_times_s") or {})
    compiler = dict(old.get("compiler") or {})
    backends = dict(old.get("backends_us_per_sample") or {})
    serving = dict(old.get("serving") or {})
    bragg = results.get("bench_braggnn", {}).get("result") or {}
    if isinstance(bragg, dict) and "pass_s" in bragg:
        pass_times["braggnn"] = bragg["pass_s"]
        compiler["braggnn"] = {k: bragg[k] for k in _COMPILER_FIELDS
                               if k in bragg}
    if isinstance(bragg, dict) and "backends" in bragg:
        # per-serving-backend µs/sample — the serving-perf trajectory
        backends["braggnn"] = bragg["backends"]
    srv = results.get("bench_serving", {}).get("result") or {}
    if isinstance(srv, dict) and srv:
        # sustained QPS / tail latency / warm-boot trajectory
        serving = _jsonable(srv)
    trig = dict(old.get("trigger") or {})
    tr = results.get("bench_trigger", {}).get("result") or {}
    if isinstance(tr, dict) and tr.get("backends"):
        # sustained fps / deadline-miss % / drop % trajectory
        trig = _jsonable(tr)
    scaling = dict(old.get("compiler_scaling") or {})
    sc = results.get("bench_compile_scaling", {}).get("result") or {}
    if isinstance(sc, dict) and sc.get("workloads"):
        scaling = _jsonable(sc)
    benchmarks = dict(old.get("benchmarks") or {})
    benchmarks.update(_jsonable(results))
    report = {
        "date": date,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "args": {"fast": args.fast, "only": args.only},
        "pass_times_s": pass_times,
        "compiler": compiler,
        "backends_us_per_sample": backends,
        "serving": serving,
        "trigger": trig,
        "compiler_scaling": scaling,
        "benchmarks": benchmarks,
    }
    if obs.enabled():
        # metrics collected across the whole run (cache hits, padding
        # waste, queue depths, ...) ride along in the perf trajectory
        report["obs"] = _jsonable(obs.snapshot())
    path.write_text(json.dumps(report, indent=1, sort_keys=True))
    return path


def compare_with_previous(report: dict, path: pathlib.Path) -> None:
    """Print a before/after compile-perf comparison against the most recent
    other ``BENCH_*.json`` in the repo root, when one exists."""
    previous = sorted(p for p in REPO_ROOT.glob("BENCH_*.json")
                      if p.resolve() != path.resolve())
    if not previous:
        return
    prev_path = previous[-1]
    try:
        old = json.loads(prev_path.read_text())
    except (OSError, json.JSONDecodeError):
        return
    old_b = (old.get("benchmarks", {}).get("bench_braggnn", {})
             .get("result") or {})
    new_b = (report["benchmarks"].get("bench_braggnn", {})
             .get("result") or {})
    if not (isinstance(old_b, dict) and isinstance(new_b, dict)
            and old_b.get("build_s") and new_b.get("build_s")):
        return
    speedup = old_b["build_s"] / new_b["build_s"]
    log.info("# compile-perf vs %s: build_s %s -> %s (%.1fx)",
             prev_path.name, old_b["build_s"], new_b["build_s"], speedup)
    old_p, new_p = old_b.get("pass_s") or {}, new_b.get("pass_s") or {}
    for name in sorted(set(old_p) | set(new_p)):
        log.info("#   pass %s: %ss -> %ss", name, old_p.get(name, "-"),
                 new_p.get(name, "-"))
    if new_b.get("pass_ops_per_s"):
        log.info("#   pass-pipeline throughput: %s ops/s%s",
                 f"{new_b['pass_ops_per_s']:,}",
                 (f" (was {old_b['pass_ops_per_s']:,})"
                  if old_b.get("pass_ops_per_s") else ""))

    def _backends(b):
        if isinstance(b.get("backends"), dict):
            return b["backends"]
        # pre-backends reports carried two flat keys
        legacy = {"simd": b.get("simd_us_per_sample_cpu"),
                  "tensor": b.get("tensor_us_per_sample_cpu")}
        return {k: round(v, 1) for k, v in legacy.items() if v is not None}

    old_bk, new_bk = _backends(old_b), _backends(new_b)
    if new_bk:
        log.info("#   serving backends (us/sample): %s",
                 ", ".join(f"{name} {old_bk.get(name, '-')} -> "
                           f"{new_bk.get(name, '-')}"
                           for name in sorted(set(old_bk) | set(new_bk))))


def compare_serving(report: dict, path: pathlib.Path) -> None:
    """Per-metric before/after diff of the ``serving`` section (engine QPS,
    tail latency, warm boot) against the most recent other report."""
    previous = sorted(p for p in REPO_ROOT.glob("BENCH_*.json")
                      if p.resolve() != path.resolve())
    new_s = report.get("serving") or {}
    if not (previous and new_s.get("backends")):
        return
    try:
        old = json.loads(previous[-1].read_text())
    except (OSError, json.JSONDecodeError):
        return
    old_s = old.get("serving") or {}
    old_bk = old_s.get("backends") or {}
    log.info("# serving vs %s:", previous[-1].name)
    for name in sorted(new_s["backends"]):
        nb, ob = new_s["backends"][name], old_bk.get(name) or {}
        for metric in ("qps", "p50_ms", "p95_ms", "p99_ms",
                       "max_queue_depth"):
            log.info("#   %s.%s: %s -> %s", name, metric,
                     ob.get(metric, "-"), nb.get(metric, "-"))
    for metric in ("cold_compile_s", "warm_boot_s", "warm_speedup"):
        log.info("#   %s: %s -> %s", metric, old_s.get(metric, "-"),
                 new_s.get(metric, "-"))


def compare_trigger(report: dict, path: pathlib.Path) -> None:
    """Per-backend before/after diff of the ``trigger`` section (sustained
    fps, deadline-miss %, drop %, p99 decision latency) against the most
    recent other report."""
    previous = sorted(p for p in REPO_ROOT.glob("BENCH_*.json")
                      if p.resolve() != path.resolve())
    new_t = report.get("trigger") or {}
    if not (previous and new_t.get("backends")):
        return
    try:
        old = json.loads(previous[-1].read_text())
    except (OSError, json.JSONDecodeError):
        return
    old_bk = (old.get("trigger") or {}).get("backends") or {}
    log.info("# trigger vs %s:", previous[-1].name)
    for name in sorted(new_t["backends"]):
        nb, ob = new_t["backends"][name], old_bk.get(name) or {}
        for metric in ("sustained_fps", "miss_pct", "drop_pct", "p99_us"):
            log.info("#   %s.%s: %s -> %s", name, metric,
                     ob.get(metric, "-"), nb.get(metric, "-"))
    check = new_t.get("budget_check") or {}
    if check:
        log.info("#   budget check vs %s: %s", check.get("part", "?"),
                 "PASS" if check.get("passed") else
                 f"FAIL ({', '.join(check.get('failures', []))})")


def compare_compile_scaling(report: dict, path: pathlib.Path) -> None:
    """Per-workload before/after diff of the ``compiler_scaling`` section
    (compile-time curve + scheduler/partition A/Bs) against the most
    recent other report."""
    previous = sorted(p for p in REPO_ROOT.glob("BENCH_*.json")
                      if p.resolve() != path.resolve())
    new_c = report.get("compiler_scaling") or {}
    if not (previous and new_c.get("workloads")):
        return
    try:
        old = json.loads(previous[-1].read_text())
    except (OSError, json.JSONDecodeError):
        return
    old_w = {w["name"]: w
             for w in (old.get("compiler_scaling") or {}).get("workloads",
                                                              [])}
    log.info("# compile scaling vs %s:", previous[-1].name)
    for w in new_c["workloads"]:
        ow = old_w.get(w["name"]) or {}
        log.info("#   %s (%s ops): total_s %s -> %s, ops/s %s -> %s",
                 w["name"], f"{w['ops_raw']:,}", ow.get("total_s", "-"),
                 w["total_s"], ow.get("ops_per_s", "-"), w["ops_per_s"])
    ab = new_c.get("sched_ab") or {}
    if ab:
        log.info("#   scheduler A/B (largest): legacy %ss / python %ss / "
                 "C %ss (%sx vs legacy)", ab.get("legacy_s", "-"),
                 ab.get("python_scalar_s", "-"), ab.get("c_path_s", "-"),
                 ab.get("speedup_vs_legacy", "-"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default=None,
                    help="aggregate JSON path (default: "
                         "BENCH_<date>.json at the repo root)")
    args, _ = ap.parse_known_args()
    obs.setup_logging()

    from benchmarks import (bench_braggnn, bench_compile_scaling,
                            bench_layers, bench_precision, bench_roofline,
                            bench_serving, bench_tool_runtime, bench_trigger)

    todo = args.only.split(",") if args.only else [
        "layers", "tool_runtime", "braggnn", "precision", "roofline",
        "serving", "trigger", "compile_scaling"]

    results: dict = {}
    print("name,us_per_call,derived")
    if "layers" in todo:
        log.info("## Fig4: layer suite ##")
        _timed("bench_layers", results, bench_layers.main)
    if "tool_runtime" in todo:
        log.info("## Fig2/5: tool runtime ##")
        if args.fast:
            bench_tool_runtime.IMAGE_SIZES = (8, 16, 32)
        _timed("bench_tool_runtime", results, bench_tool_runtime.main)
    if "braggnn" in todo:
        log.info("## §4.2: BraggNN case study ##")
        img = 9 if args.fast else 11
        _timed("bench_braggnn", results, bench_braggnn.main, img=img)
    if "precision" in todo:
        log.info("## Fig7: precision study ##")
        steps = 60 if args.fast else 300
        _timed("bench_precision", results, bench_precision.main, steps=steps)
    if "roofline" in todo:
        log.info("## §Roofline: 40-cell table ##")
        _timed("bench_roofline", results, bench_roofline.main)
    if "serving" in todo:
        log.info("## deployment: serving engine under bursty load ##")
        _timed("bench_serving", results, bench_serving.main, fast=args.fast)
    if "trigger" in todo:
        log.info("## deployment: hard-real-time trigger stream ##")
        _timed("bench_trigger", results, bench_trigger.main, fast=args.fast)
    if "compile_scaling" in todo:
        log.info("## compile-time scaling curve ##")
        _timed("bench_compile_scaling", results, bench_compile_scaling.main,
               fast=args.fast)

    path = write_report(results, args, args.out)
    report = json.loads(path.read_text())
    compare_with_previous(report, path)
    compare_serving(report, path)
    compare_trigger(report, path)
    compare_compile_scaling(report, path)
    log.info("# aggregate report: %s", path)


if __name__ == "__main__":
    main()
