"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,us_per_call,derived`` CSV lines per benchmark plus each
benchmark's own detailed CSV.  Mapping to the paper:
    layers        — Fig. 4   (latency/resources vs unroll, 5 layer types)
    tool_runtime  — Fig. 2/5 (compiler runtime vs trip count)
    braggnn       — §4.2/Fig. 6 (end-to-end case study)
    precision     — Fig. 7   (trained-weight exponents, accuracy sweep)
    roofline      — §Roofline (TPU adaptation; reads dry-run artifacts)
"""

from __future__ import annotations

import argparse
import sys
import time


def _timed(name, fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt:.0f},ok")
    sys.stdout.flush()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_braggnn, bench_layers, bench_precision,
                            bench_roofline, bench_tool_runtime)

    todo = args.only.split(",") if args.only else [
        "layers", "tool_runtime", "braggnn", "precision", "roofline"]

    print("name,us_per_call,derived")
    if "layers" in todo:
        print("## Fig4: layer suite ##")
        _timed("bench_layers", bench_layers.main)
    if "tool_runtime" in todo:
        print("## Fig2/5: tool runtime ##")
        if args.fast:
            bench_tool_runtime.IMAGE_SIZES = (8, 16, 32)
        _timed("bench_tool_runtime", bench_tool_runtime.main)
    if "braggnn" in todo:
        print("## §4.2: BraggNN case study ##")
        img = 9 if args.fast else 11
        _timed("bench_braggnn", bench_braggnn.main, img=img)
    if "precision" in todo:
        print("## Fig7: precision study ##")
        steps = 60 if args.fast else 300
        _timed("bench_precision", bench_precision.main, steps=steps)
    if "roofline" in todo:
        print("## §Roofline: 40-cell table ##")
        _timed("bench_roofline", bench_roofline.main)


if __name__ == "__main__":
    main()
