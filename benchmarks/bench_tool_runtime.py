"""Paper Fig. 2 / Fig. 5: compiler runtime vs problem size.

Fig. 2's point: static store-load forwarding over a fully unrolled conv
explodes (577,419 s at 128x128 trip count 147,456); symbolic interpretation
unrolls the same nests in seconds.  We sweep the conv image size and report
our full pipeline time (interpret + passes + schedule) and the op count —
the trend line that replaces the paper's hours-scale curve.
"""

from __future__ import annotations

import time

from repro.core import Context, frontend, passes
from repro.core.schedule import list_schedule

IMAGE_SIZES = (8, 16, 32, 64, 96, 128)


def run() -> list[dict]:
    rows = []
    for img in IMAGE_SIZES:
        t0 = time.perf_counter()
        ctx = Context()
        x = ctx.memref("input", (1, 1, img, img), "input")
        w = ctx.memref("w", (1, 1, 3, 3), "weight")
        out = ctx.memref("out", (1, 1, img, img), "output")
        frontend.conv2d(ctx, x, w, None, out, padding=1)
        g = ctx.finalize()
        t_interp = time.perf_counter() - t0
        t0 = time.perf_counter()
        g2 = passes.optimize(g)
        t_passes = time.perf_counter() - t0
        t0 = time.perf_counter()
        sched = list_schedule(g2)
        t_sched = time.perf_counter() - t0
        rows.append({
            "image": img, "trip_count": img * img * 9,
            "ops": len(g.ops), "ops_opt": len(g2.ops),
            "interp_s": round(t_interp, 3), "passes_s": round(t_passes, 3),
            "schedule_s": round(t_sched, 3),
            "total_s": round(t_interp + t_passes + t_sched, 3),
            "intervals": sched.makespan,
        })
    return rows


def main(print_csv: bool = True) -> list[dict]:
    rows = run()
    if print_csv:
        print("image,trip_count,ops,ops_opt,interp_s,passes_s,schedule_s,"
              "total_s,intervals")
        for r in rows:
            print(f"{r['image']},{r['trip_count']},{r['ops']},"
                  f"{r['ops_opt']},{r['interp_s']},{r['passes_s']},"
                  f"{r['schedule_s']},{r['total_s']},{r['intervals']}")
        # the paper's 128x128 static-analysis time for contrast
        print("# paper Fig.2: static -affine-scalrep at 128x128 = 577,419 s")
    return rows


if __name__ == "__main__":
    main()
