"""Paper Fig. 2 / Fig. 5: compiler runtime vs problem size.

Fig. 2's point: static store-load forwarding over a fully unrolled conv
explodes (577,419 s at 128x128 trip count 147,456); symbolic interpretation
unrolls the same nests in seconds.  We sweep the conv image size and report
the full ``repro.hls`` compile stage timings (trace / passes /
schedule) plus the per-pass wall-time breakdown from the ``PassReport``s —
the trend line that replaces the paper's hours-scale curve.
"""

from __future__ import annotations

import repro.hls as hls
from repro import obs
from repro.core import frontend

log = obs.get_logger(__name__)

IMAGE_SIZES = (8, 16, 32, 64, 96, 128)


def run() -> list[dict]:
    # sweep workload: each size compiles once; don't pin all designs
    session = hls.Session(max_memory_entries=1)
    rows = []
    for img in IMAGE_SIZES:
        def build(ctx, img=img):
            x = ctx.memref("input", (1, 1, img, img), "input")
            w = ctx.memref("w", (1, 1, 3, 3), "weight")
            out = ctx.memref("out", (1, 1, img, img), "output")
            frontend.conv2d(ctx, x, w, None, out, padding=1)

        design = session.compile(build, name=f"conv_{img}")
        t = design.timings
        rows.append({
            "image": img, "trip_count": img * img * 9,
            "ops": len(design.graph_raw.ops),
            "ops_opt": len(design.graph_opt.ops),
            "interp_s": round(t["trace_s"], 3),
            "passes_s": round(t["passes_s"], 3),
            "schedule_s": round(t["schedule_s"], 3),
            "total_s": round(t["total_s"], 3),
            "intervals": design.makespan,
            "per_pass_s": {k: round(v, 3)
                           for k, v in design.pass_time_by_name().items()},
        })
    return rows


def main(print_csv: bool = True) -> list[dict]:
    rows = run()
    if print_csv:
        print("image,trip_count,ops,ops_opt,interp_s,passes_s,schedule_s,"
              "total_s,intervals")
        for r in rows:
            print(f"{r['image']},{r['trip_count']},{r['ops']},"
                  f"{r['ops_opt']},{r['interp_s']},{r['passes_s']},"
                  f"{r['schedule_s']},{r['total_s']},{r['intervals']}")
        log.info("# per-pass wall time (s), largest image:")
        for k, v in rows[-1]["per_pass_s"].items():
            log.info("#   %s: %s", k, v)
        # the paper's 128x128 static-analysis time for contrast
        log.info("# paper Fig.2: static -affine-scalrep at 128x128 = "
                 "577,419 s")
    return rows


if __name__ == "__main__":
    obs.setup_logging()
    main()
