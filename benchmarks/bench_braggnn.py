"""Paper §4.2 (case study) + Fig. 6: BraggNN end-to-end.

Reproduces, per precision ((5,11) -> (5,4) -> (5,3)):
  * total interval count of the fully scheduled design and the 3-stage
    pipeline initiation interval (paper: 1238 total / 480 II -> 4.8 us);
  * resource analogues (DSP/FF/BRAM), incl. the no-BRAM result;
  * an Alveo-U280-capacity schedule (DSP pool capped at 9024) — the
    apples-to-apples capacity point against the paper's device;
  * the SLL-crossing wire count that forced (5,4) -> (5,3) (§4.2);
  * behavioural accuracy of the quantised functional model vs fp32;
  * measured CPU throughput of the deployable artifacts, one figure per
    serving backend: the emitted SIMD design, the fused tensor path (jit),
    and the Pallas emission backend (registry kernels over the bridged
    nests), fp32 and (5,4).
"""

from __future__ import annotations

import time

import numpy as np

import repro.hls as hls
from repro import obs
from repro.core import emit, frontend, verify
from repro.core.schedule import CLOCK_NS
from repro.core.precision import FORMATS
from repro.trigger import alveo_u280

log = obs.get_logger(__name__)

# the part catalog is the single source of truth for device envelopes
U280_DSP = alveo_u280.dsp


def run(s: int = 1, img: int = 11) -> dict:
    # a private session: this benchmark measures cold-compile time
    session = hls.Session()
    build = lambda ctx: frontend.braggnn(ctx, s=s, img=img)

    # full-capacity schedule (K = max K_i, the paper's binding)
    design = session.compile(build, name=f"braggnn_s{s}")
    g_raw, g = design.graph_raw, design.graph_opt

    out: dict = {"build_s": round(design.timings["total_s"], 2),
                 "trace_s": round(design.timings.get("trace_s", 0.0), 2),
                 "passes_s": round(design.timings.get("passes_s", 0.0), 2),
                 "schedule_s": round(design.timings.get("schedule_s", 0.0), 2),
                 # compiler throughput: ops entering each executed pass
                 # application / total pass wall time — the first-class
                 # perf-trajectory figure tracked across PRs
                 "pass_ops_per_s": round(design.pass_throughput_ops_s()),
                 "ops_raw": len(g_raw.ops), "ops_opt": len(g.ops),
                 "pass_s": {k: round(v, 3)
                            for k, v in design.pass_time_by_name().items()},
                 "passes_skipped": sum(1 for r in design.pass_reports
                                       if r.skipped),
                 "rows": []}

    stages, ii = design.partition(3)
    res = design.schedule.resources()
    out["rows"].append({
        "design": "openhls_fullK", "intervals": design.makespan,
        "stage_ii": ii, "us_per_sample": ii * CLOCK_NS * 1e-3,
        "dsp": res["DSP"], "ff": res["FF"], "bram": res["BRAM_ports"]})

    # U280-capacity schedule: the paper's physical DSP budget.  Reschedule
    # the already-optimised graph (empty pipeline) under the capped capacity
    # — a distinct cache entry keyed by the changed config.
    cfg_u280 = hls.CompilerConfig(pipeline=(), unroll_factor=U280_DSP // 3)
    design_u280 = session.compile(g, name=f"braggnn_s{s}_u280",
                                  config=cfg_u280)
    stages2, ii2 = design_u280.partition(3)
    res2 = design_u280.schedule.resources()
    out["rows"].append({
        "design": "openhls_u280dsp", "intervals": design_u280.makespan,
        "stage_ii": ii2, "us_per_sample": ii2 * CLOCK_NS * 1e-3,
        "dsp": res2["DSP"], "ff": res2["FF"], "bram": res2["BRAM_ports"]})

    # SLL-crossing computation (paper §4.2)
    h1 = img - 2
    wires = (16 * s * h1 * h1 + 8 * s * h1 * h1)
    out["sll"] = {fmt_name: wires * FORMATS[key].wire_bits
                  for fmt_name, key in (("(5,11)", "5_11"), ("(5,4)", "5_4"),
                                        ("(5,3)", "5_3"))}
    out["sll_available"] = 23_040

    # quantised behavioural accuracy
    feeds = verify.random_feeds(g_raw, batch=8, seed=0, scale=0.4)
    ref = emit.evaluate(g, feeds)["dense_3_out"]
    out["quant_err"] = {}
    for key in ("5_11", "5_4", "5_3"):
        q = emit.evaluate(g, feeds, fmt=FORMATS[key])["dense_3_out"]
        denom = np.abs(ref).max() + 1e-9
        out["quant_err"][key] = float(np.abs(q - ref).max() / denom)

    # measured CPU throughput of the deployable paths, per backend
    fn = design.jax_fn()
    batch = 64
    feeds_b = verify.random_feeds(g_raw, batch=batch, seed=1, scale=0.4)
    import jax
    jfn = jax.jit(fn)
    o = jfn(feeds_b)
    jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(jfn(feeds_b))
    out["simd_us_per_sample_cpu"] = (time.perf_counter() - t0) / (
        5 * batch) * 1e6

    from repro.models import braggnn as bnn
    params = bnn.params_from_feeds(feeds_b, s=s)
    # feeds carry (batch,) + memref shape (1, 1, img, img): collapse the
    # per-sample singleton batch of the memref into the throughput batch
    x = np.asarray(feeds_b["input"]).reshape(batch, 1, img, img)
    tfn = jax.jit(lambda p, xx: bnn.forward(p, xx, s=s, fmt="5_4"))
    jax.block_until_ready(tfn(params, x))
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(tfn(params, x))
    out["tensor_us_per_sample_cpu"] = (time.perf_counter() - t0) / (
        20 * batch) * 1e6

    # Pallas emission backend (nest-pattern tier through the kernel
    # registry).  Weight feeds must be shared across the batch (the
    # random_feeds weights vary per sample), so rebuild them from the
    # same params the tensor path uses.
    module = bnn.build(s, img=img, params=params)
    pfeeds = dict(module.weight_feeds())
    pfeeds["input"] = np.asarray(feeds_b["input"])

    def _time_pallas(fmt):
        pfn = emit.to_jax_fn(g, backend="pallas", module=module, fmt=fmt)
        jax.block_until_ready(pfn(pfeeds)["dense_3_out"])
        t0 = time.perf_counter()
        for _ in range(20):
            jax.block_until_ready(pfn(pfeeds)["dense_3_out"])
        return (time.perf_counter() - t0) / (20 * batch) * 1e6, pfn.plan

    out["pallas_us_per_sample_cpu"], plan = _time_pallas(None)
    pallas_54_us, _ = _time_pallas("5_4")
    out["pallas_plan"] = plan.summary()
    #: one µs/sample figure per serving backend (tensor + pallas_5_4 run
    #: the (5,4) quantised model; simd + pallas are the fp32 designs)
    out["backends"] = {
        "simd": round(out["simd_us_per_sample_cpu"], 1),
        "tensor": round(out["tensor_us_per_sample_cpu"], 1),
        "pallas": round(out["pallas_us_per_sample_cpu"], 1),
        "pallas_5_4": round(pallas_54_us, 1),
    }
    return out


def main(print_csv: bool = True, s: int = 1, img: int = 11) -> dict:
    out = run(s=s, img=img)
    if print_csv:
        log.info("# BraggNN(s=%s, img=%s): ops %s -> %s, compile %ss "
                 "(trace %s / passes %s / schedule %s; %s ops/s through "
                 "the pass pipeline, %s pass applications skipped)",
                 s, img, out["ops_raw"], out["ops_opt"], out["build_s"],
                 out["trace_s"], out["passes_s"], out["schedule_s"],
                 f"{out['pass_ops_per_s']:,}", out["passes_skipped"])
        log.info("# per-pass time: %s",
                 ", ".join(f"{k}={v}s" for k, v in out["pass_s"].items()))
        print("design,intervals,stage_ii,us_per_sample,dsp,ff,bram")
        for r in out["rows"]:
            print(f"{r['design']},{r['intervals']},{r['stage_ii']},"
                  f"{r['us_per_sample']:.2f},{r['dsp']},{r['ff']},{r['bram']}")
        log.info("# paper: 1238 intervals total, 3-stage II=480 -> 4.8 us")
        log.info("# SLL crossings (avail %s): %s", out["sll_available"],
                 ", ".join(f"{k}={v}" for k, v in out["sll"].items()))
        log.info("# quant rel-err vs fp32: %s",
                 ", ".join(f"{k}={v:.4f}"
                           for k, v in out["quant_err"].items()))
        log.info("# CPU throughput (us/sample): %s",
                 ", ".join(f"{k}={v}" for k, v in out["backends"].items()))
        log.info("# pallas plan: %s", out["pallas_plan"])
    return out


if __name__ == "__main__":
    obs.setup_logging()
    main()
