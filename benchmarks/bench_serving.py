"""Serving benchmark: sustained QPS + tail latency under bursty open load.

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]
        [--backends tensor,pallas] [--assert-healthy]

The paper's headline is µs/sample in a warm loop; a deployed detector
pipeline instead sees an *open-loop* arrival process — requests arrive on
the experiment's clock whether or not the replica keeps up.  This bench
drives :class:`repro.serving.design_engine.DesignEngine` over a compiled
BraggNN(s=1) with a seeded bursty schedule (Poisson base rate with
periodic burst windows) and reports, per serving backend:

  * sustained QPS (completed / span of completions),
  * p50/p95/p99 per-request latency (queueing + batching + compute),
  * max/mean queue depth, dispatch bucket histogram, padded samples.

It also measures the warm-boot claim in the same run: cold boot = full
``hls.compile`` in a fresh Session + engine bucket warm-up, warm boot =
``hls.load`` of the ``Design.save`` artifact + the same warm-up.  The
aggregate lands in ``BENCH_<date>.json`` under ``"serving"`` via
``benchmarks/run.py``.
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys
import tempfile
import time

import jax
import numpy as np

import repro.hls as hls
from repro import obs
from repro.models import braggnn

log = obs.get_logger(__name__)


@dataclasses.dataclass
class BurstyLoad:
    """Open-loop arrival schedule: Poisson base rate + burst windows.

    Deterministic given ``seed`` — every backend (and every PR) sees the
    same arrival times.  Requests ``burst_len``-out-of-``burst_every`` are
    drawn at ``burst_qps``; arrivals never wait for completions.
    """

    n_requests: int = 240
    base_qps: float = 400.0
    burst_qps: float = 1500.0
    burst_every: int = 60
    burst_len: int = 20
    seed: int = 0

    def schedule(self) -> list[float]:
        """Arrival offsets (s, from load start), strictly increasing."""
        rng = np.random.default_rng(self.seed)
        t, out = 0.0, []
        for i in range(self.n_requests):
            rate = (self.burst_qps if (i % self.burst_every) < self.burst_len
                    else self.base_qps)
            t += float(rng.exponential(1.0 / rate))
            out.append(t)
        return out

    def drive(self, engine, samples: list[np.ndarray]) -> list:
        """Submit ``samples`` (cycled) at the scheduled times; returns the
        request futures.  Open loop: a late engine only grows the queue."""
        sched = self.schedule()
        t0 = time.perf_counter()
        reqs = []
        for i, at in enumerate(sched):
            delay = at - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            reqs.append(engine.submit(samples[i % len(samples)]))
        return reqs

    def describe(self) -> dict:
        return {"n_requests": self.n_requests, "base_qps": self.base_qps,
                "burst_qps": self.burst_qps, "burst_every": self.burst_every,
                "burst_len": self.burst_len, "seed": self.seed}


def _samples(img: int, n: int = 32, seed: int = 1) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.normal(0.0, 0.25, (1, 1, img, img)).astype(np.float32)
            for _ in range(n)]


def _bench_backend(design, backend: str, load: BurstyLoad, img: int,
                   max_batch: int) -> dict:
    eng = design.engine(backend=backend, fmt=None, max_batch=max_batch,
                        max_delay_ms=2.0)
    with eng:
        reqs = load.drive(eng, _samples(img))
        for r in reqs:
            r.wait(timeout=300)
    rep = eng.report()
    return {
        "qps": round(rep.qps, 1),
        "p50_ms": round(rep.p50_ms, 3),
        "p95_ms": round(rep.p95_ms, 3),
        "p99_ms": round(rep.p99_ms, 3),
        "mean_ms": round(rep.mean_ms, 3),
        "completed": rep.completed,
        "dropped": rep.dropped,
        "dispatches": rep.dispatches,
        "batch_hist": {str(k): v for k, v in sorted(rep.batch_hist.items())},
        "padded_samples": rep.padded_samples,
        "max_queue_depth": rep.max_queue_depth,
        "mean_queue_depth": rep.mean_queue_depth,
        "boot_s": round(rep.boot_s, 3),
        "served": rep.served,
    }


def main(fast: bool = False, backends=None) -> dict:
    img = 9 if fast else 11
    max_batch = 8 if fast else 16
    backends = tuple(backends) if backends else ("tensor", "pallas")
    load = BurstyLoad(n_requests=60 if fast else 240)

    model = braggnn.build(1, img)
    params = model.init_params(jax.random.key(0))
    bound = model.bind(params)

    # cold boot: trace + passes + schedule in a fresh Session, then the
    # engine's bucket warm-up — everything a brand-new replica pays
    t0 = time.perf_counter()
    design = hls.Session().compile(bound, name="braggnn_serve")
    design.engine(backend="tensor", max_batch=max_batch)
    cold_s = time.perf_counter() - t0

    out: dict = {"model": f"braggnn_s1_img{img}", "max_batch": max_batch,
                 "load": load.describe(), "backends": {}}

    with tempfile.TemporaryDirectory() as td:
        artifact = pathlib.Path(td) / "braggnn_s1.design"
        design.save(artifact, backend="tensor")
        out["artifact_bytes"] = artifact.stat().st_size

        # warm boot: one disk read + the SAME bucket warm-up, no compile
        t0 = time.perf_counter()
        warmed = hls.load(artifact)
        warmed.engine(max_batch=max_batch)
        warm_s = time.perf_counter() - t0
        out["cold_compile_s"] = round(cold_s, 3)
        out["warm_boot_s"] = round(warm_s, 3)
        out["warm_speedup"] = round(cold_s / warm_s, 1)
        print(f"serving_cold_boot,{cold_s * 1e6:.0f},compile+warm")
        print(f"serving_warm_boot,{warm_s * 1e6:.0f},"
              f"{out['warm_speedup']}x_faster")

        for backend in backends:
            res = _bench_backend(warmed, backend, load, img, max_batch)
            out["backends"][backend] = res
            print(f"serving_{backend},{res['p95_ms'] * 1e3:.0f},"
                  f"{res['qps']}qps")
            sys.stdout.flush()
    return out


def check_healthy(result: dict) -> list[str]:
    """Sanity assertions for CI: every backend completed everything."""
    problems = []
    if result["warm_boot_s"] >= result["cold_compile_s"]:
        problems.append(
            f"warm boot ({result['warm_boot_s']}s) not faster than cold "
            f"compile ({result['cold_compile_s']}s)")
    for name, b in result["backends"].items():
        if b["qps"] <= 0:
            problems.append(f"{name}: qps {b['qps']} <= 0")
        if b["dropped"]:
            problems.append(f"{name}: dropped {b['dropped']} requests")
        if b["completed"] != result["load"]["n_requests"]:
            problems.append(f"{name}: completed {b['completed']} != "
                            f"submitted {result['load']['n_requests']}")
    return problems


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backends", default=None,
                    help="comma-separated subset of tensor,simd,pallas")
    ap.add_argument("--out", default=None, help="write result JSON here")
    ap.add_argument("--assert-healthy", action="store_true",
                    help="exit 1 unless QPS>0 and zero dropped everywhere")
    a = ap.parse_args()
    obs.setup_logging()
    result = main(fast=a.fast,
                  backends=a.backends.split(",") if a.backends else None)
    for name, b in result["backends"].items():
        log.info("# %s: %s qps, p50 %sms / p95 %sms / p99 %sms, "
                 "max queue %s, %s dispatches %s", name, b["qps"],
                 b["p50_ms"], b["p95_ms"], b["p99_ms"],
                 b["max_queue_depth"], b["dispatches"], b["batch_hist"])
    log.info("# boot: cold %ss vs warm %ss (%sx)",
             result["cold_compile_s"], result["warm_boot_s"],
             result["warm_speedup"])
    if a.out:
        import json
        pathlib.Path(a.out).write_text(json.dumps(result, indent=1))
    if a.assert_healthy:
        issues = check_healthy(result)
        for p in issues:
            log.error("# UNHEALTHY: %s", p)
        sys.exit(1 if issues else 0)
