"""Hard-real-time trigger: sustained frame rate, deadlines, drops.

The deployment figure OpenHLS is actually judged on: BraggNN serving a
fixed-rate detector stream as a trigger.  Per serving backend this
benchmark runs a seeded :class:`~repro.trigger.DetectorFeed` (event rate
+ pileup bursts) through a pre-warmed :class:`~repro.trigger.TriggerLoop`
in realtime mode and reports

  * sustained frame rate vs the configured one,
  * deadline-miss % against a per-decision latency budget,
  * drop % out of the drop-oldest ring,
  * p50/p95/p99 decision latency (arrival -> accept/reject),

plus the :meth:`Design.check_budget` verdict against the paper's
deployment part (``alveo_u280``) — the schedule-level contract next to
the measured stream-level numbers.  Feeds the ``trigger`` section of
``BENCH_<date>.json`` via ``benchmarks/run.py``.
"""

from __future__ import annotations

import time

import jax

import repro.hls as hls
from repro import obs, trigger
from repro.models import braggnn

log = obs.get_logger(__name__)

#: per-decision deadline (µs) for the realtime run — generous enough that
#: a warm CPU-simulated backend holds it, tight enough that a regression
#: (or an unwarmed shape on the hot path) shows up as misses
DEADLINE_US = 50_000.0


def run_backend(design, backend: str, *, img: int, n_frames: int,
                rate_hz: float, window: int) -> dict:
    budget = trigger.TriggerBudget(max_latency_us=DEADLINE_US)
    t0 = time.perf_counter()
    loop = design.trigger(backend=backend, window=window, budget=budget)
    loop.calibrate(trigger.DetectorFeed(img=img, seed=11), 64)
    build_s = time.perf_counter() - t0
    feed = trigger.DetectorFeed(img=img, frame_rate_hz=rate_hz, seed=11)
    rep = loop.run(feed, n_frames, realtime=True)
    log.info("  %s: %s", backend, rep.summary())
    out = rep.to_json()
    out.update(build_s=round(build_s, 2), threshold=loop.threshold,
               configured_fps=rate_hz,
               rate_sustained=rep.sustained_fps >= 0.95 * rate_hz)
    for k in ("p50_us", "p95_us", "p99_us", "max_us", "sustained_fps",
              "wall_s", "warmup_s"):
        out[k] = round(out[k], 1)
    return out


def main(fast: bool = False, backends=None) -> dict:
    img = 9 if fast else 11
    n_frames = 200 if fast else 1000
    rate_hz = 500.0 if fast else 1000.0
    window = 4
    backends = tuple(backends) if backends else \
        (("tensor",) if fast else ("tensor", "pallas"))

    model = braggnn.build(1, img)
    params = model.init_params(jax.random.key(0))
    design = hls.Session().compile(model.bind(params),
                                   name=f"braggnn_trigger_img{img}")

    # the deployment contract: full-capacity binding (K = max K_i) blows
    # the U280 DSP pool at img=11, so — like the paper — the deployed
    # schedule caps unrolling at device capacity (4 DSP units per
    # unrolled lane) and must then PASS the part check
    full_check = design.check_budget(part="alveo_u280")
    log.info("full-capacity: %s", full_check.summary())
    if full_check.passed:
        deployed = design
    else:
        deployed = design.with_config(
            hls.CompilerConfig(unroll_factor=trigger.alveo_u280.dsp // 4))
    part_check = deployed.check_budget(part="alveo_u280")
    log.info("deployed: %s", part_check.summary())
    part_check.raise_if_failed()

    out: dict = {"model": f"braggnn_s1_img{img}", "frames": n_frames,
                 "frame_rate_hz": rate_hz, "window": window,
                 "deadline_us": DEADLINE_US,
                 "sample_latency_us": deployed.sample_latency_us,
                 "full_capacity_check": full_check.to_json(),
                 "budget_check": part_check.to_json(),
                 "backends": {}}
    for backend in backends:
        out["backends"][backend] = run_backend(
            deployed, backend, img=img, n_frames=n_frames, rate_hz=rate_hz,
            window=window)
    return out


if __name__ == "__main__":
    import json
    obs.setup_logging()
    print(json.dumps(main(fast=True), indent=1))
