"""§Roofline: the 40-cell three-term table from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun``) and
prints the per-(arch x shape) compute/memory/collective terms, dominant
bottleneck, MODEL_FLOPS ratio, and roofline fraction.  See
EXPERIMENTS.md §Roofline-methodology for sourcing and corrections.
"""

from __future__ import annotations

import pathlib

from repro import obs
from repro.launch import roofline

log = obs.get_logger(__name__)


def main(print_csv: bool = True, dryrun_dir: str = "experiments/dryrun"):
    if not pathlib.Path(dryrun_dir).exists():
        log.warning("# no dry-run artifacts under %s; run "
                    "`python -m repro.launch.dryrun` first", dryrun_dir)
        return []
    rows = roofline.load_cells(dryrun_dir)
    if print_csv:
        print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
              "model_flops,useful_ratio,roofline_frac")
        for r in rows:
            print(f"{r.arch},{r.shape},{r.compute_s:.4f},{r.memory_s:.4f},"
                  f"{r.collective_s:.4f},{r.dominant},{r.model_flops:.3e},"
                  f"{r.useful_ratio:.3f},{r.roofline_fraction:.4f}")
    return rows


if __name__ == "__main__":
    obs.setup_logging()
    main()
