"""Paper Fig. 7 + §4.2 precision study on a TRAINED BraggNN.

Trains BraggNN on synthetic Bragg peaks (Gaussian blobs), then:
  * histograms the trained weight exponents (Fig. 7) and derives the
    smallest sufficient wE;
  * sweeps (5,11)/(5,4)/(5,3) weight+activation quantisation and reports
    localisation error vs fp32 — the accuracy evidence behind the paper's
    precision choices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.precision import (FORMATS, exponent_histogram,
                                  required_exponent_bits)
from repro.models import braggnn
from repro.nn import module
from repro.optim import adamw

log = obs.get_logger(__name__)


def train(steps: int = 300, img: int = 11, batch: int = 64):
    sp = braggnn.specs(1, img)
    params = module.init_tree(sp, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup_steps=20,
                                total_steps=steps, weight_decay=0.0)
    state = adamw.init_state(params)

    def loss_fn(p, x, y):
        return jnp.mean((braggnn.forward(p, x) - y * 10.0) ** 2)

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, s2, _ = adamw.apply_updates(opt_cfg, p, g, s)
        return p2, s2, l

    key = jax.random.key(1)
    losses = []
    for i in range(steps):
        x, y = braggnn.synthetic_peaks(jax.random.fold_in(key, i), batch,
                                       img=img)
        params, state, l = step(params, state, x, y)
        losses.append(float(l))
    return params, losses


def run(steps: int = 300) -> dict:
    params, losses = train(steps)
    hist = exponent_histogram(params)
    out = {
        "loss_first": losses[0], "loss_last": losses[-1],
        "exp_min": min(hist), "exp_max": max(hist),
        "required_we_100": required_exponent_bits(hist, 1.0),
        "required_we_999": required_exponent_bits(hist, 0.999),
        "hist": hist,
    }
    # accuracy sweep
    x, y = braggnn.synthetic_peaks(jax.random.key(99), 256)
    ref = braggnn.forward(params, x)
    err_ref = float(jnp.mean(jnp.abs(ref / 10.0 - y)))
    out["pixel_err_fp32"] = err_ref * 11
    for key in ("5_11", "5_4", "5_3"):
        pred = braggnn.forward(params, x, fmt=key)
        out[f"pixel_err_{key}"] = float(
            jnp.mean(jnp.abs(pred / 10.0 - y))) * 11
    return out


def main(print_csv: bool = True, steps: int = 300) -> dict:
    out = run(steps)
    if print_csv:
        log.info("# trained %s steps: loss %.3f -> %.4f", steps,
                 out["loss_first"], out["loss_last"])
        log.info("# weight exponents in [%s, %s] -> required wE=%s "
                 "(99.9%%: %s) — paper keeps wE=5", out["exp_min"],
                 out["exp_max"], out["required_we_100"],
                 out["required_we_999"])
        print("format,mean_pixel_error")
        print(f"fp32,{out['pixel_err_fp32']:.4f}")
        for key in ("5_11", "5_4", "5_3"):
            print(f"({key.replace('_', ',')}),{out[f'pixel_err_{key}']:.4f}")
    return out


if __name__ == "__main__":
    obs.setup_logging()
    main()
