"""Compile-time scaling curve: conv2d -> BraggNN -> transformer block.

The million-op compile path benchmark: per-phase compile wall time
(trace / passes / schedule / partition) and end-to-end throughput
(raw ops/s) across three workloads spanning ~3 orders of magnitude in
graph size, everything through the public ``repro.hls`` surface.

Also records two A/Bs on the largest workload, feeding the
``compiler_scaling`` section of ``BENCH_<date>.json``:
  * the scheduler: compiled-C ASAP core vs the pure-Python scalar core
    (``REPRO_SCHED_SCALAR=1``) vs the per-``Op`` ``core.legacy`` path —
    the headline schedule+partition speedup is measured against legacy,
    the golden reference both fast paths are proven bit-identical to;
  * the numpy-batched stage-partition DP vs the historical scalar DP.
"""

from __future__ import annotations

import os
import time

import repro.hls as hls
from repro import obs
from repro.core import frontend
from repro.core.schedule import (_partition_stages_scalar, list_schedule,
                                 partition_stages)

log = obs.get_logger(__name__)


def _conv2d_build(ctx):
    x = ctx.memref("input", (1, 2, 12, 12), "input")
    w = ctx.memref("w", (8, 2, 3, 3), "weight")
    b = ctx.memref("b", (8,), "weight")
    out = ctx.memref("out", (1, 8, 10, 10), "output")
    frontend.conv2d(ctx, x, w, b, out)


def _workloads(fast: bool):
    if fast:
        return [
            ("conv2d", _conv2d_build),
            ("braggnn", lambda ctx: frontend.braggnn(ctx, s=1, img=9)),
            ("transformer", lambda ctx: frontend.transformer_encoder_block(
                ctx, seq=8, d_model=32, n_heads=4, ffn=64)),
        ]
    return [
        ("conv2d", _conv2d_build),
        ("braggnn", lambda ctx: frontend.braggnn(ctx, s=1, img=11)),
        ("transformer", lambda ctx: frontend.transformer_encoder_block(
            ctx, seq=16, d_model=64, n_heads=4, ffn=256)),
    ]


def _sched_ab(design) -> dict:
    """C ASAP core vs forced-Python scalar core vs the per-``Op`` legacy
    scheduler on the optimised graph (all three must agree)."""
    from repro.core import legacy
    g_opt = design.graph_opt
    t0 = time.perf_counter()
    s_c = list_schedule(g_opt)
    c_s = time.perf_counter() - t0
    os.environ["REPRO_SCHED_SCALAR"] = "1"
    try:
        t0 = time.perf_counter()
        s_py = list_schedule(g_opt)
        py_s = time.perf_counter() - t0
    finally:
        del os.environ["REPRO_SCHED_SCALAR"]
    t0 = time.perf_counter()
    s_l = legacy.list_schedule(g_opt)
    legacy_s = time.perf_counter() - t0
    assert s_c.makespan == s_py.makespan == s_l.makespan, \
        "A/B paths disagree"
    return {"c_path_s": round(c_s, 3), "python_scalar_s": round(py_s, 3),
            "legacy_s": round(legacy_s, 3),
            "speedup": round(py_s / c_s, 1) if c_s > 0 else None,
            "speedup_vs_legacy":
                round(legacy_s / c_s, 1) if c_s > 0 else None,
            "makespan": s_c.makespan}


def _partition_ab(design, n_stages: int = 3) -> dict:
    g_opt, sched = design.graph_opt, design.schedule
    t0 = time.perf_counter()
    stages_v, ii_v = partition_stages(g_opt, sched, n_stages)
    vec_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stages_s, ii_s = _partition_stages_scalar(g_opt, sched, n_stages)
    sca_s = time.perf_counter() - t0
    assert ii_v == ii_s and stages_v == stages_s, "partition DPs disagree"
    return {"vectorised_s": round(vec_s, 4), "scalar_s": round(sca_s, 4),
            "speedup": round(sca_s / vec_s, 1) if vec_s > 0 else None,
            "stage_ii": ii_v}


def run(fast: bool = False) -> dict:
    out: dict = {"workloads": []}
    largest = largest_ops = None
    for name, build in _workloads(fast):
        session = hls.Session()       # private: measures cold compiles
        design = session.compile(build, name=f"scaling_{name}")
        tm = design.timings
        total = tm.get("total_s") or (tm.get("trace_s", 0.0)
                                      + tm.get("passes_s", 0.0)
                                      + tm.get("schedule_s", 0.0))
        ops_raw = len(design.graph_raw.ops)
        row = {"name": name, "ops_raw": ops_raw,
               "ops_opt": len(design.graph_opt.ops),
               "trace_s": round(tm.get("trace_s", 0.0), 3),
               "passes_s": round(tm.get("passes_s", 0.0), 3),
               "schedule_s": round(tm.get("schedule_s", 0.0), 3),
               "partition_s": round(tm.get("partition_s", 0.0), 4),
               "total_s": round(total, 3),
               "ops_per_s": round(ops_raw / total) if total > 0 else None}
        out["workloads"].append(row)
        log.info("# %s: %s raw ops, %.2fs total (%.0f ops/s)", name,
                 f"{ops_raw:,}", total, row["ops_per_s"] or 0)
        if largest_ops is None or ops_raw > largest_ops:
            largest, largest_ops = design, ops_raw
    out["sched_ab"] = _sched_ab(largest)
    out["partition_ab"] = _partition_ab(largest)
    log.info("# scheduler on largest graph: legacy %.2fs / python-scalar "
             "%.2fs / C %.2fs (%.1fx vs legacy)",
             out["sched_ab"]["legacy_s"],
             out["sched_ab"]["python_scalar_s"],
             out["sched_ab"]["c_path_s"],
             out["sched_ab"]["speedup_vs_legacy"] or 0)
    return out


def main(fast: bool = False) -> dict:
    return run(fast=fast)


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
