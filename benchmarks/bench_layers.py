"""Paper Fig. 4: per-layer latency + resources vs unroll factor.

For each of the five layer types (Table 1 shapes) we schedule:
  * the OpenHLS design: store-load forwarding + full pass pipeline + full
    K_i binding (one point — full unroll);
  * the conventional-HLS baseline (Vitis-like): NO forwarding (loads/stores
    kept, 2 ports/array) and capacity limited to the unroll factor u, for
    u in {1, 4, 16, 64, 256, 1024}.

Reported per design: interval count, end-to-end latency (10 ns clock),
DSP/FF/BRAM-port analogues, and compiler runtime — reproducing the paper's
headline: the baseline never reaches the forwarded design's latency, and
its tool time explodes with u while symbolic interpretation stays flat.
"""

from __future__ import annotations

import dataclasses

import repro.hls as hls
from repro.core import frontend
from repro.core.schedule import CLOCK_NS

UNROLL_FACTORS = (1, 4, 16, 64, 256, 1024)


def _builders():
    def addmm(ctx):
        a = ctx.memref("a", (16, 16), "input")
        b = ctx.memref("b", (16, 16), "input")
        c = ctx.memref("c", (16, 16), "input")
        out = ctx.memref("out", (16, 16), "output")
        frontend.addmm(ctx, a, b, c, out)

    def batch_norm_2d(ctx):
        x = ctx.memref("input", (10, 2, 3, 3), "input")
        g = ctx.memref("gamma", (2,), "weight")
        bt = ctx.memref("beta", (2,), "weight")
        mu = ctx.memref("mean", (2,), "weight")
        var = ctx.memref("var", (2,), "weight")
        out = ctx.memref("out", (10, 2, 3, 3), "output")
        frontend.batch_norm_2d(ctx, x, g, bt, mu, var, out)

    def conv_2d(ctx):
        x = ctx.memref("input", (1, 1, 16, 16), "input")
        w = ctx.memref("w", (3, 1, 3, 3), "weight")
        b = ctx.memref("b", (3,), "weight")
        out = ctx.memref("out", (1, 3, 16, 16), "output")
        frontend.conv2d(ctx, x, w, b, out, padding=1)

    def max_pool_2d(ctx):
        x = ctx.memref("input", (1, 3, 16, 16), "input")
        out = ctx.memref("out", (1, 3, 7, 7), "output")
        frontend.max_pool_2d(ctx, x, out, k=3, stride=2)

    def soft_max(ctx):
        x = ctx.memref("input", (1, 3, 16, 16), "input")
        out = ctx.memref("out", (1, 3, 16, 16), "output")
        frontend.soft_max(ctx, x, out)

    return {"addmm": addmm, "batch_norm_2d": batch_norm_2d,
            "conv_2d": conv_2d, "max_pool_2d": max_pool_2d,
            "soft_max": soft_max}


def run() -> list[dict]:
    # sweep workload: no config is ever re-compiled, so keep the memory
    # cache tiny instead of pinning every design for the whole sweep
    session = hls.Session(max_memory_entries=2)
    rows = []
    for name, build in _builders().items():
        # OpenHLS design: one hls compile call is the whole flow
        design = session.compile(build, name=name)
        res = design.schedule.resources()
        rows.append({
            "layer": name, "design": "openhls", "unroll": "full",
            "intervals": design.makespan,
            "latency_us": design.latency_us,
            "dsp": res["DSP"], "ff": res["FF"],
            "bram_ports": res["BRAM_ports"],
            "tool_s": round(design.timings["total_s"], 3),
        })
        # Vitis-like baseline at increasing unroll: trace once in
        # no-forwarding mode, then one config (= one cache entry) per u —
        # ``with_config`` reuses the traced graph across the sweep
        cfg0 = hls.CompilerConfig(pipeline=(), forward=False,
                                  unroll_factor=UNROLL_FACTORS[0])
        d_base = session.compile(hls.trace(build, forward=False),
                                 name=f"{name}_u{UNROLL_FACTORS[0]}",
                                 config=cfg0)
        for u in UNROLL_FACTORS:
            d_u = d_base if u == UNROLL_FACTORS[0] else d_base.with_config(
                dataclasses.replace(cfg0, unroll_factor=u),
                name=f"{name}_u{u}")
            res_u = d_u.schedule.resources()
            rows.append({
                "layer": name, "design": "baseline", "unroll": u,
                "intervals": d_u.makespan,
                "latency_us": d_u.latency_us,
                "dsp": res_u["DSP"], "ff": res_u["FF"],
                "bram_ports": res_u["BRAM_ports"],
                "tool_s": round(d_u.timings["schedule_s"], 3),
            })
    return rows


def main(print_csv: bool = True) -> list[dict]:
    rows = run()
    if print_csv:
        print("layer,design,unroll,intervals,latency_us,dsp,ff,bram_ports,"
              "tool_s")
        for r in rows:
            print(f"{r['layer']},{r['design']},{r['unroll']},"
                  f"{r['intervals']},{r['latency_us']:.2f},{r['dsp']},"
                  f"{r['ff']},{r['bram_ports']},{r['tool_s']}")
    return rows


if __name__ == "__main__":
    main()
