"""Scheduler invariants (paper §3.3), as property tests.

For arbitrary generated programs, any schedule must:
  * respect data dependencies (consumer starts after producer finishes),
  * never exceed the per-class unit capacity K in any cycle,
  * report a makespan equal to the latest op end.
ALAP compaction must preserve all of the above and the makespan.
More units can never hurt: makespan is monotone non-increasing in
``unroll_factor`` (Fig. 4's latency-vs-unroll trend).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # hypothesis is optional: only the property
    def _skip_deco(*a, **k):   # tests skip; plain tests below still run
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    given = settings = _skip_deco
    st = _NullStrategies()

from repro.core import Context, frontend, passes
from repro.core.ir import DEFAULT_DELAYS, RESOURCE_CLASS
from repro.core.schedule import list_schedule, partition_stages


def _program(ops, width=8):
    ctx = Context()
    x = ctx.memref("x", (width,), "input")
    out = ctx.memref("out", (width,), "output")
    for (i,) in ctx.parallel(width, label="outer"):
        acc = x[i]
        for kind, j in ops:
            other = x[(i + j) % width]
            if kind == 0:
                acc = acc + other
            elif kind == 1:
                acc = acc * other
            elif kind == 2:
                acc = acc.max(other)
            else:
                acc = acc - other
        out[i] = acc
    return ctx.finalize()


def _check_valid(g, sched, *, capacity=None, pipelined=False):
    delays = DEFAULT_DELAYS
    # 1) dependencies
    ready = {}
    for op in g.ops:
        start = sched.start[op.idx]
        for a in op.args:
            if a in ready:
                assert start >= ready[a], (op.idx, op.opcode)
        if op.result >= 0:
            ready[op.result] = start + delays.get(op.opcode, 0)
    # 2) capacity per class per cycle
    if capacity is not None:
        from collections import defaultdict
        busy = defaultdict(list)     # class -> list of (start, end)
        for op in g.ops:
            cls = RESOURCE_CLASS.get(op.opcode)
            if cls is None or cls == "port":
                continue
            d = delays.get(op.opcode, 0)
            occ = 1 if pipelined else max(d, 1)
            busy[cls].append((sched.start[op.idx],
                              sched.start[op.idx] + occ))
        for cls, spans in busy.items():
            events = []
            for s, e in spans:
                events.append((s, 1))
                events.append((e, -1))
            events.sort()
            live = peak = 0
            for _, delta in events:
                live += delta
                peak = max(peak, live)
            assert peak <= capacity, (cls, peak, capacity)
    # 3) makespan
    ends = [sched.start[op.idx] + delays.get(op.opcode, 0) for op in g.ops]
    assert sched.makespan == max(ends)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                min_size=1, max_size=24))
def test_schedule_valid_pool(ops):
    g = passes.optimize(_program(ops))
    sched = list_schedule(g, binding="pool")
    _check_valid(g, sched, capacity=g.K())


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                min_size=1, max_size=24))
def test_schedule_valid_rank(ops):
    g = passes.optimize(_program(ops))
    sched = list_schedule(g, binding="rank")
    _check_valid(g, sched)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                min_size=2, max_size=20),
       st.integers(1, 4))
def test_unroll_monotonicity(ops, k):
    """More lanes never increases the interval count (Fig. 4 trend)."""
    g = passes.optimize(_program(ops))
    m1 = list_schedule(g, unroll_factor=k).makespan
    m2 = list_schedule(g, unroll_factor=2 * k).makespan
    m_full = list_schedule(g).makespan
    assert m2 <= m1
    assert m_full <= m2


def test_alap_keeps_makespan_and_validity():
    ctx = Context()
    x = ctx.memref("x", (32,), "input")
    out = ctx.memref("out", (1,), "output")
    with ctx.sequential("sum"):
        acc = x[0]
        for i in range(1, 32):
            acc = acc + x[i] * x[(i + 1) % 32]
        out[0] = acc
    g = passes.optimize(ctx.finalize())
    s_no = list_schedule(g, alap_compact=False)
    s_yes = list_schedule(g, alap_compact=True)
    assert s_yes.makespan == s_no.makespan
    _check_valid(g, s_yes, capacity=g.K())
    # ALAP can only shrink register pressure
    assert s_yes.peak_live <= s_no.peak_live


def test_pipeline_stage_partition():
    ctx = Context()
    frontend.braggnn(ctx, s=1, img=7)     # reduced BraggNN
    g = passes.optimize(ctx.finalize())
    sched = list_schedule(g)
    stages, ii = partition_stages(g, sched, 3)
    assert len(stages) == 3
    assert sum(len(s) for s in stages) == len(sched.nest_spans)
    assert 0 < ii <= sched.makespan


def test_schedule_params_unroll_tile_distinct_but_equivalent_braggnn():
    """BraggNN(s=1): distinct unroll/tile factors give distinct schedules,
    never distinct numerics — the invariant the repro.tune search relies on.
    """
    from repro.core import CompilerConfig, CompilerDriver, emit, verify
    from repro.core.schedule import ScheduleParams

    driver = CompilerDriver()
    g = driver.trace(lambda ctx: frontend.braggnn(ctx, s=1, img=7))

    configs = {
        "full_K": CompilerConfig(),
        "unroll_64": CompilerConfig(unroll_factor=64),
        "unroll_16": CompilerConfig(unroll_factor=16),
        "staged_3": CompilerConfig(n_stages=3),
    }
    designs = {name: driver.compile(g, name=name, config=cfg)
               for name, cfg in configs.items()}

    # all four share one pass-stage run (schedule knobs only)
    opts = {id(d.graph_opt) for d in designs.values()}
    assert len(opts) == 1

    # distinct schedules: fewer lanes -> strictly more intervals
    m_full = designs["full_K"].makespan
    m_64 = designs["unroll_64"].makespan
    m_16 = designs["unroll_16"].makespan
    assert m_full < m_64 < m_16
    assert designs["unroll_64"].schedule.start != \
        designs["unroll_16"].schedule.start

    # tile (stage-partition) factor is first-class on the design
    staged = designs["staged_3"]
    assert staged.stages is not None and len(staged.stages) == 3
    assert 0 < staged.stage_ii <= staged.makespan
    assert staged.sample_latency_us < staged.latency_us

    # ... but numerics are schedule-invariant: every design evaluates
    # bit-identically (same optimised graph, different timing only)
    feeds = verify.random_feeds(g, batch=2, seed=0, scale=0.4)
    outs = [d.evaluate(feeds) for d in designs.values()]
    for other in outs[1:]:
        for k in outs[0]:
            np.testing.assert_array_equal(outs[0][k], other[k])

    # ScheduleParams bundle == the flat-kwarg call, field for field
    g_opt = designs["full_K"].graph_opt
    p = ScheduleParams(unroll_factor=16)
    s_bundle = list_schedule(g_opt, params=p)
    s_flat = list_schedule(g_opt, unroll_factor=16)
    assert s_bundle.start == s_flat.start
    assert s_bundle.makespan == s_flat.makespan == m_16


def test_no_bram_in_forwarding_mode():
    """The paper's headline: OpenHLS designs use zero BRAM (all forwarding)."""
    ctx = Context()
    a = ctx.memref("a", (4, 4), "input")
    b = ctx.memref("b", (4, 4), "input")
    c = ctx.memref("c", (4, 4), "input")
    out = ctx.memref("out", (4, 4), "output")
    frontend.addmm(ctx, a, b, c, out)
    g = passes.optimize(ctx.finalize())
    assert list_schedule(g).resources()["BRAM_ports"] == 0

    ctx2 = Context(forward=False)
    a2 = ctx2.memref("a", (4, 4), "input")
    b2 = ctx2.memref("b", (4, 4), "input")
    c2 = ctx2.memref("c", (4, 4), "input")
    out2 = ctx2.memref("out", (4, 4), "output")
    frontend.addmm(ctx2, a2, b2, c2, out2)
    g2 = ctx2.finalize()
    assert list_schedule(g2).resources()["BRAM_ports"] > 0


def test_partition_stages_vectorised_matches_scalar_randomised():
    """The numpy-batched stage-partition DP must agree with the historical
    scalar DP (same stages, same ii, same first-minimiser tie-breaks) on
    randomised nest spans."""
    from types import SimpleNamespace

    from repro.core.schedule import _partition_stages_scalar

    rng = np.random.default_rng(7)
    for trial in range(60):
        m = int(rng.integers(1, 40))
        starts = np.sort(rng.integers(0, 500, size=m))
        lengths = rng.integers(1, 120, size=m)
        spans = {f"nest{t}": (int(starts[t]), int(starts[t] + lengths[t]))
                 for t in range(m)}
        sched = SimpleNamespace(nest_spans=spans)
        for n_stages in (1, 2, 3, int(rng.integers(1, 8))):
            stages_v, ii_v = partition_stages(None, sched, n_stages)
            stages_s, ii_s = _partition_stages_scalar(None, sched, n_stages)
            assert ii_v == ii_s, (trial, n_stages)
            assert stages_v == stages_s, (trial, n_stages)


def test_partition_stages_empty_and_degenerate():
    from types import SimpleNamespace

    from repro.core.schedule import _partition_stages_scalar

    empty = SimpleNamespace(nest_spans={})
    assert partition_stages(None, empty, 3) == ([[]], 0)
    assert _partition_stages_scalar(None, empty, 3) == ([[]], 0)

    one = SimpleNamespace(nest_spans={"only": (5, 17)})
    stages, ii = partition_stages(None, one, 4)   # n_stages > nests
    assert stages == [["only"]] and ii == 12
