"""repro.tune: spaces, strategies, evaluator gates, TuningDB persistence,
and the CLI contract (rerun served from the DB without re-searching).

Search-loop mechanics are tested against fake trials (no compiles); the
end-to-end paths run on a small conv2d design so the suite stays fast.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import CompilerConfig, CompilerDriver, cachedir, frontend
from repro.core.pipeline import DEFAULT_PIPELINE
from repro.tune import (Bisection, Candidate, Evaluator, HillClimb, Knob,
                        RandomSearch, SearchSpace, Trial, TuneResult, Tuner,
                        TuningDB, best_config_for, conv2d_space,
                        sweep_variants)
from repro.tune.cli import main as cli_main


def _conv_build(ctx):
    x = ctx.memref("input", (1, 2, 6, 6), "input")
    w = ctx.memref("weight", (3, 2, 3, 3), "weight")
    b = ctx.memref("bias", (3,), "weight")
    out = ctx.memref("out", (1, 3, 4, 4), "output")
    frontend.conv2d(ctx, x, w, b, out)


def _small_space():
    return SearchSpace((
        Knob("unroll_factor", (None, 8, 2)),
        Knob("pipelined_units", (False, True)),
    ), name="small")


def _fake_trial(candidate, latency, *, valid=True, dsp=0):
    return Trial(candidate=candidate, design_hash="x", latency_us=latency,
                 makespan=int(latency * 100), stage_ii=None, err=0.0,
                 valid=valid, resources={"DSP": dsp}, wire_bits=32,
                 est_roofline_us=0.0, measured_cpu_us=None, compile_s=0.0,
                 cached=False)


# -- space -------------------------------------------------------------------


def test_space_default_size_and_lowering():
    space = conv2d_space()
    c = space.default()
    assert space.contains(c)
    assert space.size() == 2 * 3 * 2 * 2
    cfg = space.to_config(c)
    assert cfg.pipeline == DEFAULT_PIPELINE
    assert cfg.unroll_factor is None
    assert space.to_format(c) is None          # baseline fp32
    c2 = c.replace("precision", "5_4")
    assert space.to_format(c2).man_bits == 4
    assert space.to_config(c.replace("unroll_factor", 16)).unroll_factor == 16


def test_space_rejects_bad_knobs():
    with pytest.raises(ValueError, match="unknown knob"):
        SearchSpace((Knob("warp_speed", (1, 2)),))
    with pytest.raises(ValueError, match="unregistered pass"):
        SearchSpace((Knob("pipeline", (("cse", "not_a_pass"),)),))
    with pytest.raises(ValueError, match="precision"):
        SearchSpace((Knob("precision", ("fp64",)),))
    with pytest.raises(ValueError, match="empty domain"):
        Knob("unroll_factor", ())


def test_candidate_json_roundtrip_and_hash():
    c = Candidate.of({"pipeline": ("cse", "dce"), "unroll_factor": None,
                      "precision": "5_4"})
    back = Candidate.from_json(json.loads(json.dumps(c.to_json())))
    assert back == c
    assert hash(back) == hash(c)
    assert back.get("pipeline") == ("cse", "dce")


def test_space_hash_sensitive_to_domain_and_base():
    s1, s2 = _small_space(), _small_space()
    assert s1.space_hash() == s2.space_hash()
    s3 = SearchSpace(s1.knobs[:1], name="small")
    assert s3.space_hash() != s1.space_hash()
    s4 = SearchSpace(s1.knobs, name="small",
                     base=CompilerConfig(tree_threshold=2))
    assert s4.space_hash() != s1.space_hash()


# -- strategies (driven with fake trials, no compiles) -----------------------


def test_random_search_unique_in_space():
    space = _small_space()
    s = RandomSearch(seed=1)
    s.reset(space, space.default())
    seen = set()
    while (c := s.propose()) is not None:
        assert space.contains(c)
        assert c not in seen
        seen.add(c)
    assert len(seen) == space.size() - 1       # everything but the baseline


def test_hillclimb_descends_to_optimum():
    space = _small_space()
    # synthetic objective: unroll None=3us, 8=2us, 2=1us; pipelined -0.5
    def latency(c):
        base = {None: 3.0, 8: 2.0, 2: 1.0}[c.get("unroll_factor")]
        return base - (0.5 if c.get("pipelined_units") else 0.0)

    s = HillClimb()
    base = space.default()
    s.reset(space, base)
    s.observe(base, _fake_trial(base, latency(base)))
    evaluated = {base}
    while (c := s.propose()) is not None:
        if c in evaluated:
            continue
        evaluated.add(c)
        s.observe(c, _fake_trial(c, latency(c)))
    assert s.best.get("unroll_factor") == 2
    assert s.best.get("pipelined_units") is True


def test_bisection_finds_minimal_capacity_meeting_target():
    space = SearchSpace((Knob("unroll_factor", (None, 64, 16, 4, 1)),),
                        name="bs")
    # monotone latency in capacity; target 5.0 -> smallest feasible is 16
    lat = {1: 40.0, 4: 10.0, 16: 5.0, 64: 3.0, None: 1.0}

    s = Bisection(target_us=5.0)
    s.reset(space, space.default())
    n = 0
    while (c := s.propose()) is not None and n < 20:
        n += 1
        s.observe(c, _fake_trial(c, lat[c.get("unroll_factor")]))
    assert s.feasible.get("unroll_factor") == 16
    assert n <= 4                              # log2(5) bisection, not a scan


def test_bisection_precision_descent_stops_at_invalid():
    space = SearchSpace((
        Knob("unroll_factor", (None, 4)),
        Knob("precision", ("5_11", "5_4", "5_3")),
    ), name="bsp")
    s = Bisection(target_us=100.0)
    s.reset(space, space.default())
    while (c := s.propose()) is not None:
        valid = c.get("precision") != "5_3"    # (5,3) fails the gate
        s.observe(c, _fake_trial(c, 1.0, valid=valid))
    assert s.feasible.get("precision") == "5_4"


def test_sweep_variants_skips_and_orders():
    ran = []
    out = sweep_variants(
        [("a", 1), ("b", 2), ("c", 3)],
        lambda tag, p: ran.append(tag) or p * 10,
        skip=lambda tag, p: tag == "b")
    assert ran == ["a", "c"]
    assert out == {"a": 10, "c": 30}


# -- evaluator ---------------------------------------------------------------


@pytest.fixture(scope="module")
def conv_evaluator():
    return Evaluator(_conv_build, conv2d_space(), name="conv_eval")


def test_evaluator_validates_and_costs(conv_evaluator):
    ev = conv_evaluator
    t = ev.evaluate(ev.space.default())
    assert t.valid and t.err <= 1e-3
    assert t.latency_us > 0 and t.makespan > 0
    assert t.est_roofline_us > 0
    assert t.measured_cpu_us is None           # dry by default
    assert t.resources["DSP"] > 0

    # quantised candidate: gated on relative error, narrower wires
    tq = ev.evaluate(ev.space.default().replace("precision", "5_4"))
    assert tq.err > t.err
    assert tq.wire_bits == 12 < t.wire_bits

    # schedule-only mutation reuses the pass stage and the numerics memo
    evals = ev.n_evals
    tu = ev.evaluate(ev.space.default().replace("unroll_factor", 4))
    assert ev.n_evals == evals + 1
    assert tu.makespan > t.makespan
    assert tu.err == t.err                     # same optimised graph


def test_evaluator_invalid_when_tolerance_zero():
    ev = Evaluator(_conv_build, conv2d_space(), tol_abs=0.0, tol_rel=0.0)
    t = ev.evaluate(ev.space.default().replace("precision", "5_4"))
    assert not t.valid
    assert t.score() is None


# -- tuner + db --------------------------------------------------------------


def test_tuner_end_to_end_persists_and_serves_reruns(tmp_path):
    db = TuningDB(tmp_path / "db.json")
    space = conv2d_space()
    driver = CompilerDriver()
    ev = Evaluator(_conv_build, space, driver=driver, name="conv_tune")
    tuner = Tuner(ev, HillClimb(), db=db, budget=5)
    res = tuner.run()

    assert not res.from_db
    assert len(res.trials) <= 5
    assert res.best.valid
    assert res.best.latency_us <= res.baseline.latency_us
    assert all(t.valid for t in [res.best])    # accepted => validated
    assert db.path.exists()

    # the DB stores the full trial log as plain JSON, keyed by run context
    entries = db.entries_for(res.design_fingerprint, res.space_hash)
    assert len(entries) == 1
    entry = next(iter(entries.values()))
    assert entry["strategy"] == "hillclimb"
    assert entry["context"]["eval"]["mode"] == "dry"
    assert entry["n_trials"] == len(res.trials)

    # rerun with the same budget: served from the DB, zero evaluations
    ev2 = Evaluator(_conv_build, space, driver=driver, name="conv_tune")
    res2 = Tuner(ev2, HillClimb(), db=db, budget=5).run()
    assert res2.from_db
    assert ev2.n_evals == 0
    assert res2.best.candidate == res.best.candidate

    # a larger budget is NOT covered -> searches again
    res3 = Tuner(ev2, HillClimb(), db=db, budget=7).run()
    assert not res3.from_db

    # changed evaluation settings are a different experiment: re-search,
    # stored under a new context key (nothing overwritten)
    ev3 = Evaluator(_conv_build, space, driver=driver, name="conv_tune",
                    scale=0.2)
    res4 = Tuner(ev3, HillClimb(), db=db, budget=5).run()
    assert not res4.from_db
    assert len(db.entries_for(res.design_fingerprint, res.space_hash)) == 2

    # serving-side auto-load resolves the best valid config across contexts
    hit = best_config_for(ev.graph, space, db=db)
    assert hit is not None
    cfg, cand = hit
    assert cand in {res3.best.candidate, res4.best.candidate}
    assert cfg == space.to_config(cand)


def test_db_invalid_best_never_served(tmp_path):
    """An entry whose best failed the numerics gate must not reach
    serving, and a bisect run toward a different target is a different
    context (no false DB hit)."""
    from repro.tune.db import best_entry

    db = TuningDB(tmp_path / "db.json")
    space = conv2d_space()
    # all-invalid run: zero tolerance fails every candidate
    ev = Evaluator(_conv_build, space, tol_abs=0.0, tol_rel=0.0)
    res = Tuner(ev, Bisection(target_us=1e9), db=db, budget=2).run()
    assert not res.best.valid
    assert "numerics gate" in res.summary()
    assert best_entry(db, res.design_fingerprint, res.space_hash) is None
    assert best_config_for(ev.graph, space, db=db) is None

    # same strategy, different target -> different context -> no DB serve
    ev2 = Evaluator(_conv_build, space, tol_abs=0.0, tol_rel=0.0)
    res2 = Tuner(ev2, Bisection(target_us=1.0), db=db, budget=2).run()
    assert not res2.from_db

    # a valid run coexists and wins the serving lookup
    ev3 = Evaluator(_conv_build, space)
    res3 = Tuner(ev3, HillClimb(), db=db, budget=3).run()
    assert best_config_for(ev3.graph, space, db=db) is not None
    assert len(db.entries_for(res.design_fingerprint, res.space_hash)) == 3


def test_tuner_force_researches(tmp_path):
    db = TuningDB(tmp_path / "db.json")
    ev = Evaluator(_conv_build, conv2d_space())
    Tuner(ev, RandomSearch(seed=0), db=db, budget=2).run()
    before = ev.n_evals
    res = Tuner(ev, RandomSearch(seed=0), db=db, budget=2).run(force=True)
    assert not res.from_db
    assert ev.n_evals > before                 # evaluator ran again


# -- shared versioned cache root (the eviction bugfix) -----------------------


def test_cache_root_evicts_stale_versions(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    stale = tmp_path / "v1" / "designs"
    stale.mkdir(parents=True)
    (stale / "old.pkl").write_bytes(b"stale")
    unrelated = tmp_path / "not_a_version"
    unrelated.mkdir()

    root = cachedir.cache_root("tune")
    assert root == tmp_path / f"v{cachedir.CACHE_FORMAT_VERSION}" / "tune"
    assert root.is_dir()
    assert not (tmp_path / "v1").exists()      # stale version evicted
    assert unrelated.exists()                  # non-version dirs untouched

    # TuningDB defaults into the shared root
    db = TuningDB()
    assert db.path.parent == root
    db.put("fp", "sh", {"best": {"candidate": {"unroll_factor": 4}}})
    assert db.get("fp", "sh")["best"]["candidate"] == {"unroll_factor": 4}


def test_tuning_db_discards_stale_schema(tmp_path):
    path = tmp_path / "db.json"
    path.write_text(json.dumps({"version": -1, "entries": {"k": {}}}))
    db = TuningDB(path)
    assert db.entries() == {}
    db.put("a", "b", {"best": {}})
    assert json.loads(path.read_text())["version"] == \
        cachedir.CACHE_FORMAT_VERSION


# -- CLI ---------------------------------------------------------------------


def test_cli_conv2d_dry_and_db_rerun(tmp_path, capsys):
    db_path = str(tmp_path / "cli_db.json")
    res = cli_main(["--config", "conv2d", "--dry", "--budget", "3",
                    "--db", db_path])
    assert not res.from_db
    assert res.best.latency_us <= res.baseline.latency_us
    out = capsys.readouterr().out
    assert "trial   1" in out and "best of" in out

    res2 = cli_main(["--config", "conv2d", "--dry", "--budget", "3",
                     "--db", db_path])
    assert res2.from_db
    assert "served from tuning DB" in capsys.readouterr().out

    res3 = cli_main(["--config", "conv2d", "--dry", "--db", db_path,
                     "--show"])
    assert res3.from_db
    assert res3.best.candidate == res2.best.candidate


def test_precision_only_candidates_share_one_design_and_pass_stage():
    """Precision-only tune candidates (the error/wire-bits sweep) must not
    recompile: ``to_config`` drops the precision knob, so they map to one
    CompilerConfig -> one design-cache entry.  Schedule-only mutations do
    recompile but reuse the optimised graph via the pass-stage memo —
    both visible through ``Session.stats()``."""
    import repro.hls as hls

    space = conv2d_space()
    session = hls.Session()
    base = space.default()
    for prec in ("5_11", "5_4", "5_3"):
        session.compile(_conv_build, name="conv_prec",
                        config=space.to_config(base.replace("precision",
                                                            prec)))
    st = session.stats()
    assert st["recompiles"] == 1
    assert st["hits"] == 2
    assert st["pass_memo_hits"] == 0        # full cache hits skip passes

    # schedule-only mutation: new design, same optimised graph
    session.compile(_conv_build, name="conv_unroll",
                    config=space.to_config(base.replace("unroll_factor",
                                                        4)))
    st2 = session.stats()
    assert st2["recompiles"] == 2
    assert st2["pass_memo_hits"] == 1
    assert st2["pass_memo_entries"] == 1


# -- trigger-budget gate -----------------------------------------------------


def test_budget_gate_flips_winner(tmp_path):
    """The acceptance criterion: the fastest candidate blows the DSP cap,
    so the constrained search must crown the fastest *feasible* one —
    a different winner than the unconstrained run."""
    from repro.trigger import TriggerBudget

    space = conv2d_space()
    driver = CompilerDriver()

    # unconstrained: full-capacity unrolling wins (heaviest DSP footprint)
    ev = Evaluator(_conv_build, space, driver=driver, name="conv_gate")
    free = Tuner(ev, RandomSearch(seed=0), budget=24).run()
    free_dsp = free.best.resources["DSP"]
    assert free.best.feasible and free.best.budget_failures == []

    # cap below the free winner's footprint: the winner must change, and
    # the new one must actually fit
    budget = TriggerBudget(max_dsp=free_dsp - 1)
    ev2 = Evaluator(_conv_build, space, driver=driver, name="conv_gate",
                    budget=budget)
    capped = Tuner(ev2, RandomSearch(seed=0), budget=24).run()
    assert capped.best.candidate != free.best.candidate
    assert capped.best.feasible
    assert capped.best.resources["DSP"] < free_dsp
    assert capped.best.latency_us >= free.best.latency_us

    # over-budget trials are logged as infeasible with the offender named,
    # and are ineligible (score None) — mirroring the numerics gate
    over = [t for t in capped.trials if not t.feasible]
    assert over
    assert all(t.score() is None for t in over)
    assert all("DSP" in t.budget_failures for t in over)
    assert any("OVER BUDGET" in t.summary() for t in over)

    # the budget is part of the evaluation context: the two runs are
    # different experiments
    assert ev.settings()["budget"] is None
    assert ev2.settings()["budget"] == budget.key()


def test_design_tune_accepts_trigger_budget(tmp_path):
    """`Design.tune(..., budget=TriggerBudget(...))` — the literal
    acceptance-criterion spelling — routes the envelope to the gate and
    keeps the trial count on `trials=`."""
    import repro.hls as hls
    from repro.trigger import TriggerBudget

    session = hls.Session()
    design = session.compile(_conv_build, name="conv_design_tune")
    space = conv2d_space()

    free = design.tune(space, strategy=RandomSearch(seed=0), trials=24,
                       db=TuningDB(tmp_path / "free.json"))
    cap = free.best.resources["DSP"] - 1
    capped = design.tune(space, strategy=RandomSearch(seed=0),
                         budget=TriggerBudget(max_dsp=cap), trials=24,
                         db=TuningDB(tmp_path / "capped.json"))
    assert capped.best.candidate != free.best.candidate
    assert capped.best.resources["DSP"] <= cap

    # part= shorthand builds the envelope too
    from repro.trigger import part
    capped2 = design.tune(space, strategy=RandomSearch(seed=0), trials=24,
                          part=part(dsp=cap),
                          db=TuningDB(tmp_path / "capped2.json"))
    assert capped2.best.candidate == capped.best.candidate

    with pytest.raises(ValueError, match="not both"):
        design.tune(space, budget=TriggerBudget(max_dsp=4),
                    trigger_budget=TriggerBudget(max_dsp=4))


def test_db_infeasible_best_never_served(tmp_path):
    """An all-infeasible run persists for the log, but its best must
    never reach serving — exactly like an invalid (numerics) best."""
    from repro.tune.db import best_entry
    from repro.trigger import TriggerBudget

    db = TuningDB(tmp_path / "db.json")
    space = conv2d_space()
    impossible = TriggerBudget(max_dsp=1)          # nothing fits
    ev = Evaluator(_conv_build, space, budget=impossible)
    res = Tuner(ev, RandomSearch(seed=0), db=db, budget=4).run()
    assert not res.best.feasible
    assert "trigger budget" in res.summary()
    assert "DSP" in res.summary()
    assert best_entry(db, res.design_fingerprint, res.space_hash) is None
    assert best_config_for(ev.graph, space, db=db) is None

    # a feasible run coexists under its own context and wins the lookup
    ev2 = Evaluator(_conv_build, space, budget=TriggerBudget(max_dsp=10 ** 6))
    res2 = Tuner(ev2, RandomSearch(seed=0), db=db, budget=4).run()
    assert res2.best.feasible
    hit = best_config_for(ev2.graph, space, db=db)
    assert hit is not None and hit[1] == res2.best.candidate

    # trial JSON roundtrips the gate fields (additive schema change)
    back = Trial.from_json(json.loads(json.dumps(res.best.to_json())))
    assert back.feasible is False
    assert back.budget_failures == res.best.budget_failures
