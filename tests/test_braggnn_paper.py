"""BraggNN case-study checks against the paper's §4.2 claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Context, emit, frontend, passes, verify
from repro.core.precision import FP_5_3, FP_5_4
from repro.core.schedule import list_schedule, partition_stages
from repro.models import braggnn
from repro.nn import module


@pytest.fixture(scope="module")
def braggnn_graphs():
    """Reduced BraggNN (img=7) keeps CI fast; the full s=1/img=11 build is
    exercised by benchmarks/bench_braggnn.py."""
    ctx = Context()
    frontend.braggnn(ctx, s=1, img=7)
    g_raw = ctx.finalize()
    g_opt = passes.optimize(g_raw)
    return g_raw, g_opt


def test_scalar_dfg_matches_tensor_model(braggnn_graphs):
    """The loop-nest DFG and the jnp BraggNN are the same function."""
    g_raw, _ = braggnn_graphs
    # scale 0.25: with *untrained* random weights the NLB attention scores
    # grow with feed scale, and beyond ~|z/4| > 8 the paper's 8th-order
    # Taylor exp leaves its accurate domain (the DFG and the true-exp
    # tensor model then diverge by design — trained BraggNN weights keep
    # scores well inside it, see benchmarks/bench_precision.py).
    feeds = verify.random_feeds(g_raw, batch=2, seed=0, scale=0.25)
    out_dfg = emit.evaluate(g_raw, feeds)["dense_3_out"]
    params = braggnn.params_from_feeds(
        {k: v[:1] for k, v in feeds.items() if k != "input"})
    # params_from_feeds takes weights only; drive the tensor model with the
    # batch of inputs but the FIRST feed's weights -> compare batch row 0
    x = jnp.asarray(feeds["input"][0])        # (1, 1, img, img)
    out_t = braggnn.forward(params, x, s=1)
    np.testing.assert_allclose(out_dfg[0, 0], np.asarray(out_t)[0],
                               rtol=5e-2, atol=5e-3)


def test_optimised_dfg_semantics_preserved(braggnn_graphs):
    g_raw, g_opt = braggnn_graphs
    feeds = verify.random_feeds(g_raw, batch=2, seed=1, scale=0.5)
    a = emit.evaluate(g_raw, feeds)["dense_3_out"]
    b = emit.evaluate(g_opt, feeds)["dense_3_out"]
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_schedule_structure(braggnn_graphs):
    _, g_opt = braggnn_graphs
    sched = list_schedule(g_opt)
    assert sched.makespan > 0
    res = sched.resources()
    assert res["BRAM_ports"] == 0          # the paper's no-BRAM result
    stages, ii = partition_stages(g_opt, sched, 3)
    assert len(stages) == 3 and ii <= sched.makespan


def test_quantized_functional_model(braggnn_graphs):
    """(5,4) quantisation stays usably close to fp32 (paper's precision
    choice), (5,3) degrades further but stays finite."""
    g_raw, g_opt = braggnn_graphs
    feeds = verify.random_feeds(g_raw, batch=2, seed=2, scale=0.3)
    ref = emit.evaluate(g_opt, feeds)["dense_3_out"]
    q54 = emit.evaluate(g_opt, feeds, fmt=FP_5_4)["dense_3_out"]
    q53 = emit.evaluate(g_opt, feeds, fmt=FP_5_3)["dense_3_out"]
    assert np.all(np.isfinite(q54)) and np.all(np.isfinite(q53))
    scale = np.abs(ref).max() + 1e-6
    assert np.abs(q54 - ref).max() / scale < 0.35
    assert np.abs(q53 - ref).max() >= np.abs(q54 - ref).max() * 0.3


def test_braggnn_training_converges():
    """End-to-end substrate check: 200 Adam steps on synthetic peaks reduce
    the held-out localisation loss by >5x (paper's model is trainable in
    our stack).

    Recalibrated by a seeded lr/step-budget sweep on CPU jax (2026-07-28):
    with the original peak_lr <= 1e-2 the loss plateaus at ~2x (a dead
    basin just below the mean predictor); peak_lr=3e-2 on a near-constant
    schedule (total_steps >> steps) escapes it and reaches ~700x on this
    seed (worst case 33x across seed variants), so the 5x bar holds with
    wide margin.  The eval loss is measured on a fixed held-out batch,
    which is less noisy than the final minibatch loss.
    """
    from repro.optim import adamw
    cfg_img = 11
    steps = 200
    sp = braggnn.specs(1, cfg_img)
    params = module.init_tree(sp, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=3e-2, warmup_steps=20,
                                total_steps=10 * steps, weight_decay=0.0)
    state = adamw.init_state(params)

    def loss_fn(p, x, y):
        pred = braggnn.forward(p, x)
        return jnp.mean((pred - y * 10.0) ** 2)

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, s2, _ = adamw.apply_updates(opt_cfg, p, g, s)
        return p2, s2, l

    eval_x, eval_y = braggnn.synthetic_peaks(jax.random.key(99), 256,
                                             img=cfg_img)
    first = float(loss_fn(params, eval_x, eval_y))
    key = jax.random.key(1)
    for i in range(steps):
        x, y = braggnn.synthetic_peaks(jax.random.fold_in(key, i), 64,
                                       img=cfg_img)
        params, state, l = step(params, state, x, y)
    last = float(loss_fn(params, eval_x, eval_y))
    assert last < first / 5, (first, last)
    # and it genuinely localises: well below the ~1.7 loss of always
    # predicting the mean centre (the plateau the old lr never escaped)
    assert last < 1.0, last
