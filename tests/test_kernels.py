"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in
interpret mode (the TPU-target kernels executed on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d_vmem.conv2d_vmem import conv2d_vmem
from repro.kernels.conv2d_vmem.ref import conv2d_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.fused_softmax.fused_softmax import fused_softmax
from repro.kernels.fused_softmax.ref import fused_softmax_ref
from repro.kernels.smallfloat_matmul.ref import smallfloat_matmul_ref
from repro.kernels.smallfloat_matmul.smallfloat_matmul import smallfloat_matmul


def _r(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (64, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("em", [(5, 4), (5, 3), (5, 11)])
def test_smallfloat_matmul_sweep(m, k, n, dtype, em):
    key = jax.random.key(m * n + em[1])
    x = _r(jax.random.fold_in(key, 0), (m, k), dtype)
    w = _r(jax.random.fold_in(key, 1), (k, n), dtype)
    got = smallfloat_matmul(x, w, exp_bits=em[0], man_bits=em[1])
    want = smallfloat_matmul_ref(x, w, exp_bits=em[0], man_bits=em[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_smallfloat_matmul_bias_relu():
    key = jax.random.key(0)
    x = _r(jax.random.fold_in(key, 0), (128, 128), jnp.float32)
    w = _r(jax.random.fold_in(key, 1), (128, 128), jnp.float32)
    b = _r(jax.random.fold_in(key, 2), (128,), jnp.float32)
    got = smallfloat_matmul(x, w, b, fuse_relu=True)
    want = smallfloat_matmul_ref(x, w, b, fuse_relu=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    assert float(jnp.min(got)) >= 0.0


@pytest.mark.parametrize("b,cin,cout,img,kk", [
    (8, 1, 16, 11, 3), (4, 3, 8, 9, 3), (2, 16, 8, 9, 1)])
@pytest.mark.parametrize("fmt", [None, (5, 4)])
def test_conv2d_vmem_sweep(b, cin, cout, img, kk, fmt):
    key = jax.random.key(b * img)
    x = _r(jax.random.fold_in(key, 0), (b, cin, img, img), jnp.float32)
    w = _r(jax.random.fold_in(key, 1), (cout, cin, kk, kk), jnp.float32)
    bias = _r(jax.random.fold_in(key, 2), (cout,), jnp.float32)
    got = conv2d_vmem(x, w, bias, fmt=fmt, bb=min(4, b))
    want = conv2d_ref(x, w, bias, fmt=fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s,h,kv,d", [(128, 4, 2, 32), (256, 2, 2, 64),
                                      (64, 8, 1, 16)])
@pytest.mark.parametrize("window,cap", [(None, 0.0), (32, 0.0),
                                        (None, 10.0)])
def test_flash_attention_sweep(s, h, kv, d, window, cap):
    key = jax.random.key(s + h)
    q = _r(jax.random.fold_in(key, 0), (2, s, h, d), jnp.float32)
    k = _r(jax.random.fold_in(key, 1), (2, s, kv, d), jnp.float32)
    v = _r(jax.random.fold_in(key, 2), (2, s, kv, d), jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, window=window,
                           logit_cap=cap, use_pallas=True)
    want = fa_ops.attention(q, k, v, causal=True, window=window,
                            logit_cap=cap, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_matches_model_blockwise():
    """Kernel and the model's XLA blockwise path agree on GQA inputs."""
    from repro.nn import attention as nn_attn
    key = jax.random.key(3)
    B, S, H, K, D = 2, 128, 4, 2, 32
    q = _r(jax.random.fold_in(key, 0), (B, S, H, D), jnp.float32)
    k = _r(jax.random.fold_in(key, 1), (B, S, K, D), jnp.float32)
    v = _r(jax.random.fold_in(key, 2), (B, S, K, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = nn_attn.blockwise_attention(q, k, v, q_pos=pos, k_pos=pos,
                                    causal=True, block_size=32)
    b = fa_ops.attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("rows,cols", [(256, 64), (128, 200), (512, 32)])
@pytest.mark.parametrize("taylor", [0, 8])
def test_fused_softmax_sweep(rows, cols, taylor):
    key = jax.random.key(rows + cols)
    x = _r(key, (rows, cols), jnp.float32) * 3.0
    got = fused_softmax(x, taylor_order=taylor)
    want = fused_softmax_ref(x, taylor_order=taylor)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-4)


def test_fused_softmax_taylor_close_to_true_softmax():
    """The paper's Taylor exp (order 8, 2^2 range reduction) approximates
    true softmax to ~1e-3 on the stabilised domain."""
    key = jax.random.key(9)
    x = _r(key, (64, 96), jnp.float32) * 2.0
    approx = fused_softmax(x, taylor_order=8)
    true = fused_softmax_ref(x, taylor_order=0)
    assert float(jnp.max(jnp.abs(approx - true))) < 5e-3
