"""Model-substrate correctness: attention paths agree, decode-with-cache
matches full-sequence forward for EVERY temporal-mixing family, MoE routes
sanely, rope variants are shape/semantics-correct."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.nn import attention, module, moe as moe_lib, rope, transformer


def _r(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def test_blockwise_matches_full_attention():
    key = jax.random.key(0)
    B, S, H, K, D = 2, 96, 4, 2, 16
    q = _r(jax.random.fold_in(key, 0), (B, S, H, D))
    k = _r(jax.random.fold_in(key, 1), (B, S, K, D))
    v = _r(jax.random.fold_in(key, 2), (B, S, K, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for window, cap in [(None, 0.0), (16, 0.0), (None, 30.0)]:
        a = attention.full_attention(q, k, v, q_pos=pos, k_pos=pos,
                                     causal=True, window=window,
                                     logit_cap=cap)
        b = attention.blockwise_attention(q, k, v, q_pos=pos, k_pos=pos,
                                          causal=True, window=window,
                                          logit_cap=cap, block_size=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


TINY_CONFIGS = {
    "dense-gqa": ModelConfig(
        name="t", family="dense", n_layers=3, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, attn_pattern=("global",),
        attn_block_size=32),
    "local+softcap+postnorm": ModelConfig(
        name="t", family="dense", n_layers=4, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64,
        attn_pattern=("local", "global"), window=8, attn_softcap=20.0,
        final_softcap=30.0, post_norms=True, zero_centered_norm=True,
        attn_block_size=32),
    "rglru-hybrid": ModelConfig(
        name="t", family="hybrid", n_layers=5, d_model=32, n_heads=4,
        n_kv_heads=1, d_ff=64, vocab_size=64, lru_width=32,
        attn_pattern=("rglru", "rglru", "local"), window=8,
        attn_block_size=32),
    "xlstm": ModelConfig(
        name="t", family="ssm", n_layers=4, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=64, mlstm_chunk=8,
        attn_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        attn_block_size=32),
    "moe": ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=64, attn_pattern=("global",),
        n_experts=4, n_experts_padded=4, experts_per_token=2,
        expert_d_ff=32, capacity_factor=2.0, attn_block_size=32),
}


@pytest.mark.parametrize("name", list(TINY_CONFIGS))
def test_decode_matches_forward(name):
    """Token-by-token decode with cache reproduces the full forward —
    the strongest cache-correctness check, for every mixing family."""
    cfg = TINY_CONFIGS[name]
    S = 12
    params = module.init_tree(transformer.model_specs(cfg),
                              jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (2, S), 0, cfg.vocab_size)
    logits_full, _ = transformer.forward(cfg, params, toks)

    cache = transformer.init_cache(cfg, 2, S + 4)
    outs = []
    for t in range(S):
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = transformer.decode_step(cfg, params, toks[:, t:t + 1],
                                            cache, pos)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    # tolerance is relative to the logit SCALE: the cache quantises K/V and
    # recurrent conv state to bf16 by design, and decode re-rounds values
    # the forward path keeps in registers (double rounding), compounding
    # through recurrent gates.  fp32-everything agrees to ~1e-3; the
    # masking bug this test exists to catch produced errors of ~4.0 (13%
    # of scale) — we assert < 1%.
    a, b = np.asarray(logits_dec), np.asarray(logits_full)
    scale = np.abs(b).max()
    assert np.abs(a - b).max() <= 0.01 * scale, (
        np.abs(a - b).max(), scale)


def test_moe_routes_and_balances():
    cfg = TINY_CONFIGS["moe"]
    p = module.init_tree(
        moe_lib.moe_specs(32, 4, 32, n_experts_padded=4), jax.random.key(0))
    x = _r(jax.random.key(1), (2, 16, 32))
    y, aux = moe_lib.moe(p, x, n_experts=4, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert 0.5 < float(aux) < 8.0     # E * sum f_e P_e ~ 1 when balanced


def test_moe_padding_experts_never_selected():
    p = module.init_tree(
        moe_lib.moe_specs(16, 3, 16, n_experts_padded=8), jax.random.key(0))
    x = _r(jax.random.key(1), (1, 32, 16))
    y, _ = moe_lib.moe(p, x, n_experts=3, top_k=2)
    assert bool(jnp.all(jnp.isfinite(y)))
    # direct check on router probabilities
    logits = jnp.einsum("nd,de->ne", x.reshape(-1, 16),
                        p["router"]["kernel"])
    masked = jnp.where(jnp.arange(8) >= 3, -1e30, logits)
    probs = jax.nn.softmax(masked, -1)
    assert float(probs[:, 3:].max()) == 0.0


def test_moe_token_chunks_equivalent():
    p = module.init_tree(
        moe_lib.moe_specs(16, 4, 16, n_experts_padded=4), jax.random.key(0))
    x = _r(jax.random.key(1), (2, 16, 16))
    y1, a1 = moe_lib.moe(p, x, n_experts=4, top_k=2, capacity_factor=4.0,
                         token_chunks=1)
    y2, a2 = moe_lib.moe(p, x, n_experts=4, top_k=2, capacity_factor=4.0,
                         token_chunks=4)
    # chunking changes which tokens hit capacity; at high capacity factor
    # nothing drops and results must match exactly
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


def test_rope_orthogonal_and_position_zero_identity():
    x = _r(jax.random.key(0), (1, 8, 2, 16))
    pos = jnp.zeros((1, 8), jnp.int32)
    y = rope.rope(x, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
    # norm preservation at any position
    pos2 = jnp.arange(8)[None]
    y2 = rope.rope(x, pos2)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y2), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_partial_rope_leaves_tail_untouched():
    x = _r(jax.random.key(0), (1, 4, 1, 16))
    y = rope.rope(x, jnp.arange(4)[None], fraction=0.25)
    np.testing.assert_array_equal(np.asarray(y[..., 4:]),
                                  np.asarray(x[..., 4:]))


def test_mrope_matches_rope_for_text():
    """With t==h==w position streams, M-RoPE == standard RoPE."""
    x = _r(jax.random.key(0), (2, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y1 = rope.rope(x, pos)
    y2 = rope.mrope(x, rope.text_positions_3d(pos), sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-6)
