"""The public API surface: ``repro.hls`` contract + deprecation shims.

Covers the api_redesign acceptance criteria: the documented ``__all__``
surface, warn-once deprecation shims that forward to ``repro.hls``, and
bit-identity (CompiledDesign hash) of ``hls.compile`` with the direct
``CompilerDriver`` path.
"""

import warnings

import numpy as np
import pytest

import repro.hls as hls
from repro.core import frontend, pipeline, verify
from repro.core.pipeline import CompilerConfig, CompilerDriver

#: The documented surface (README "Public API" section).  Additions are
#: deliberate API changes: update the README and this tuple together.
DOCUMENTED_SURFACE = (
    "compile",
    "load",
    "trace",
    "Design",
    "Session",
    "ServeReport",
    "CompilerConfig",
    "CompiledDesign",
    "ModuleGraph",
)


def conv_build(ctx):
    x = ctx.memref("input", (1, 3, 8, 8), "input")
    w = ctx.memref("weight", (4, 3, 3, 3), "weight")
    b = ctx.memref("bias", (4,), "weight")
    out = ctx.memref("out", (1, 4, 6, 6), "output")
    frontend.conv2d(ctx, x, w, b, out)


@pytest.fixture()
def design():
    return hls.Session().compile(conv_build, name="conv_api")


# ---------------------------------------------------------------------------
# Surface
# ---------------------------------------------------------------------------


def test_all_is_the_documented_surface():
    assert tuple(hls.__all__) == DOCUMENTED_SURFACE
    for name in hls.__all__:
        assert getattr(hls, name, None) is not None, name


def test_compile_rejects_garbage():
    with pytest.raises(TypeError, match="ModuleGraph"):
        hls.compile(42)


# ---------------------------------------------------------------------------
# Bit-identity with the internal driver
# ---------------------------------------------------------------------------


def test_hash_identical_to_compiler_driver(design):
    direct = CompilerDriver().compile(conv_build, name="conv_api")
    assert design.design_hash == direct.design_hash
    assert design.graph_opt is not direct.graph_opt  # separate caches
    np.testing.assert_array_equal(design.graph_opt.cols().opcode,
                                  direct.graph_opt.cols().opcode)


def test_trace_matches_driver_trace():
    from repro.core.pipeline import graph_fingerprint
    g = hls.trace(conv_build)
    g2 = CompilerDriver().trace(conv_build)
    assert graph_fingerprint(g) == graph_fingerprint(g2)


# ---------------------------------------------------------------------------
# Design verbs
# ---------------------------------------------------------------------------


def test_run_accepts_dict_and_merges_nothing_for_plain_builds(design):
    feeds = verify.random_feeds(design.graph_raw, batch=3, seed=0)
    out = design.run(feeds)
    assert out["out"].shape == (3, 1, 4, 6, 6)
    # matches the raw artifact evaluation
    ref = design.compiled.evaluate(feeds)
    np.testing.assert_array_equal(out["out"], ref["out"])


def test_verify_passes(design):
    rep = design.verify(batch=2, seed=0)
    assert rep.passed, rep.summary()


def test_with_config_shares_trace_and_changes_hash(design):
    d2 = design.with_config(CompilerConfig(pipeline=("cse", "dce")))
    assert d2.design_hash != design.design_hash
    assert d2.graph_raw is design.graph_raw        # trace shared
    assert d2.session is design.session


def test_report_mentions_pipeline_and_schedule(design):
    text = design.report()
    assert "pipeline" in text and "schedule" in text
    assert design.design_hash[:12] in text


def test_session_cache_hit():
    s = hls.Session()
    d1 = s.compile(conv_build, name="a")
    d2 = s.compile(conv_build, name="a")
    assert s.stats()["hits"] == 1
    assert d1.compiled is d2.compiled


def test_serve_simd_backend(design):
    x = np.random.default_rng(0).normal(
        0, 0.5, (4, 3, 8, 8)).astype(np.float32)
    weights = verify.random_feeds(design.graph_raw, batch=1, seed=1)
    feeds = {k: v[0] for k, v in weights.items() if k != "input"}
    feeds["input"] = x[:, None]
    rep = design.serve([feeds, feeds], backend="simd", collect=True)
    assert rep.batches == 2 and rep.samples == 8
    assert rep.us_per_sample > 0
    assert len(rep.outputs) == 2


def test_example_inputs_shape_checked():
    with pytest.raises(ValueError, match="does not match"):
        hls.Session().compile(conv_build, example_inputs=np.zeros((4, 7, 7)))


# ---------------------------------------------------------------------------
# Tuning verbs (the resolve_config replacement)
# ---------------------------------------------------------------------------


def test_tune_persists_and_apply_tuned_loads(design, tmp_path, caplog):
    import logging
    from repro.tune import TuningDB, conv2d_space
    db = TuningDB(tmp_path / "db.json")
    space = conv2d_space()

    # miss path is loud, not silent: names the probed DB path (a WARNING
    # on the repro logger since the print->logging conversion)
    with caplog.at_level(logging.WARNING, logger="repro"):
        same, cand = design.apply_tuned(space, db=db)
    assert same is design and cand is None
    assert str(db.path) in caplog.text
    caplog.clear()

    result = design.tune(space, strategy="random", budget=2, db=db, dry=True)
    assert len(result.trials) >= 1 and len(db) == 1   # auto-persisted

    tuned, cand = design.apply_tuned(space, db=db)
    assert cand is not None
    assert tuned.config == space.to_config(cand)
    assert tuned.tuned_candidate is cand
    # a covered rerun is served from the DB without searching
    again = design.tune(space, strategy="random", budget=2, db=db, dry=True)
    assert again.from_db

    # compile(tuned=space) resolves the win before its single compile
    d3 = hls.compile(conv_build, session=design.session, tuned=space, db=db)
    assert d3.tuned_candidate is not None
    assert d3.config == space.to_config(d3.tuned_candidate)
    # and a miss on an empty DB is loud, keeping the given config
    from repro.tune import TuningDB
    empty = TuningDB(tmp_path / "empty.json")
    with caplog.at_level(logging.WARNING, logger="repro"):
        d4 = hls.compile(conv_build, session=design.session, tuned=space,
                         db=empty)
    assert d4.tuned_candidate is None
    assert str(empty.path) in caplog.text


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------


def test_shims_warn_exactly_once():
    pipeline._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        a = pipeline.compile(conv_build, name="shim")
        b = pipeline.compile(conv_build, name="shim")
        drv = pipeline.default_driver()
        pipeline.default_driver()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 2, [str(w.message) for w in dep]
    assert any("repro.hls.compile" in str(w.message) for w in dep)
    # the shims forward to the hls layer: same artifact type, same session
    assert isinstance(a, hls.CompiledDesign)
    assert a is b                                   # served from the cache
    assert drv is hls._default_session().driver
