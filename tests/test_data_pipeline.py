"""Data pipeline: seekability, host sharding, prefetch semantics."""

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticTokenPipeline


def test_seekable_and_deterministic():
    a = SyntheticTokenPipeline(DataConfig(seq_len=32, global_batch=4,
                                          vocab_size=1000, seed=7))
    b = SyntheticTokenPipeline(DataConfig(seq_len=32, global_batch=4,
                                          vocab_size=1000, seed=7))
    np.testing.assert_array_equal(a.batch_at(5)["tokens"],
                                  b.batch_at(5)["tokens"])
    assert not np.array_equal(a.batch_at(5)["tokens"],
                              a.batch_at(6)["tokens"])


def test_targets_are_shifted_tokens():
    p = SyntheticTokenPipeline(DataConfig(seq_len=16, global_batch=2,
                                          vocab_size=100))
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)
    # next-token: targets[t] is the stream one step ahead — verify by
    # reconstructing from the same seed
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_sharding_disjoint_and_covering():
    full = SyntheticTokenPipeline(DataConfig(seq_len=8, global_batch=4,
                                             vocab_size=50, num_hosts=1))
    h0 = SyntheticTokenPipeline(DataConfig(seq_len=8, global_batch=4,
                                           vocab_size=50, num_hosts=2,
                                           host_id=0))
    h1 = SyntheticTokenPipeline(DataConfig(seq_len=8, global_batch=4,
                                           vocab_size=50, num_hosts=2,
                                           host_id=1))
    assert h0.local_batch == h1.local_batch == 2
    b0, b1 = h0.batch_at(3), h1.batch_at(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    del full


def test_prefetch_in_order_and_seek():
    p = SyntheticTokenPipeline(DataConfig(seq_len=8, global_batch=2,
                                          vocab_size=64, prefetch=2))
    p.seek(0)
    for step in range(4):
        got = p.get(step)
        np.testing.assert_array_equal(got["tokens"],
                                      p.batch_at(step)["tokens"])
    # rewind (restart path)
    p.seek(1)
    got = p.get(1)
    np.testing.assert_array_equal(got["tokens"], p.batch_at(1)["tokens"])
    p.stop()


def test_vocab_bounds():
    p = SyntheticTokenPipeline(DataConfig(seq_len=64, global_batch=4,
                                          vocab_size=97))
    b = p.batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 97
