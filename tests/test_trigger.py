"""repro.trigger: part catalog, budget checks, and the streaming loop.

The subsystem acceptance criteria: structured pass/fail budget reports on
both sides (a feasible design vs the deployment part, a capped synthetic
part failing with *named* resources), drop-oldest ring overrun, seeded
feed determinism with pileup bursts, bit-identical accept/reject
decisions across same-seed runs, deadline accounting, and the per-window
obs spans/counters.
"""

import time

import jax
import numpy as np
import pytest

import repro.hls as hls
from repro import obs, trigger
from repro.models import braggnn
from repro.serving.common import DropOldestRing

IMG = 7


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.disable()
    obs.reset()
    yield
    obs.disable()


@pytest.fixture(scope="module")
def design():
    """One small bound BraggNN design shared by the loop tests."""
    model = braggnn.build(1, IMG)
    params = model.init_params(jax.random.key(0))
    return hls.Session().compile(model.bind(params), name="braggnn_trig")


# -- parts -------------------------------------------------------------------


def test_part_caps_speak_schedule_vocabulary():
    caps = trigger.alveo_u280.caps()
    assert caps["DSP"] == 9024
    assert caps["BRAM_ports"] == 2 * 2016          # ports, not blocks
    assert set(caps) <= {"DSP", "FF", "BRAM_ports", "LUT_units"}
    assert trigger.zcu102.caps()["DSP"] == 2520
    # synthetic parts constrain only what they name
    assert trigger.part(dsp=16).caps() == {"DSP": 16}


def test_get_part_resolves_and_rejects():
    assert trigger.get_part("alveo_u280") is trigger.alveo_u280
    assert trigger.get_part(None) is None
    p = trigger.part(dsp=4, name="tiny")
    assert trigger.get_part(p) is p
    with pytest.raises(KeyError, match="unknown part"):
        trigger.get_part("virtex_2000")


# -- budgets -----------------------------------------------------------------


def test_budget_caps_merge_and_margin_validation():
    b = trigger.TriggerBudget(part="zcu102", max_dsp=100)
    caps = b.resource_caps()
    assert caps["DSP"] == 100                      # explicit beats the part
    assert caps["FF"] == trigger.zcu102.caps()["FF"]
    with pytest.raises(ValueError, match="margin"):
        trigger.TriggerBudget(margin=1.0)
    with pytest.raises(KeyError, match="unknown part"):
        trigger.TriggerBudget(part="nope")         # typo fails eagerly
    # key() is a stable identity for tuning-context hashing
    assert b.key() == trigger.TriggerBudget(part="zcu102", max_dsp=100).key()
    assert b.key() != trigger.TriggerBudget(part="zcu102").key()


def test_check_design_both_sides(design):
    ok = design.check_budget(part="alveo_u280")
    assert ok.passed and ok.failures == []
    assert ok.check("DSP").used == design.schedule.resources()["DSP"]
    assert "PASS" in ok.summary()
    assert ok.raise_if_failed() is ok

    bad = design.check_budget(part=trigger.part(dsp=16))
    assert not bad.passed
    assert bad.failures == ["DSP"]                 # named offender
    assert "FAIL" in bad.summary() and "DSP" in bad.summary()
    with pytest.raises(trigger.BudgetError, match="DSP"):
        bad.raise_if_failed()
    j = bad.to_json()
    assert j["passed"] is False and j["failures"] == ["DSP"]


def test_budget_latency_ii_and_margin(design):
    lat = design.sample_latency_us
    tight = trigger.TriggerBudget(max_latency_us=lat / 2)
    rep = design.check_budget(tight)
    assert rep.failures == ["latency_us"]
    loose = trigger.TriggerBudget(max_latency_us=lat * 2, max_ii=10 ** 9)
    assert design.check_budget(loose).passed

    # margin shrinks resource caps: exactly-at-cap fails with headroom
    dsp = design.schedule.resources()["DSP"]
    at_cap = trigger.TriggerBudget(part=trigger.part(dsp=dsp))
    assert design.check_budget(at_cap).passed
    with_headroom = trigger.TriggerBudget(part=trigger.part(dsp=dsp),
                                          margin=0.1)
    assert design.check_budget(with_headroom).failures == ["DSP"]


def test_check_budget_requires_an_envelope(design):
    with pytest.raises(ValueError, match="TriggerBudget"):
        design.check_budget()


def test_report_budget_section_and_summary_latency(design):
    assert "us/sample" in design.summary()         # surfaced, not buried
    rep = design.report(part="alveo_u280")
    assert "budget check [PASS]" in rep
    rep2 = design.report(part=trigger.part(dsp=1))
    assert "FAIL" in rep2 and "DSP" in rep2


# -- the ring ----------------------------------------------------------------


def test_ring_drop_oldest_overrun():
    ring = DropOldestRing(3)
    assert [ring.push(i) for i in range(3)] == [None, None, None]
    assert ring.push(3) == 0                       # oldest evicted, returned
    assert ring.push(4) == 1
    assert ring.dropped == 2 and ring.pushed == 5
    assert ring.pop_many(10) == [2, 3, 4]          # survivors oldest-first
    assert ring.pop() is None
    with pytest.raises(ValueError, match="capacity"):
        DropOldestRing(0)


def test_ring_drops_count_in_obs():
    obs.enable()
    ring = DropOldestRing(1)
    ring.push("a")
    ring.push("b")
    assert obs.snapshot()["counters"]["trigger.dropped_frames"] == 1.0


# -- the feed ----------------------------------------------------------------


def test_feed_deterministic_and_pileup_bursts():
    mk = lambda: trigger.DetectorFeed(img=IMG, seed=5, event_rate=0.5,
                                      pileup_every=10, pileup_len=3,
                                      pileup_peaks=4)
    a, b = list(mk().frames(25)), list(mk().frames(25))
    assert all(np.array_equal(x.data, y.data) for x, y in zip(a, b))
    assert [f.n_peaks for f in a] == [f.n_peaks for f in b]
    # bursts: frames 0-2, 10-12, 20-22 carry pileup_peaks each
    for i in (0, 1, 2, 10, 11, 12, 20, 21, 22):
        assert a[i].n_peaks == 4
    # outside the bursts the event rate is Bernoulli 0/1
    assert set(f.n_peaks for f in a[3:10]) <= {0, 1}
    assert a[0].data.shape == (1, 1, IMG, IMG)
    assert a[0].data.dtype == np.float32
    # arrival schedule follows the configured rate
    assert a[2].t_sched == pytest.approx(2 / mk().frame_rate_hz)


# -- the loop ----------------------------------------------------------------


def test_loop_decisions_bit_identical_across_runs(design):
    def once():
        loop = design.trigger(backend="tensor", window=4)
        loop.calibrate(trigger.DetectorFeed(img=IMG, seed=9), 32)
        rep = loop.run(trigger.DetectorFeed(img=IMG, seed=9), 50)
        return loop.threshold, rep

    th1, r1 = once()
    th2, r2 = once()
    assert th1 == th2
    assert r1.processed == r1.frames == 50
    assert r1.dropped == 0                         # deterministic mode
    assert 0 < r1.accepts < 50                     # calibrated split
    assert [(d.frame_id, d.accept, d.score) for d in r1.decisions] == \
           [(d.frame_id, d.accept, d.score) for d in r2.decisions]
    # every frame decided exactly once, in order
    assert [d.frame_id for d in r1.decisions] == list(range(50))


def test_loop_partial_window_padding(design):
    loop = design.trigger(backend="tensor", window=8, threshold=0.0)
    rep = loop.run(trigger.DetectorFeed(img=IMG, seed=1), 10)
    assert rep.processed == 10                     # 8 + padded 2
    assert rep.windows == 2
    assert all(d.frame_id >= 0 for d in rep.decisions)


def test_loop_deadline_accounting(design):
    # an impossible deadline: every decision late, slack negative
    tight = trigger.TriggerBudget(max_latency_us=1e-3)
    rep = design.trigger(backend="tensor", window=4, budget=tight).run(
        trigger.DetectorFeed(img=IMG, seed=2), 12)
    assert rep.deadline_misses == rep.processed == 12
    assert rep.miss_pct == 100.0
    assert all(not d.deadline_met and d.slack_us < 0 for d in rep.decisions)
    assert "missed" in rep.summary()

    # a generous one: all met, slack positive
    loose = trigger.TriggerBudget(max_latency_us=60e6)
    rep2 = design.trigger(backend="tensor", window=4, budget=loose).run(
        trigger.DetectorFeed(img=IMG, seed=2), 12)
    assert rep2.deadline_misses == 0
    assert all(d.deadline_met and d.slack_us > 0 for d in rep2.decisions)


def test_loop_realtime_overrun_drops_oldest(design):
    # a predicate 10x slower than the feed with a tiny ring: the loop
    # must lose (old) frames, never stall the producer
    slow = trigger.threshold_predicate(0.5)

    def slow_predicate(out):
        time.sleep(0.02)
        return slow(out)

    loop = design.trigger(backend="tensor", window=2, capacity=4,
                          predicate=slow_predicate)
    rep = loop.run(trigger.DetectorFeed(img=IMG, frame_rate_hz=2000,
                                        seed=3), 60, realtime=True)
    assert rep.realtime
    assert rep.dropped > 0
    assert rep.processed + rep.dropped == rep.frames == 60
    assert rep.drop_pct > 0
    # survivors decided in arrival order
    ids = [d.frame_id for d in rep.decisions]
    assert ids == sorted(ids)


def test_loop_realtime_sustains_modest_rate(design):
    budget = trigger.TriggerBudget(max_latency_us=2e6)
    loop = design.trigger(backend="tensor", window=4, budget=budget)
    rep = loop.run(trigger.DetectorFeed(img=IMG, frame_rate_hz=200,
                                        seed=4), 60, realtime=True)
    assert rep.dropped == 0
    assert rep.deadline_misses == 0
    assert rep.processed == 60
    assert rep.sustained_fps > 100                 # kept pace with the feed
    assert rep.p99_us >= rep.p50_us > 0


def test_loop_window_spans_and_counters(design):
    obs.enable()
    loop = design.trigger(backend="tensor", window=4,
                          budget=trigger.TriggerBudget(max_latency_us=1e-3))
    rep = loop.run(trigger.DetectorFeed(img=IMG, seed=6), 16)
    spans = [s for s in obs.tracer.spans() if s.name == "trigger.window"]
    assert len(spans) == rep.windows == 4
    assert all(s.attrs["frames"] == 4 for s in spans)
    assert {s.attrs["window"] for s in spans} == {0, 1, 2, 3}
    counters = obs.snapshot()["counters"]
    assert counters["trigger.windows"] == 4.0
    assert counters["trigger.deadline_misses"] == 16.0
    assert counters["trigger.accepts"] + counters["trigger.rejects"] == 16.0


def test_loop_rejects_bad_window(design):
    with pytest.raises(ValueError, match="window"):
        design.trigger(window=0)


def test_calibrate_refuses_custom_predicate(design):
    loop = design.trigger(backend="tensor",
                          predicate=trigger.threshold_predicate(0.1))
    with pytest.raises(ValueError, match="custom predicate"):
        loop.calibrate(trigger.DetectorFeed(img=IMG), 8)
