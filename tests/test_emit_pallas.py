"""The Pallas emission backend vs the functional simulator.

Backend equivalence (``backend='pallas'`` vs ``emit.evaluate``, fp32 and
quantised) on a conv2d design and on bridged BraggNN(s=1); the per-group
tensor fallback path; the kernel registry's pattern table; and the
``serve``/``to_jax_fn`` backend-validation contract.
"""

import numpy as np
import pytest

import repro.hls as hls
from repro.core import emit, frontend, verify
from repro.core.emit_pallas import to_pallas_fn
from repro.core.precision import FORMATS
from repro.kernels import registry
from repro.models import braggnn

jax = pytest.importorskip("jax")


def conv_build(ctx):
    x = ctx.memref("input", (1, 3, 8, 8), "input")
    w = ctx.memref("weight", (4, 3, 3, 3), "weight")
    b = ctx.memref("bias", (4,), "weight")
    out = ctx.memref("out", (1, 4, 6, 6), "output")
    frontend.conv2d(ctx, x, w, b, out)


@pytest.fixture(scope="module")
def conv_design():
    return hls.Session().compile(conv_build, name="conv_pallas")


@pytest.fixture(scope="module")
def conv_feeds(conv_design):
    return verify.random_feeds(conv_design.graph_raw, batch=3, seed=0)


@pytest.fixture(scope="module")
def bragg_design():
    m = braggnn.build(1, img=9)
    module = m.bind(m.init_params(jax.random.PRNGKey(0)))
    return hls.compile(module)


@pytest.fixture(scope="module")
def bragg_feeds(bragg_design):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 1, 1, 9, 9)).astype(np.float32) * 0.2
    return bragg_design.feeds({"input": x})


# ---------------------------------------------------------------------------
# Generic DFG tier: conv2d design
# ---------------------------------------------------------------------------


def test_conv_dfg_matches_evaluate_fp32(conv_design, conv_feeds):
    g = conv_design.graph_opt
    ref = emit.evaluate(g, conv_feeds)
    fn = emit.to_jax_fn(g, backend="pallas")
    out = fn(conv_feeds)
    assert fn.plan.mode == "dfg"
    assert fn.plan.n_segments >= 1
    assert not fn.plan.fallbacks
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k],
                                   rtol=1e-5, atol=1e-4)


def test_conv_dfg_matches_evaluate_quantised(conv_design, conv_feeds):
    """With ``fmt`` the dfg tier re-quantises per op — the FloPoCo
    functional model, matching ``emit.evaluate`` tightly."""
    g = conv_design.graph_opt
    ref = emit.evaluate(g, conv_feeds, fmt=FORMATS["5_4"])
    fn = emit.to_jax_fn(g, backend="pallas", fmt="5_4")
    out = fn(conv_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k], atol=1e-5)


def test_conv_dfg_real_pallas_call_interpret(conv_design, conv_feeds):
    """Force real ``pl.pallas_call`` segment bodies (interpret mode on
    CPU) — the CI pallas-smoke path."""
    g = conv_design.graph_opt
    ref = emit.evaluate(g, conv_feeds)
    fn = emit.to_jax_fn(g, backend="pallas", use_pallas=True,
                        interpret=True)
    assert fn.plan.use_pallas and fn.plan.interpret
    out = fn(conv_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k],
                                   rtol=1e-5, atol=1e-4)


def test_conv_dfg_per_group_fallback(conv_design, conv_feeds):
    """Groups whose opcode is missing from the table run on the tensor
    path and are recorded in the plan — results unchanged."""
    g = conv_design.graph_opt
    table = {k: v for k, v in registry.OPCODE_KERNELS.items()
             if k != "fmac"}
    ref = emit.evaluate(g, conv_feeds)
    fn = emit.to_jax_fn(g, backend="pallas", opcode_table=table)
    out = fn(conv_feeds)
    assert fn.plan.fallbacks, "dropping fmac must force fallbacks"
    assert all("fmac" in f for f in fn.plan.fallbacks)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k],
                                   rtol=1e-5, atol=1e-4)


def test_dfg_unbatched_feeds_broadcast(conv_design):
    feeds = verify.random_feeds(conv_design.graph_raw, batch=1, seed=3)
    unbatched = {k: np.asarray(v)[0] for k, v in feeds.items()}
    ref = emit.evaluate(conv_design.graph_opt, unbatched)
    out = emit.to_jax_fn(conv_design.graph_opt, backend="pallas")(unbatched)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k],
                                   rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# BraggNN: nest-pattern tier + quantised dfg tier
# ---------------------------------------------------------------------------


def test_braggnn_nest_tier_matches_evaluate(bragg_design, bragg_feeds):
    g = bragg_design.graph_opt
    ref = emit.evaluate(g, bragg_feeds)
    fn = bragg_design.jax_fn(backend="pallas")
    assert fn.plan.mode == "nests"
    assert fn.plan.kernels, "registry kernels must serve the bridged nests"
    assert any(k.startswith("conv2d_vmem") for k in fn.plan.kernels)
    assert any(k.startswith("smallfloat_matmul") for k in fn.plan.kernels)
    assert any(k.startswith("fused_softmax") for k in fn.plan.kernels)
    out = fn(bragg_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k],
                                   rtol=1e-4, atol=1e-5)


def test_braggnn_dfg_tier_quantised_matches_evaluate(bragg_design,
                                                     bragg_feeds):
    g = bragg_design.graph_opt
    ref = emit.evaluate(g, bragg_feeds, fmt=FORMATS["5_4"])
    fn = bragg_design.jax_fn(backend="pallas", mode="dfg", fmt="5_4")
    assert fn.plan.mode == "dfg"
    out = fn(bragg_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k], atol=1e-5)


def test_braggnn_scatter_gather_fusion_happens(bragg_design):
    fn = bragg_design.jax_fn(backend="pallas", mode="dfg")
    assert fn.plan.fused_scatters > 0, \
        "aligned scatter->gather pairs must be forwarded in-register"


def test_nest_tier_rejects_per_sample_weights(bragg_design):
    feeds = verify.random_feeds(bragg_design.graph_raw, batch=2, seed=1)
    fn = bragg_design.jax_fn(backend="pallas")
    with pytest.raises(ValueError, match="varies across the batch"):
        fn(feeds)


def test_nest_tier_flash_attention_mode(bragg_design, bragg_feeds):
    """The flash-attention NLB throughput mode: a true-exp softmax, so an
    approximation of the Taylor functional model — recorded as a note."""
    g = bragg_design.graph_opt
    ref = emit.evaluate(g, bragg_feeds)
    fn = bragg_design.jax_fn(backend="pallas", nlb_flash=True)
    assert "flash_attention" in fn.plan.kernels
    assert any("flash" in n for n in fn.plan.notes)
    out = fn(bragg_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), ref[k], atol=5e-2)


# ---------------------------------------------------------------------------
# Serving + validation contract
# ---------------------------------------------------------------------------


def test_serve_pallas_backend_and_report(conv_design, conv_feeds):
    rep = conv_design.serve([conv_feeds, conv_feeds], backend="pallas",
                            collect=True)
    assert rep.backend == "pallas"
    assert rep.served and rep.served.startswith("pallas[dfg]")
    assert rep.batches == 2 and rep.samples == 6
    ref = emit.evaluate(conv_design.graph_opt, conv_feeds)
    for k in ref:
        np.testing.assert_allclose(np.asarray(rep.outputs[0][k]), ref[k],
                                   rtol=1e-5, atol=1e-4)


def test_serve_rejects_unknown_backend(conv_design, conv_feeds):
    with pytest.raises(ValueError, match="'tensor', 'simd' or 'pallas'"):
        conv_design.serve([conv_feeds], backend="veryl")


def test_to_jax_fn_rejects_unknown_backend(conv_design):
    with pytest.raises(ValueError, match="simd, pallas"):
        emit.to_jax_fn(conv_design.graph_opt, backend="veryl")
    with pytest.raises(ValueError, match="simd, pallas"):
        conv_design.jax_fn(backend="veryl")
    with pytest.raises(TypeError, match="simd"):
        emit.to_jax_fn(conv_design.graph_opt, fmt="5_4")


def test_to_pallas_fn_rejects_unknown_mode(conv_design):
    with pytest.raises(ValueError, match="nests, dfg"):
        to_pallas_fn(conv_design.graph_opt, mode="turbo")
    with pytest.raises(ValueError, match="ModuleGraph"):
        to_pallas_fn(conv_design.graph_opt, mode="nests")


# ---------------------------------------------------------------------------
# Kernel registry
# ---------------------------------------------------------------------------


def test_registry_has_all_four_exemplars():
    assert registry.names() == ["conv2d_vmem", "flash_attention",
                                "fused_softmax", "smallfloat_matmul"]
    for name in registry.names():
        e = registry.get(name)
        assert callable(e.fn) and callable(e.kernel) and callable(e.oracle)
        assert e.accelerates


@pytest.mark.parametrize("pattern,name", [
    ("Conv2d", "conv2d_vmem"),
    ("Linear", "smallfloat_matmul"),
    ("Softmax", "fused_softmax"),
    ("nlb.soft", "fused_softmax"),
    ("NonLocalBlock.attention", "flash_attention"),
])
def test_registry_pattern_table(pattern, name):
    assert registry.for_pattern(pattern).name == name


def test_registry_rejects_duplicates_and_unknown():
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.get("conv2d_vmem"))
    with pytest.raises(KeyError, match="no kernel"):
        registry.get("nope")
    assert registry.for_pattern("Transformer") is None


def test_registry_conv2d_entry_roundtrip():
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.fold_in(key, 0), (2, 3, 9, 9))
    w = jax.random.normal(jax.random.fold_in(key, 1), (4, 3, 3, 3))
    e = registry.get("conv2d_vmem")
    got = e.fn(x, w, None, use_pallas=True, interpret=True)
    want = e.oracle(x, w, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_registry_matmul_entry_fp32_identity_mode():
    """``exp_bits=None`` (the nest tier's fp32 path) must be a plain
    matmul with no quantisation, through both wrapper routes."""
    key = jax.random.key(1)
    x = jax.random.normal(jax.random.fold_in(key, 0), (8, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    e = registry.get("smallfloat_matmul")
    want = np.asarray(x) @ np.asarray(w)
    got_o = e.fn(x, w, exp_bits=None, man_bits=None)
    got_p = e.fn(x, w, exp_bits=None, man_bits=None, use_pallas=True,
                 interpret=True)
    np.testing.assert_allclose(np.asarray(got_o), want, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p), want, rtol=1e-4,
                               atol=1e-4)


def test_registry_softmax_entry_taylor_mode():
    key = jax.random.key(2)
    x = jax.random.normal(key, (16, 16)) * 0.3
    e = registry.get("fused_softmax")
    got = e.fn(x, taylor_order=8, use_pallas=True, interpret=True)
    want = e.fn(x, taylor_order=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, atol=1e-4)


def test_registry_flash_attention_entry_roundtrip():
    key = jax.random.key(3)
    q = jax.random.normal(jax.random.fold_in(key, 0), (1, 16, 1, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 1, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, 1, 8))
    e = registry.get("flash_attention")
    got = e.fn(q, k, v, causal=False, use_pallas=True, interpret=True)
    want = e.fn(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
