"""CompilerDriver / PassManager / design-cache behaviour (the Fig. 1 flow
as one orchestrated entrypoint).

Covers: pass registration + unknown-pass error, fixpoint termination,
PassReport op-count deltas, cache hit/miss semantics (including the on-disk
layer), and bit-for-bit equivalence of ``CompilerDriver.compile()`` with
the historical hand-stitched optimize + list_schedule + emit flow on
BraggNN(s=1).
"""

import numpy as np
import pytest

from repro.core import (CompilerConfig, CompilerDriver, Context, PassManager,
                        emit, frontend, passes, pipeline, verify)
from repro.core.schedule import list_schedule


def _small_build(ctx):
    x = ctx.memref("input", (1, 1, 6, 6), "input")
    w = ctx.memref("w", (2, 1, 3, 3), "weight")
    b = ctx.memref("b", (2,), "weight")
    out = ctx.memref("out", (1, 2, 4, 4), "output")
    frontend.conv2d(ctx, x, w, b, out)


def _trace(build):
    ctx = Context()
    build(ctx)
    return ctx.finalize()


# -- registry ----------------------------------------------------------------


def test_builtin_passes_registered():
    assert set(passes.DEFAULT_PIPELINE) <= set(pipeline.PASS_REGISTRY)


def test_unknown_pass_raises():
    with pytest.raises(ValueError, match="unknown pass"):
        PassManager(("cse", "not_a_pass"))


def test_register_pass_decorator_and_duplicate_rejected():
    @pipeline.register_pass("identity_test_pass")
    def identity(g):
        return g

    try:
        assert "identity_test_pass" in pipeline.PASS_REGISTRY
        g, reports = PassManager(("identity_test_pass",), max_rounds=2).run(
            _trace(_small_build))
        assert reports[0].ops_delta == 0
        with pytest.raises(ValueError, match="already registered"):
            pipeline.register_pass("identity_test_pass")(identity)
    finally:
        del pipeline.PASS_REGISTRY["identity_test_pass"]


# -- PassManager -------------------------------------------------------------


def test_fixpoint_terminates_in_one_extra_round():
    """Once a full round leaves the op count unchanged, the loop stops."""
    g = _trace(_small_build)
    pm = PassManager(max_rounds=10)
    g_opt, reports = pm.run(g)
    rounds = {r.round for r in reports}
    # the pipeline must converge well before the round cap
    assert max(rounds) < 9
    # re-running the converged graph is a no-op round
    g_again, reports2 = PassManager(max_rounds=10).run(g_opt)
    assert len(g_again.ops) == len(g_opt.ops)
    assert {r.round for r in reports2} == {0}


def test_pass_reports_deltas_and_histograms():
    g = _trace(_small_build)
    g_opt, reports = PassManager().run(g)
    assert reports, "at least one pass application"
    for rep in reports:
        assert rep.ops_after - rep.ops_before == rep.ops_delta
        assert sum(rep.hist_before.values()) == rep.ops_before
        assert sum(rep.hist_after.values()) == rep.ops_after
        # hist_delta only reports opcodes whose count changed
        for k, v in rep.hist_delta().items():
            assert v != 0
            assert rep.hist_after.get(k, 0) - rep.hist_before.get(k, 0) == v
    # the pipeline as a whole must shrink this conv (cse/dce fire)
    assert len(g_opt.ops) < len(g.ops)


def test_topo_check_and_spot_verify_hooks():
    g = _trace(_small_build)
    pm = PassManager(topo_check=True, spot_verify=True)
    g_opt, reports = pm.run(g)
    executed = [r for r in reports if not r.skipped]
    assert executed, "at least one executed pass application"
    for rep in executed:
        assert rep.topo_ok is True
        assert rep.spot_err is not None
        # reassociation may change rounding, but only slightly
        assert rep.spot_err < 1e-3
    for rep in reports:
        if rep.skipped:
            # a skipped application is a proven no-op: no wall time, no
            # graph change, hooks not re-run
            assert rep.wall_s == 0.0
            assert rep.ops_delta == 0
            assert rep.hist_before == rep.hist_after


# -- cache -------------------------------------------------------------------


def test_cache_hit_on_identical_content_miss_on_config_change(tmp_path):
    driver = CompilerDriver(cache_dir=tmp_path)
    d1 = driver.compile(_small_build, name="a")
    assert (driver.cache.hits, driver.cache.misses) == (0, 1)
    d2 = driver.compile(_small_build, name="b")
    assert (driver.cache.hits, driver.cache.misses) == (1, 1)
    # served from memory: relabeled for this caller, artifacts shared
    assert d2.name == "b"
    assert d2.graph_opt is d1.graph_opt
    assert d2.schedule is d1.schedule

    # changed pipeline config -> different hash -> miss
    cfg = CompilerConfig(pipeline=("cse", "dce"))
    d3 = driver.compile(_small_build, name="c", config=cfg)
    assert driver.cache.misses == 2
    assert d3.design_hash != d1.design_hash

    # fresh driver sharing the disk cache: hit without recompiling
    driver2 = CompilerDriver(cache_dir=tmp_path)
    d4 = driver2.compile(_small_build, name="d")
    assert (driver2.cache.hits, driver2.cache.misses) == (1, 0)
    assert d4.design_hash == d1.design_hash
    assert d4.makespan == d1.makespan
    # the jax fn was dropped at pickle time and re-emits on demand
    feeds = verify.random_feeds(d4.graph_raw, batch=2, seed=3)
    np.testing.assert_allclose(
        np.asarray(d4.jax_fn()(feeds)["out"]),
        np.asarray(d1.jax_fn()(feeds)["out"]), rtol=1e-5, atol=1e-6)


def test_graph_fingerprint_stable_across_retrace():
    g1, g2 = _trace(_small_build), _trace(_small_build)
    assert pipeline.graph_fingerprint(g1) == pipeline.graph_fingerprint(g2)


def test_cache_distinguishes_different_programs():
    def other_build(ctx):
        x = ctx.memref("input", (1, 1, 6, 6), "input")
        out = ctx.memref("out", (1, 1, 2, 2), "output")
        frontend.max_pool_2d(ctx, x, out, k=3, stride=2)

    driver = CompilerDriver()
    d1 = driver.compile(_small_build)
    d2 = driver.compile(other_build)
    assert d1.design_hash != d2.design_hash
    assert driver.cache.misses == 2


# -- equivalence with the hand-stitched flow ---------------------------------


def test_compile_equals_hand_stitched_flow_on_braggnn():
    """Driver output matches optimize + list_schedule + emit bit-for-bit."""
    build = lambda ctx: frontend.braggnn(ctx, s=1)

    # hand-stitched (the historical consumer-side recipe)
    ctx = Context(forward=True)
    build(ctx)
    g_raw = ctx.finalize()
    g_opt = passes.optimize(g_raw)
    sched = list_schedule(g_opt)

    driver = CompilerDriver()
    design = driver.compile(build, name="braggnn_s1")

    assert len(design.graph_raw.ops) == len(g_raw.ops)
    assert len(design.graph_opt.ops) == len(g_opt.ops)
    assert [(o.opcode, o.args, o.result) for o in design.graph_opt.ops] == \
           [(o.opcode, o.args, o.result) for o in g_opt.ops]
    assert design.makespan == sched.makespan
    assert design.schedule.start == sched.start
    assert design.schedule.resource_units == sched.resource_units

    # identical numerics: functional sim and emitted SIMD design
    feeds = verify.random_feeds(g_raw, batch=4, seed=0, scale=0.4)
    out_hand = emit.evaluate(g_opt, feeds)
    out_drv = design.evaluate(feeds)
    for k in out_hand:
        np.testing.assert_array_equal(out_hand[k], out_drv[k])
    err_hand = max(float(np.max(np.abs(
        emit.evaluate(g_raw, feeds)[k] - out_hand[k]))) for k in out_hand)
    err_drv = max(float(np.max(np.abs(
        design.evaluate(feeds, raw=True)[k] - out_drv[k])))
        for k in out_drv)
    assert err_hand == err_drv

    # second compile of the same config is served from cache
    before_hits = driver.cache.hits
    again = driver.compile(build, name="braggnn_s1")
    assert driver.cache.hits == before_hits + 1
    assert again is design


def test_run_testbench_accepts_compiled_design():
    driver = CompilerDriver()
    design = driver.compile(_small_build, name="conv_tb")
    rep = verify.run_testbench("conv_tb", design=design)
    assert rep.passed
    assert rep.makespan == design.makespan
    # and the build-callable path still works and agrees
    rep2 = verify.run_testbench("conv_tb", _small_build)
    assert rep2.passed
    assert rep2.makespan == rep.makespan


def test_session_stats_accounting():
    """hls.Session.stats() surfaces the DesignCache hit/miss counters and
    the driver's recompile count with exact bookkeeping."""
    import repro.hls as hls
    s = hls.Session()
    st0 = s.stats()
    assert st0 == {"hits": 0, "misses": 0, "recompiles": 0,
                   "memory_entries": 0, "pass_memo_entries": 0,
                   "pass_memo_hits": 0}

    s.compile(_small_build, name="acct")          # cold: one miss
    st1 = s.stats()
    assert st1["misses"] == 1 and st1["hits"] == 0
    assert st1["recompiles"] == 1
    assert st1["memory_entries"] == 1

    s.compile(_small_build, name="acct")          # warm: one hit, no compile
    st2 = s.stats()
    assert st2["hits"] == 1 and st2["misses"] == 1
    assert st2["recompiles"] == 1                 # unchanged
    assert st2["memory_entries"] == 1

    # a config change is a genuine recompile, not a cache hit
    s.compile(_small_build, name="acct",
              config=CompilerConfig(pipeline=("cse", "dce")))
    st3 = s.stats()
    assert st3["misses"] == 2 and st3["recompiles"] == 2
    assert st3["memory_entries"] == 2
