"""Binding rules + sharding resolution (pure logic, no devices needed) and
a subprocess dry-run on a small placeholder mesh."""

import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.core.binding import BindingRules


class _FakeMesh:
    """Duck-typed mesh: BindingRules only reads ``.shape``."""

    def __init__(self, shape: dict):
        self.shape = shape


MESH = _FakeMesh({"data": 16, "model": 16})
MESH3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_default_rules_bind_ki_axes():
    r = BindingRules()
    assert r.spec(("batch", None), MESH3) == P(("pod", "data"), None)
    assert r.spec(("embed", "mlp"), MESH) == P(None, "model")
    assert r.spec(("experts", "embed", "expert_mlp"), MESH) == \
        P("model", None, None)
    assert r.spec(("vocab", "embed"), MESH) == P("model", None)


def test_duplicate_mesh_axes_deduped():
    r = BindingRules().with_overrides(embed="model")
    # both dims want 'model': only the first gets it
    assert r.spec(("embed", "mlp"), MESH) == P("model", None)


def test_K_replication_factor():
    r = BindingRules()
    assert r.K(("batch",), MESH3) == 32
    assert r.K(("heads", None), MESH) == 16
    assert r.K((None, None), MESH) == 1


def test_overrides_shadow_defaults():
    r = BindingRules().with_overrides(heads=None, head_dim="model")
    assert r.spec(("embed", "heads", "head_dim"), MESH) == \
        P(None, None, "model")


def test_prune_spec_divisibility():
    from repro.launch.shardings import prune_spec
    import jax
    if jax.device_count() < 1:
        pytest.skip("needs a device")
    from repro.launch.mesh import single_device_mesh
    mesh = single_device_mesh()
    # sizes divide trivially on a 1x1 mesh
    assert prune_spec((4, 4), P("data", "model"), mesh) == P("data", "model")


def test_prune_drops_nondividing_axes():
    from repro.launch.shardings import prune_spec

    class M:
        shape = {"data": 16, "model": 16}

    # batch=1 can't shard 16 ways -> dropped; 60 not divisible -> dropped
    assert prune_spec((1, 128), P("data", None), M) == P(None, None)
    assert prune_spec((60, 64), P("model", None), M) == P(None, None)
    assert prune_spec((64, 64), P("model", None), M) == P("model", None)
    # multi-axis entries pruned partially: ('pod','data') on 32 -> kept,
    # on 2 -> only pod kept
    class M3:
        shape = {"pod": 2, "data": 16, "model": 16}
    assert prune_spec((32,), P(("pod", "data")), M3) == P(("pod", "data"))
    assert prune_spec((2,), P(("pod", "data")), M3) == P("pod")


DRYRUN_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import AxisType
from repro.configs import registry
from repro.launch import dryrun as dr

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)
cfg = registry.get_tiny("gemma2-27b").replace(microbatches=2)
with jax.set_mesh(mesh):   # build_cell traces eval_shape -> needs a context
    step, args, in_sh, out_sh, donate = dr.build_cell(
        "gemma2-27b", "train_4k", mesh, cfg=cfg)
# shrink the workload to the tiny config scale
import jax.numpy as jnp
inputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
          "targets": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
from repro.launch import shardings as sh
rules = sh.rules_for(cfg)
input_sh = {k: sh.sharding_for(tuple(v.shape), ("batch", None), mesh, rules)
            for k, v in inputs.items()}
args = (args[0], args[1], inputs)
in_sh = (in_sh[0], in_sh[1], input_sh)
from repro.launch.steps import make_train_step
micro_sh = {k: sh.sharding_for((2, 4) + tuple(v.shape[1:]),
                               (None, "batch", None), mesh, rules)
            for k, v in inputs.items()}
step = make_train_step(cfg, microbatch_shardings=micro_sh)
import jax
with jax.set_mesh(mesh):   # P-based activation constraints need a context
    out_abs = jax.eval_shape(step, *args)
    metrics_sh = jax.tree_util.tree_map(lambda _: sh.replicated(mesh),
                                        out_abs[2])
    compiled = jax.jit(step, in_shardings=(in_sh[0], in_sh[1], input_sh),
                       out_shardings=(in_sh[0], in_sh[1], metrics_sh),
                       donate_argnums=(0, 1)).lower(*args).compile()
print("COMPILED", compiled.memory_analysis().temp_size_in_bytes)
"""


def test_small_mesh_dryrun_subprocess():
    """Lower+compile a tiny heterogeneous (local/global, post-norm) arch on
    a 2x2x2 placeholder mesh in a fresh process (8 fake devices)."""
    import jax
    if not hasattr(jax, "set_mesh"):
        pytest.skip("dryrun path needs jax.set_mesh (jax >= 0.6)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COMPILED" in out.stdout
