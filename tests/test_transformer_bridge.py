"""The sequence-model bridge vocabulary (Attention / MLP / RMSNorm).

Acceptance for the million-op compile path: the transformer encoder block
lowers through ``hls.compile`` exactly like the hand-written
``frontend.transformer_encoder_block`` (same ``graph_fingerprint``), the
compiled design matches the tensor twin (fp32 tight — the twin mirrors the
DFG's Taylor-exp softmax — and quantised loose), and the registry fast
paths resolve for the new node patterns.

A reduced geometry (seq=4, d_model=8) keeps CI fast; the full
whisper_tiny-shaped block is exercised by the transformer-smoke CI job and
``benchmarks/bench_compile_scaling.py``.
"""

import functools

import jax
import numpy as np
import pytest

import repro.hls as hls
from repro.core import emit, frontend
from repro.core.pipeline import graph_fingerprint
from repro.core.precision import FORMATS
from repro.kernels import registry as kreg
from repro.models import transformer
from repro.nn import graph as nng
from repro.nn.module import init_tree

SEQ, D, H, F = 4, 8, 2, 16


@pytest.fixture(scope="module")
def session():
    return hls.Session()


@pytest.fixture(scope="module")
def params():
    return init_tree(transformer.specs(SEQ, D, H, F), jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def design(session, params):
    model = transformer.build(SEQ, D, H, F, params=params)
    return session.compile(model, name="toy_encoder_block")


def _hand_build(ctx):
    frontend.transformer_encoder_block(ctx, seq=SEQ, d_model=D, n_heads=H,
                                       ffn=F)


def test_fingerprint_equals_handwritten(design):
    g_hand = hls.trace(_hand_build)
    assert design.fingerprint == graph_fingerprint(g_hand)


def test_design_hash_equals_handwritten(design, session):
    hits = session.stats()["hits"]
    d_hand = session.compile(_hand_build, name="toy_encoder_hand")
    assert d_hand.design_hash == design.design_hash
    assert session.stats()["hits"] == hits + 1


def test_vocabulary_registered():
    assert {nng.RMSNorm, nng.Attention, nng.MLP} <= set(nng.NODE_TYPES)
    model = transformer.build(SEQ, D, H, F)
    specs = model.specs()
    assert set(specs) == {"attn", "mlp", "ln_post"}
    assert specs["attn"]["q"]["kernel"].shape == (D, H, D // H)
    assert specs["attn"]["o"]["kernel"].shape == (H, D // H, D)
    assert specs["mlp"]["fc1"]["w"].shape == (F, D)
    assert specs["ln_post"]["gamma"].shape == (D,)


def test_run_matches_tensor_twin_fp32(design, params):
    """The twin mirrors the DFG's functional model (Taylor-exp softmax,
    sum*(1/D) rms), so fp32 agreement is to rounding, not approximation."""
    x = np.random.default_rng(1).normal(0, 0.5, (2, SEQ, D)) \
        .astype(np.float32)
    got = np.asarray(design.run(x)["ln_post_out"])
    want = np.asarray(transformer.forward(params, x, n_heads=H))
    assert got.shape == (2, SEQ, D)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_quantised_evaluate_matches_tensor_twin(design, params):
    """(wE,wF)-quantised DFG vs the fmt-quantised twin: the twin quantises
    per layer, the DFG per op — BraggNN-style loose tolerances."""
    model = transformer.build(SEQ, D, H, F)
    x = np.random.default_rng(2).normal(0, 0.5, (1, SEQ, D)) \
        .astype(np.float32)
    feeds = {**model.weight_feeds(params), "input": x}
    got = np.asarray(emit.evaluate(design.compiled.graph_opt, feeds,
                                   fmt=FORMATS["5_11"])["ln_post_out"])
    want = np.asarray(transformer.forward(params, x, n_heads=H, fmt="5_11"))
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-3)


def test_pallas_nest_tier_taylor_and_flash(design, params):
    model = transformer.build(SEQ, D, H, F)
    x = np.random.default_rng(3).normal(0, 0.5, (2, SEQ, D)) \
        .astype(np.float32)
    feeds = {**model.weight_feeds(params), "input": x}
    want = np.asarray(transformer.forward(params, x, n_heads=H))

    fn = design.jax_fn(backend="pallas")
    assert fn.plan.mode == "nests"
    assert fn.plan.kernels.get("smallfloat_matmul", 0) >= 2
    assert fn.plan.kernels.get("fused_softmax", 0) == 1
    got = np.asarray(fn(feeds)["ln_post_out"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    # flash throughput mode: true-exp softmax, so only approximately equal
    fnf = design.jax_fn(backend="pallas", nlb_flash=True)
    assert fnf.plan.kernels.get("flash_attention", 0) == 1
    gotf = np.asarray(fnf(feeds)["ln_post_out"])
    np.testing.assert_allclose(gotf, want, rtol=5e-2, atol=5e-3)


def test_registry_patterns_resolve():
    assert kreg.for_pattern("Attention").name == "flash_attention"
    assert kreg.for_pattern("Attention.soft").name == "fused_softmax"
    assert kreg.for_pattern("Attention.proj").name == "smallfloat_matmul"
    assert kreg.for_pattern("MLP").name == "smallfloat_matmul"


def test_no_residual_no_norm_variants_lower():
    """The sub-block flags change the emitted structure, not just params."""
    nodes = [nng.Attention("attn", d_model=D, n_heads=H, pre_norm=False,
                           residual=False),
             nng.RMSNorm("ln_post", dim=D)]
    model = nng.ModuleGraph("bare_attn", (SEQ, D), nodes)
    g = hls.trace(model)
    full = hls.trace(transformer.build(SEQ, D, H, F))
    assert 0 < len(g.ops) < len(full.ops)
    assert "attn.norm.gamma" not in g.inputs
