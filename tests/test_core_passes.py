"""Pass correctness: each rewrite preserves semantics (behavioural check)
and achieves its structural goal.  Includes hypothesis property tests over
randomly generated scalar programs.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Context, emit, frontend, passes, verify


def _random_program(draw_ops, n_inputs=4):
    """Build a graph from a draw of op descriptors."""
    ctx = Context()
    mem = ctx.memref("x", (n_inputs,), "input")
    vals = [mem[i] for i in range(n_inputs)]
    for kind, a, b in draw_ops:
        va, vb = vals[a % len(vals)], vals[b % len(vals)]
        if kind == 0:
            vals.append(va + vb)
        elif kind == 1:
            vals.append(va * vb)
        elif kind == 2:
            vals.append(va - vb)
        elif kind == 3:
            vals.append(va.max(vb))
        elif kind == 4:
            vals.append(ctx.relu(va))
        else:
            vals.append(va * va + vb)
    out = ctx.memref("out", (min(4, len(vals)),), "output")
    for i in range(out.shape[0]):
        out[i] = vals[-(i + 1)]
    return ctx.finalize()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 30),
                          st.integers(0, 30)), min_size=1, max_size=60))
def test_optimize_preserves_semantics(ops):
    """Property: the full pass pipeline never changes program behaviour
    (the paper's trade: no formal proofs, behavioural verification)."""
    g = _random_program(ops)
    g_opt = passes.optimize(g)
    feeds = verify.random_feeds(g, batch=3, seed=7)
    out_a = emit.evaluate(g, feeds)
    out_b = emit.evaluate(g_opt, feeds)
    for k in out_a:
        np.testing.assert_allclose(out_a[k], out_b[k], rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 30),
                          st.integers(0, 30)), min_size=1, max_size=40))
def test_passes_idempotent(ops):
    g = passes.optimize(_random_program(ops))
    g2 = passes.optimize(g)
    assert len(g2.ops) == len(g.ops)


def test_relu_recompose():
    ctx = Context()
    x = ctx.memref("x", (4,), "input")
    out = ctx.memref("out", (4,), "output")
    frontend.relu_layer(ctx, x, out)
    g = ctx.finalize()
    assert any(op.opcode == "select" for op in g.ops)
    g2 = passes.relu_recompose(g)
    assert sum(1 for op in g2.ops if op.opcode == "relu") == 4
    assert not any(op.opcode in ("select", "cmpugt") for op in g2.ops)


def test_reduction_tree_reduces_depth():
    ctx = Context()
    x = ctx.memref("x", (64,), "input")
    out = ctx.memref("out", (1,), "output")
    with ctx.sequential("sum"):
        acc = x[0]
        for i in range(1, 64):
            acc = acc + x[i]
        out[0] = acc
    g = ctx.finalize()

    def depth(graph):
        d = {}
        best = 0
        for op in graph.ops:
            cur = 1 + max((d.get(a, 0) for a in op.args), default=0)
            if op.result >= 0:
                d[op.result] = cur
            best = max(best, cur)
        return best

    g2 = passes.reduction_tree(g)
    assert depth(g) == 63
    assert depth(g2) == 6          # ceil(log2(64))
    feeds = verify.random_feeds(g, batch=2, seed=0)
    np.testing.assert_allclose(emit.evaluate(g, feeds)["out"],
                               emit.evaluate(g2, feeds)["out"], rtol=1e-4)


def test_fmac_coalesce():
    ctx = Context()
    x = ctx.memref("x", (2,), "input")
    out = ctx.memref("out", (1,), "output")
    with ctx.sequential("mac"):
        out[0] = x[0] * x[1] + x[0]
    g = passes.fmac_coalesce(ctx.finalize())
    assert sum(1 for op in g.ops if op.opcode == "fmac") == 1
    assert not any(op.opcode == "mulf" for op in g.ops)


def test_cse_merges_duplicates():
    ctx = Context()
    x = ctx.memref("x", (2,), "input")
    out = ctx.memref("out", (2,), "output")
    with ctx.sequential("dup"):
        a = x[0] * x[1]
        b = x[1] * x[0]       # commutative duplicate
        out[0] = a + ctx.const(1.0)
        out[1] = b + ctx.const(1.0)
    g = ctx.finalize()
    g2 = passes.cse(g)
    assert sum(1 for op in g2.ops if op.opcode == "mulf") == 1


def test_hoist_globals_checked():
    ctx = Context()
    w = ctx.memref("w", (2,), "weight")
    x = ctx.memref("x", (2,), "input")
    out = ctx.memref("out", (2,), "output")
    for (i,) in ctx.parallel(2):
        out[i] = w[i] * x[i]
    g = ctx.finalize()
    passes.hoist_globals_check(g)     # does not raise
    assert "w" in g.weight_names and "w" in g.inputs
