"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs — the brief's
requirement (f).  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import braggnn, encdec, lm
from repro.nn import module, transformer
from repro.optim import adamw

ARCHS = list(registry.ARCH_IDS)


def _batch_for(cfg, B=2, S=16):
    key = jax.random.key(0)
    if getattr(cfg, "is_encoder_decoder", False):
        return {
            "frames": jax.random.normal(
                key, (B, cfg.encoder_len, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    out = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.n_patches:
        out["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = registry.get_tiny(arch)
    is_encdec = getattr(cfg, "is_encoder_decoder", False)
    specs = (encdec.model_specs(cfg) if is_encdec
             else transformer.model_specs(cfg))
    params = module.init_tree(specs, jax.random.key(0))
    batch = _batch_for(cfg)

    # forward
    if is_encdec:
        enc = encdec.encode(cfg, params, batch["frames"])
        logits = encdec.decode_forward(cfg, params, batch["tokens"], enc)
        assert logits.shape == (2, 16, cfg.vocab_size)
    else:
        logits, _ = transformer.forward(cfg, params, batch["tokens"],
                                        patches=batch.get("patches"))
        want_s = 16 + (cfg.n_patches or 0)
        assert logits.shape == (2, want_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one train step
    opt = adamw.init_state(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(total_steps=10)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # parameters actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = registry.get_tiny(arch)
    is_encdec = getattr(cfg, "is_encoder_decoder", False)
    specs = (encdec.model_specs(cfg) if is_encdec
             else transformer.model_specs(cfg))
    params = module.init_tree(specs, jax.random.key(0))
    step = jax.jit(make_serve_step(cfg))
    B = 2
    if is_encdec:
        enc = encdec.encode(
            cfg, params,
            jax.random.normal(jax.random.key(1),
                              (B, cfg.encoder_len, cfg.d_model)))
        cache = encdec.init_cache(cfg, B, 32, enc)
    else:
        cache = transformer.init_cache(cfg, B, 32)
    toks = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    nxt, cache = step(params, cache,
                      {"tokens": toks, "pos": jnp.zeros((B,), jnp.int32)})
    assert nxt.shape == (B,)
    assert bool(jnp.all((nxt >= 0) & (nxt < cfg.vocab_size)))


def test_braggnn_smoke():
    cfg = registry.get_tiny("braggnn")
    sp = braggnn.specs(cfg.scale, cfg.img)
    params = module.init_tree(sp, jax.random.key(0))
    x, y = braggnn.synthetic_peaks(jax.random.key(1), 8, img=cfg.img)
    out = braggnn.forward(params, x, s=cfg.scale)
    assert out.shape == (8, 2)
    assert bool(jnp.all(jnp.isfinite(out)))
    outq = braggnn.forward(params, x, s=cfg.scale, fmt=cfg.quant_format)
    assert bool(jnp.all(jnp.isfinite(outq)))


def test_model_flops_per_token_moe_counts_active_only():
    dense = registry.get_config("qwen2-7b")
    moe = registry.get_config("mixtral-8x7b")
    f_moe = lm.model_flops_per_token(moe)
    # mixtral active ~13B of 47B total
    from repro.nn import transformer as tf
    total = module.param_count(tf.model_specs(moe))
    assert f_moe < 6 * total * 0.5
    f_dense = lm.model_flops_per_token(dense)
    total_d = module.param_count(tf.model_specs(dense))
    assert abs(f_dense - 6 * total_d) / (6 * total_d) < 1e-6
