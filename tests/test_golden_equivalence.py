"""Golden equivalence: the vectorised struct-of-arrays compiler hot path
must be *bit-identical* to the historical per-``Op`` object-graph path.

Every pass, the full fixpoint pipeline, the scheduler (all binding modes,
including memory ports in no-forwarding mode), the functional simulator and
the ``CompiledDesign`` artifact are run through both implementations on
BraggNN(s=1) and the conv2d workload, comparing op streams, value-id
spaces, schedules and design content hashes exactly.

The legacy path is reachable two ways, both covered here:
  * calling ``repro.core.legacy`` directly;
  * setting ``REPRO_LEGACY_IR=1``, which reroutes ``passes.*``,
    ``schedule.list_schedule`` and ``emit.evaluate`` at call time.
"""

import numpy as np
import pytest

from repro.core import (CompilerDriver, Context, emit, frontend, legacy,
                        passes, pipeline, verify)
from repro.core.precision import FP_5_4
from repro.core.schedule import list_schedule

PASS_NAMES = ("cse", "dce", "relu_recompose", "reduction_tree",
              "fmac_coalesce")


def _braggnn_build(ctx):
    frontend.braggnn(ctx, s=1, img=7)


def _conv2d_build(ctx):
    x = ctx.memref("input", (1, 2, 8, 8), "input")
    w = ctx.memref("w", (3, 2, 3, 3), "weight")
    b = ctx.memref("b", (3,), "weight")
    out = ctx.memref("out", (1, 3, 6, 6), "output")
    frontend.conv2d(ctx, x, w, b, out)


def _transformer_build(ctx):
    frontend.transformer_encoder_block(ctx, seq=4, d_model=8, n_heads=2,
                                       ffn=16)


def _trace(build, forward=True):
    ctx = Context(forward=forward)
    build(ctx)
    return ctx.finalize()


def _stream(g):
    """The exact op stream: opcode, operands, result, nest, rank, array."""
    return [(o.opcode, o.args, o.result, o.nest, o.rank, o.array)
            for o in g.ops]


def _graphs_identical(a, b):
    assert a.n_values == b.n_values
    assert _stream(a) == _stream(b)
    assert a.outputs == b.outputs
    assert a.inputs == b.inputs
    assert pipeline.graph_fingerprint(a) == pipeline.graph_fingerprint(b)


def _schedules_identical(a, b):
    assert a.start == b.start
    assert a.makespan == b.makespan
    assert a.resource_units == b.resource_units
    assert a.nest_spans == b.nest_spans
    assert a.peak_live == b.peak_live


@pytest.fixture(scope="module",
                params=["braggnn", "conv2d", "transformer"])
def workload(request):
    build = {"braggnn": _braggnn_build, "conv2d": _conv2d_build,
             "transformer": _transformer_build}[request.param]
    return request.param, _trace(build)


def test_each_pass_bit_identical(workload):
    _, g = workload
    for name in PASS_NAMES:
        g_new = getattr(passes, name)(g)
        g_old = getattr(legacy, name)(g)
        _graphs_identical(g_new, g_old)


def test_pipeline_fixpoint_bit_identical(workload, monkeypatch):
    _, g = workload
    g_new = passes.optimize(g)
    monkeypatch.setenv("REPRO_LEGACY_IR", "1")
    g_old = passes.optimize(g)
    monkeypatch.delenv("REPRO_LEGACY_IR")
    _graphs_identical(g_new, g_old)


def test_schedule_bit_identical(workload):
    _, g = workload
    g_opt = passes.optimize(g)
    for kwargs in ({}, {"binding": "rank"}, {"unroll_factor": 4},
                   {"alap_compact": False}, {"pipelined_units": True}):
        _schedules_identical(list_schedule(g_opt, **kwargs),
                             legacy.list_schedule(g_opt, **kwargs))


def test_schedule_ports_bit_identical():
    """No-forwarding mode: surviving load/store ops bind to per-array
    memory-port pools — the port discipline must match too."""
    g = _trace(_conv2d_build, forward=False)
    for kwargs in ({}, {"ports_per_array": 1}, {"binding": "rank"}):
        _schedules_identical(list_schedule(g, **kwargs),
                             legacy.list_schedule(g, **kwargs))


def test_asap_c_kernel_matches_python_scalar(workload, monkeypatch):
    """The compiled C ASAP core vs the pure-Python scalar core
    (``REPRO_SCHED_SCALAR=1`` forces the latter at call time).  On hosts
    without a C toolchain both runs take the Python path and the test
    degenerates to determinism — still a valid invariant."""
    _, g = workload
    g_opt = passes.optimize(g)
    for kwargs in ({}, {"unroll_factor": 4}, {"pipelined_units": True},
                   {"alap_compact": False}):
        s_c = list_schedule(g_opt, **kwargs)
        monkeypatch.setenv("REPRO_SCHED_SCALAR", "1")
        s_py = list_schedule(g_opt, **kwargs)
        monkeypatch.delenv("REPRO_SCHED_SCALAR")
        _schedules_identical(s_c, s_py)


def test_evaluate_bit_identical(workload, monkeypatch):
    name, g = workload
    g_opt = passes.optimize(g)
    feeds = verify.random_feeds(g, batch=3, seed=0, scale=0.4)
    for fmt in (None, FP_5_4):
        out_new = emit.evaluate(g_opt, feeds, fmt=fmt)
        monkeypatch.setenv("REPRO_LEGACY_IR", "1")
        out_old = emit.evaluate(g_opt, feeds, fmt=fmt)
        monkeypatch.delenv("REPRO_LEGACY_IR")
        assert set(out_new) == set(out_old)
        for k in out_old:
            np.testing.assert_array_equal(out_new[k], out_old[k])


def test_compiled_design_content_hash_identical(workload, monkeypatch):
    """The full driver artifact agrees: design hash, optimised graph
    fingerprint, schedule, makespan."""
    name, g = workload
    d_new = CompilerDriver().compile(g, name=name)
    monkeypatch.setenv("REPRO_LEGACY_IR", "1")
    d_old = CompilerDriver().compile(g, name=name)
    monkeypatch.delenv("REPRO_LEGACY_IR")
    assert d_new.design_hash == d_old.design_hash
    _graphs_identical(d_new.graph_opt, d_old.graph_opt)
    _schedules_identical(d_new.schedule, d_old.schedule)
    assert d_new.makespan == d_old.makespan


# ---------------------------------------------------------------------------
# Rewriter shim regressions (the micro-fix satellite)
# ---------------------------------------------------------------------------


def test_rewriter_lookup_long_replacement_chain():
    """A replacement chain of 10k links must resolve to the root, stay
    correct across interleaved queries, and path-compress (second lookup of
    the deepest link is O(1): the whole chain points at the root)."""
    g = _trace(_conv2d_build)
    rw = passes.Rewriter(g)
    n = 10_000
    for i in range(n):
        rw.replace(i + 1, i)          # i+1 -> i -> ... -> 0
    assert rw.lookup(n) == 0
    # compressed: every visited link now points directly at the root
    assert all(rw.repl[i] == 0 for i in range(1, n + 1))
    assert rw.lookup(n // 2) == 0
    assert rw.lookup(0) == 0          # the root resolves to itself
    # a later replacement extends the chain through the compressed root
    rw.replace(0, n + 7)
    assert rw.lookup(n) == n + 7


def test_cse_single_lookup_on_kept_ops():
    """CSE resolves each kept op's operands exactly once (the historical
    code looked them up a second time inside ``keep``) and still produces
    the same graph."""
    ctx = Context()
    x = ctx.memref("x", (2,), "input")
    out = ctx.memref("out", (3,), "output")
    with ctx.sequential("dups"):
        a = x[0] * x[1]
        b = x[1] * x[0]          # commutative duplicate of a
        c = a + b                # becomes a + a after replacement
        d = b + a                # duplicate of c after replacement
        out[0] = c
        out[1] = d
        out[2] = b
    g = ctx.finalize()
    g_new = passes.cse(g)
    g_old = legacy.cse(g)
    _graphs_identical(g_new, g_old)
    muls = [o for o in g_new.ops if o.opcode == "mulf"]
    assert len(muls) == 1
    adds = [o for o in g_new.ops if o.opcode == "addf"]
    assert len(adds) == 1
    # every surviving operand reference resolved through the dup mapping
    assert adds[0].args == (muls[0].result, muls[0].result)


def test_rewriter_keep_accepts_resolved_args():
    g = _trace(_conv2d_build)
    op = g.ops[0]
    rw = passes.Rewriter(g)
    rw.keep(op, args=op.args)
    assert rw.out.ops[0].args == op.args
