"""FloPoCo (wE,wF) emulation properties (paper §3, §4.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import precision
from repro.core.precision import (FP_5_3, FP_5_4, FP_5_11, FloatFormat,
                                  exponent_histogram, quantize, quantize_np,
                                  required_exponent_bits, ste_quantize)


@settings(max_examples=60, deadline=None)
@given(st.floats(-1e4, 1e4, allow_nan=False),
       st.integers(3, 8), st.integers(2, 10))
def test_quantize_idempotent(x, e, m):
    fmt = FloatFormat(e, m)
    q1 = quantize_np(np.float32(x), fmt)
    q2 = quantize_np(q1, fmt)
    np.testing.assert_array_equal(q1, q2)


@settings(max_examples=60, deadline=None)
@given(st.floats(-1e3, 1e3, allow_nan=False))
def test_quantize_relative_error_bound(x):
    """RNE to wF fraction bits: |q(x)-x| <= 2^-(wF+1) * 2^exp(x) for
    in-range normals."""
    fmt = FP_5_4
    if abs(x) < fmt.min_normal or abs(x) > fmt.max_value:
        return
    q = float(quantize_np(np.float32(x), fmt))
    ulp = 2.0 ** (np.floor(np.log2(abs(x)))) * 2.0 ** (-fmt.man_bits)
    assert abs(q - x) <= ulp / 2 + 1e-12


@settings(max_examples=30, deadline=None)
@given(st.integers(-13, 14))
def test_powers_of_two_exact(e):
    fmt = FP_5_4
    x = np.float32(2.0 ** e)
    assert float(quantize_np(x, fmt)) == float(x)


def test_flush_to_zero_and_saturate():
    fmt = FP_5_4
    tiny = np.float32(fmt.min_normal * 0.4)
    assert float(quantize_np(tiny, fmt)) == 0.0
    huge = np.float32(fmt.max_value * 8)
    assert float(quantize_np(huge, fmt)) == fmt.max_value
    assert float(quantize_np(-huge, fmt)) == -fmt.max_value


def test_wire_bits_match_paper():
    """(5,4) occupies 12 wires: the paper's SLL computation (§4.2)."""
    assert FP_5_4.wire_bits == 12
    assert FP_5_3.wire_bits == 11
    assert FP_5_11.wire_bits == 19
    # paper: (1x16x9x9 + 1x8x9x9) x 12 = 23,328 SLLs > 23,040 available
    assert (16 * 9 * 9 + 8 * 9 * 9) * FP_5_4.wire_bits == 23_328
    assert (16 * 9 * 9 + 8 * 9 * 9) * FP_5_3.wire_bits == 21_384  # < 23,040


def test_jnp_and_np_quantizers_agree():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 10, size=(256,)).astype(np.float32)
    a = quantize_np(x, FP_5_3)
    b = np.asarray(quantize(jnp.asarray(x), FP_5_3))
    np.testing.assert_array_equal(a, b)


def test_ste_gradient_is_identity():
    x = jnp.linspace(-2.0, 2.0, 16)
    g = jax.grad(lambda v: jnp.sum(ste_quantize(v, 5, 4) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_exponent_histogram_and_required_bits():
    """Fig. 7 logic: exponent spread -> smallest sufficient wE."""
    w = {"a": jnp.asarray([0.5, 0.25, 1.0, 2.0])}      # exps -1..1
    hist = exponent_histogram(w)
    assert hist == {-1: 1, -2: 1, 0: 1, 1: 1}
    assert required_exponent_bits(hist) <= 3
    wide = {"a": jnp.asarray([2.0 ** -14, 2.0 ** 15])}
    assert required_exponent_bits(exponent_histogram(wide)) == 5
