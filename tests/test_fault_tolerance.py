"""Fault-tolerance integration: restart bit-exactness, checkpoint
atomicity, straggler substitution, elastic resharding."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.steps import make_train_step
from repro.nn import module, transformer
from repro.optim import adamw
from repro.runtime.fault import (DriverConfig, FailureInjector,
                                 TrainingDriver)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                  attn_pattern=("global",), attn_block_size=32)


def _setup(tmp_path, total_steps=12, fail_at=()):
    params = module.init_tree(transformer.model_specs(CFG),
                              jax.random.key(0))
    opt = adamw.init_state(params)
    step = jax.jit(make_train_step(CFG, adamw.AdamWConfig(
        total_steps=total_steps)))
    pipe = SyntheticTokenPipeline(DataConfig(
        seq_len=16, global_batch=4, vocab_size=128, prefetch=2))
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    driver = TrainingDriver(
        DriverConfig(total_steps=total_steps, checkpoint_every=4,
                     max_restarts=3),
        train_step=step, pipeline=pipe, ckpt=ckpt,
        injector=FailureInjector(fail_at))
    return driver, params, opt


def test_restart_is_bit_exact(tmp_path):
    d1, p1, o1 = _setup(tmp_path / "a", fail_at=())
    rep1 = d1.run(p1, o1)
    d2, p2, o2 = _setup(tmp_path / "b", fail_at=(7,))
    rep2 = d2.run(p2, o2)
    assert rep2.restarts == 1
    assert d2.injector.fired == [7]
    # the interrupted run must converge to the identical loss trajectory
    # after the restart point (deterministic pipeline + optimizer)
    np.testing.assert_allclose(rep1.losses[-1], rep2.losses[-1], rtol=1e-6)
    # and identical final checkpoints
    s1 = CheckpointManager(str(tmp_path / "a")).latest_step()
    s2 = CheckpointManager(str(tmp_path / "b")).latest_step()
    assert s1 == s2 == 12


def test_too_many_failures_raise(tmp_path):
    d, p, o = _setup(tmp_path, fail_at=(2,))
    d.cfg = DriverConfig(total_steps=12, checkpoint_every=4, max_restarts=0)
    with pytest.raises(RuntimeError, match="injected failure"):
        d.run(p, o)


def test_checkpoint_atomicity_and_retention(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]            # retention
    assert not list(pathlib.Path(tmp_path).glob(".tmp_*"))  # atomicity
    restored, step = ckpt.restore(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_async_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((64, 64))}
    ckpt.save_async(10, tree)
    ckpt.wait()
    assert ckpt.latest_step() == 10


def test_structure_mismatch_rejected(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"a": jnp.ones((4,))})
    with pytest.raises(AssertionError):
        ckpt.restore({"a": jnp.ones((4,)), "b": jnp.ones((2,))})


def test_straggler_substitution():
    pipe = SyntheticTokenPipeline(DataConfig(
        seq_len=8, global_batch=2, vocab_size=64, prefetch=1,
        deadline_s=0.05))
    pipe.fetch_delay_s = 0.5          # inject slow I/O
    pipe.seek(0)
    batch = pipe.get(0)               # must not block past the deadline
    assert batch["tokens"].shape == (2, 8)
    assert pipe.straggler_substitutions >= 1
    pipe.stop()
    # substituted batch is the deterministic one
    np.testing.assert_array_equal(batch["tokens"], pipe.batch_at(0)["tokens"])


def test_elastic_restore_with_shardings(tmp_path):
    """Restore a checkpoint with explicit (new-mesh) shardings."""
    from repro.runtime.elastic import reshard_checkpoint
    from repro.launch.mesh import single_device_mesh
    params = module.init_tree(transformer.model_specs(CFG),
                              jax.random.key(0))
    opt = adamw.init_state(params)
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(5, {"params": params, "opt": opt})
    mesh = single_device_mesh()
    tree, step = reshard_checkpoint(ckpt, CFG, mesh)
    assert step == 5
    np.testing.assert_allclose(
        np.asarray(jax.tree_util.tree_leaves(tree["params"])[0]),
        np.asarray(jax.tree_util.tree_leaves(params)[0]))
