"""repro.obs: tracing, metrics, exports, and the instrumented pipeline.

The observability acceptance criteria: a no-op default, correctly nested
spans (including under concurrent DesignEngine submissions), a metrics
registry with snapshot + Prometheus exposition, valid Chrome-trace JSON,
and the compile/pallas/serve instrumentation actually firing.
"""

import json
import threading

import jax
import numpy as np
import pytest

import repro.hls as hls
from repro import obs
from repro.core import frontend
from repro.models import braggnn
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP_SPAN, Tracer


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts disabled with empty tracer/metrics state."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _conv_build(ctx):
    x = ctx.memref("input", (1, 1, 6, 6), "input")
    w = ctx.memref("w", (2, 1, 3, 3), "weight")
    b = ctx.memref("b", (2,), "weight")
    out = ctx.memref("out", (1, 2, 4, 4), "output")
    frontend.conv2d(ctx, x, w, b, out)


# ---------------------------------------------------------------------------
# disabled default: no spans, no metrics, shared no-op span
# ---------------------------------------------------------------------------


def test_disabled_is_noop():
    assert not obs.enabled()
    with obs.span("x", cat="t") as sp:
        sp.set(a=1)                       # must not raise
        assert sp is NOOP_SPAN
    obs.inc("c")
    obs.observe("h", 1.0)
    obs.gauge("g", 2.0)
    assert len(obs.tracer) == 0
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}


def test_enable_disable_round_trip():
    obs.enable()
    assert obs.enabled()
    with obs.span("x", cat="t"):
        pass
    assert len(obs.tracer) == 1
    obs.disable()
    with obs.span("y", cat="t"):
        pass
    assert len(obs.tracer) == 1           # unchanged while disabled


# ---------------------------------------------------------------------------
# tracer: nesting, attributes, threads
# ---------------------------------------------------------------------------


def test_span_nesting_parent_links():
    obs.enable()
    with obs.span("outer", cat="t") as outer:
        with obs.span("inner", cat="t") as inner:
            assert inner.parent_id == outer.span_id
    spans = {s.name: s for s in obs.tracer.spans()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["outer"].t1 >= spans["inner"].t1 >= spans["inner"].t0


def test_span_attrs_and_record():
    obs.enable()
    with obs.span("s", cat="t", k=1) as sp:
        sp.set(v="x")
    s = obs.tracer.spans()[0]
    assert s.attrs == {"k": 1, "v": "x"}
    t = obs.now()
    obs.record_span("retro", t - 0.5, t, cat="t", kind="async", rid=7)
    r = [s for s in obs.tracer.spans() if s.name == "retro"][0]
    assert r.kind == "async" and r.attrs["rid"] == 7
    assert r.dur_s == pytest.approx(0.5, abs=0.05)


def test_thread_local_span_stacks():
    """Spans on different threads never parent across threads."""
    tracer = Tracer()
    barrier = threading.Barrier(4)

    def work(i):
        barrier.wait()
        with tracer.span(f"outer{i}", cat="t"):
            with tracer.span(f"inner{i}", cat="t"):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = {s.name: s for s in tracer.spans()}
    assert len(spans) == 8
    for i in range(4):
        assert spans[f"inner{i}"].parent_id == spans[f"outer{i}"].span_id
        assert spans[f"inner{i}"].thread == spans[f"outer{i}"].thread


def test_tracer_cap_drops_not_grows():
    tracer = Tracer(max_spans=3)
    for i in range(5):
        with tracer.span(f"s{i}", cat="t"):
            pass
    assert len(tracer) == 3 and tracer.dropped == 2


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_snapshot_and_kinds():
    m = MetricsRegistry()
    m.inc("reqs")
    m.inc("reqs", 2)
    m.set_gauge("depth", 4.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("lat", v)
    snap = m.snapshot()
    assert snap["counters"]["reqs"] == 3
    assert snap["gauges"]["depth"] == 4.5
    h = snap["histograms"]["lat"]
    assert h["count"] == 4 and h["sum"] == 10.0
    assert h["min"] == 1.0 and h["max"] == 4.0
    with pytest.raises(TypeError):
        m.inc("lat")                      # kind mismatch is loud


def test_histogram_rejects_nan():
    m = MetricsRegistry()
    m.observe("h", float("nan"))
    m.observe("h", 2.0)
    assert m.snapshot()["histograms"]["h"]["count"] == 1


def test_prometheus_exposition():
    m = MetricsRegistry()
    m.inc("design_cache.hits", 3)
    m.observe("serve.queue_depth", 5.0)
    text = m.to_prometheus()
    assert "# TYPE repro_design_cache_hits counter" in text
    assert "repro_design_cache_hits 3" in text
    assert 'repro_serve_queue_depth{quantile="0.95"}' in text


# ---------------------------------------------------------------------------
# chrome trace export + __main__ summary
# ---------------------------------------------------------------------------


def test_chrome_trace_export_is_valid(tmp_path):
    obs.enable()
    with obs.span("compile", cat="compile"):
        with obs.span("compile.trace", cat="compile"):
            pass
    t = obs.now()
    obs.record_span("serve.request", t - 0.01, t, cat="serve",
                    kind="async", rid=0)
    obs.inc("design_cache.misses")
    path = obs.export_chrome_trace(tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"X", "b", "e", "M"} <= phases
    names = {e["name"] for e in events if e["ph"] == "X"}
    assert {"compile", "compile.trace"} <= names
    for e in events:
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
    assert doc["otherData"]["metrics"]["counters"]["design_cache.misses"] \
        == 1


def test_main_summarises_trace(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main
    obs.enable()
    with obs.span("compile", cat="compile"):
        pass
    obs.inc("design_cache.hits")
    path = obs.export_chrome_trace(tmp_path / "t.json")
    assert obs_main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "compile" in out and "design_cache.hits" in out


# ---------------------------------------------------------------------------
# instrumentation: compiler, pallas, serving engine
# ---------------------------------------------------------------------------


def test_compile_emits_nested_spans_and_cache_counters():
    obs.enable()
    s = hls.Session()
    s.compile(_conv_build, name="obs_conv")
    names = [sp.name for sp in obs.tracer.spans()]
    for expected in ("compile", "compile.trace", "compile.passes",
                     "compile.schedule", "passes.cse"):
        assert expected in names, (expected, names)
    by_name = {sp.name: sp for sp in obs.tracer.spans()}
    root = by_name["compile"]
    assert by_name["compile.trace"].parent_id == root.span_id
    assert by_name["compile.schedule"].parent_id == root.span_id
    assert root.attrs["ops_raw"] >= root.attrs["ops_opt"] > 0
    snap = obs.snapshot()
    assert snap["counters"]["design_cache.misses"] == 1
    s.compile(_conv_build, name="obs_conv")
    assert obs.snapshot()["counters"]["design_cache.hits"] == 1


def test_pallas_profile_spans_on_first_call():
    from repro.core import verify
    from repro.core.emit_pallas import to_pallas_fn
    obs.enable()
    design = hls.Session().compile(_conv_build, name="obs_pallas")
    feeds = verify.random_feeds(design.graph_raw, batch=2, seed=0)
    fn = to_pallas_fn(design.graph_opt)
    out1 = fn(feeds)
    names = [sp.name for sp in obs.tracer.spans()]
    assert "emit.pallas" in names
    assert "pallas.profile" in names
    assert any(n.startswith("pallas.segment") or n.startswith("pallas.fall")
               for n in names), names
    counters = obs.snapshot()["counters"]
    assert counters["pallas.lowerings"] == 1
    # the second call takes the jitted path but matches the profiled one
    n_before = len(obs.tracer)
    out2 = fn(feeds)
    assert [s.name for s in obs.tracer.spans()[n_before:]].count(
        "pallas.profile") == 0
    for k in out1:
        np.testing.assert_allclose(np.asarray(out1[k]),
                                   np.asarray(out2[k]), rtol=1e-5)


def test_engine_request_spans_and_queue_histogram():
    obs.enable()
    model = braggnn.build(1, 9)
    params = model.init_params(jax.random.key(0))
    design = hls.Session().compile(model.bind(params), name="obs_engine")
    eng = design.engine(backend="tensor", max_batch=4)
    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 0.25, (1, 1, 9, 9)).astype(np.float32)
          for _ in range(6)]
    reqs = [eng.submit(x) for x in xs]
    eng.run_until_drained()
    for r in reqs:
        r.wait(timeout=30)
    spans = obs.tracer.spans()
    req_spans = [s for s in spans if s.name == "serve.request"]
    assert len(req_spans) == 6
    assert all(s.kind == "async" for s in req_spans)
    assert {s.attrs["rid"] for s in req_spans} == {r.rid for r in reqs}
    assert any(s.name == "serve.dispatch" for s in spans)
    snap = obs.snapshot()
    assert snap["counters"]["serve.requests_completed"] == 6
    assert snap["histograms"]["serve.queue_depth"]["count"] > 0
    assert snap["histograms"]["serve.batch_occupancy"]["count"] >= 2


def test_concurrent_engine_submissions_keep_spans_consistent():
    """Satellite: span nesting stays consistent when many threads submit
    to a live threaded engine at once."""
    obs.enable()
    model = braggnn.build(1, 9)
    params = model.init_params(jax.random.key(0))
    design = hls.Session().compile(model.bind(params), name="obs_threads")
    eng = design.engine(backend="tensor", max_batch=4, max_delay_ms=1.0)
    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 0.25, (1, 1, 9, 9)).astype(np.float32)
          for _ in range(12)]
    reqs: list = []
    lock = threading.Lock()

    def submit(chunk):
        for x in chunk:
            r = eng.submit(x)
            with lock:
                reqs.append(r)

    with eng:
        threads = [threading.Thread(target=submit, args=(xs[i::3],))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for r in reqs:
            r.wait(timeout=30)
    spans = obs.tracer.spans()
    req_spans = [s for s in spans if s.name == "serve.request"]
    assert len(req_spans) == 12
    assert len({s.attrs["rid"] for s in req_spans}) == 12
    # dispatch spans all live on the engine loop thread, correctly closed
    for s in spans:
        if s.name == "serve.dispatch":
            assert s.t1 >= s.t0
    rep = eng.report()
    assert rep.completed == 12 and rep.dropped == 0


def test_design_report_mentions_obs_when_enabled():
    obs.enable()
    design = hls.Session().compile(_conv_build, name="obs_report")
    text = design.report()
    assert "obs" in text and "spans recorded" in text
    obs.disable()
    assert "spans recorded" not in design.report()
