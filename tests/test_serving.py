"""Serving engines.

LM engine: continuous batching, lane reuse, recurrent-state reset.
Design engine: adaptive batching, warm-boot artifacts, fault-tolerant
replica restarts (the save/load + fault-injection acceptance criteria).
"""

import time

import jax
import numpy as np
import pytest

import repro.hls as hls
from repro.configs import registry
from repro.models import braggnn
from repro.nn import module, transformer
from repro.runtime.fault import FailureInjector
from repro.serving.design_engine import DesignEngine, default_buckets
from repro.serving.engine import ServingEngine


def _engine(arch="qwen2.5-3b", max_batch=3, max_len=64):
    cfg = registry.get_tiny(arch)
    params = module.init_tree(transformer.model_specs(cfg),
                              jax.random.key(0))
    return ServingEngine(cfg, params, max_batch=max_batch, max_len=max_len)


def test_continuous_batching_drains_more_requests_than_lanes():
    eng = _engine(max_batch=2)
    rids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(5)]
    finished = eng.run_until_drained()
    assert len(finished) == 5
    assert sorted(r.rid for r in finished) == rids
    for r in finished:
        assert len(r.output) == 4
    s = eng.stats()
    assert s["generated_tokens"] == 20


def test_deterministic_outputs_independent_of_batching():
    """A request's tokens must not depend on lane traffic around it."""
    eng1 = _engine(max_batch=1)
    eng1.submit([5, 6, 7, 8], max_new_tokens=6)
    alone = eng1.run_until_drained()[0].output

    eng2 = _engine(max_batch=3)
    eng2.submit([9, 10], max_new_tokens=6)
    eng2.submit([5, 6, 7, 8], max_new_tokens=6)
    eng2.submit([11, 12, 13], max_new_tokens=6)
    packed = {r.rid: r.output for r in eng2.run_until_drained()}
    assert packed[1] == alone


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "recurrentgemma-9b"])
def test_lane_reuse_resets_recurrent_state(arch):
    """Recurrent state must not leak between requests sharing a lane."""
    eng = _engine(arch, max_batch=1, max_len=48)
    eng.submit([3, 4, 5], max_new_tokens=5)
    first = eng.run_until_drained()[-1].output

    # same prompt again through the SAME lane after other traffic
    eng.submit([20, 21, 22, 23, 24, 25], max_new_tokens=5)
    eng.run_until_drained()
    eng.submit([3, 4, 5], max_new_tokens=5)
    again = eng.run_until_drained()[-1].output
    assert again == first


def test_eos_stops_generation():
    eng = _engine(max_batch=1)
    # pick eos as whatever the model emits first so it stops at length 1
    eng.submit([1, 2], max_new_tokens=8)
    tok = eng.run_until_drained()[0].output[0]
    eng2 = _engine(max_batch=1)
    eng2.submit([1, 2], max_new_tokens=8, eos_id=tok)
    out = eng2.run_until_drained()[0].output
    assert out[0] == tok and len(out) == 1


# ---------------------------------------------------------------------------
# DesignEngine: adaptive batching over a compiled Design
# ---------------------------------------------------------------------------


IMG = 7


@pytest.fixture(scope="module")
def bound_design():
    model = braggnn.build(1, IMG)
    params = model.init_params(jax.random.key(0))
    return hls.Session().compile(model.bind(params), name="braggnn_engine")


@pytest.fixture(scope="module")
def samples():
    rng = np.random.default_rng(0)
    return [rng.normal(0.0, 0.25, (1, 1, IMG, IMG)).astype(np.float32)
            for _ in range(9)]


def _drain(engine, xs):
    reqs = [engine.submit(x) for x in xs]
    engine.run_until_drained()
    return [r.wait(timeout=30) for r in reqs]


def _assert_same(a, b):
    """Bit-identity across array outputs (tensor) or memref dicts (simd)."""
    if isinstance(a, dict):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_buckets():
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(12) == (1, 2, 4, 8, 12)
    assert default_buckets(1) == (1,)
    with pytest.raises(ValueError):
        default_buckets(0)


def test_engine_sync_mode_serves_all_requests(bound_design, samples):
    eng = bound_design.engine(backend="tensor", max_batch=4)
    outs = _drain(eng, samples)
    rep = eng.report()
    assert rep.completed == len(samples) and rep.dropped == 0
    assert all(np.asarray(o).shape == (2,) for o in outs)
    # head-of-queue grouping: 9 requests, max_batch 4 -> 4+4+1
    assert sorted(rep.batch_hist.items()) == [(1, 1), (4, 2)]
    assert rep.p95_ms >= rep.p50_ms >= 0.0


def test_engine_matches_design_serve(bound_design, samples):
    """Engine per-sample outputs == the sync Design.serve outputs.

    Same bucket shape as the serve batch — bit-identity is a per-compiled-
    program property, so the comparison pins one (9,) dispatch.
    """
    eng = bound_design.engine(backend="tensor", buckets=(len(samples),))
    outs = _drain(eng, samples)
    batch = np.concatenate(samples)          # (9, 1, IMG, IMG)
    report = bound_design.serve([batch], backend="tensor", collect=True)
    ref = np.asarray(report.outputs[0])
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(o, ref[i])


def test_engine_padding_counts_bucket_fill(bound_design, samples):
    eng = bound_design.engine(backend="tensor", buckets=(4,))
    _drain(eng, samples[:3])
    rep = eng.report()
    assert rep.batch_hist == {4: 1}
    assert rep.padded_samples == 1


def test_engine_threaded_mode_drains_on_stop(bound_design, samples):
    eng = bound_design.engine(backend="simd", max_batch=4, max_delay_ms=1.0)
    with eng:
        reqs = [eng.submit(x) for x in samples]
        outs = [r.wait(timeout=30) for r in reqs]
    rep = eng.report()
    assert rep.completed == len(samples) and rep.dropped == 0
    assert rep.qps > 0
    # the SIMD design returns its output memrefs as a dict, sliced per sample
    assert all(np.asarray(o["dense_3_out"]).shape == (1, 2) for o in outs)
    with pytest.raises(RuntimeError, match="stopped"):
        eng.submit(samples[0])


def test_engine_rejects_bad_sample_shape(bound_design):
    eng = bound_design.engine(backend="tensor", max_batch=2)
    with pytest.raises(ValueError, match="does not match input memref"):
        eng.submit(np.zeros((3, 3), np.float32))


# ---------------------------------------------------------------------------
# Warm-boot artifacts: Design.save / hls.load
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["tensor", "simd"])
def test_save_load_round_trip_bit_identical(bound_design, samples,
                                            tmp_path, backend):
    path = tmp_path / "bragg.design"
    bound_design.save(path, backend=backend)
    ref = _drain(bound_design.engine(backend=backend, max_batch=4), samples)

    loaded = hls.load(path)
    assert loaded.manifest["backend"] == backend
    assert loaded.manifest["path"] == str(path)
    eng = loaded.engine(max_batch=4)         # backend from the manifest
    assert eng.backend == backend
    outs = _drain(eng, samples)
    for a, b in zip(ref, outs):
        _assert_same(a, b)


def test_load_rejects_non_artifact(tmp_path):
    p = tmp_path / "junk.design"
    import pickle
    p.write_bytes(pickle.dumps({"nope": 1}))
    with pytest.raises(ValueError, match="not a repro design artifact"):
        hls.load(p)
    with pytest.raises(FileNotFoundError):
        hls.load(tmp_path / "missing.design")


# ---------------------------------------------------------------------------
# Fault tolerance: poisoned dispatch -> artifact warm re-boot, zero dropped
# ---------------------------------------------------------------------------


def test_fault_injection_restarts_from_artifact_no_request_lost(
        bound_design, samples, tmp_path):
    path = tmp_path / "bragg.design"
    bound_design.save(path, backend="tensor")

    # uninterrupted reference run
    ref = _drain(bound_design.engine(backend="tensor", max_batch=4,
                                     artifact_path=path), samples)

    # poison dispatch 1: the second batch fails mid-stream
    inj = FailureInjector(fail_at=(1,))
    eng = bound_design.engine(backend="tensor", max_batch=4,
                              artifact_path=path, injector=inj)
    outs = _drain(eng, samples)
    rep = eng.report()
    assert inj.fired == [1]
    assert rep.restarts == 1
    assert rep.boots == ["memory", "artifact"]   # re-booted from the file
    assert rep.dropped == 0
    assert rep.retried == 4                      # the failed batch, requeued
    assert rep.completed == len(samples)
    for a, b in zip(ref, outs):                  # bit-identical recovery
        _assert_same(a, b)


def test_fault_exhausted_retries_fail_requests_not_hang(bound_design,
                                                        samples):
    inj = FailureInjector(fail_at=(0, 1, 2))
    eng = bound_design.engine(backend="tensor", max_batch=4, max_retries=2,
                              injector=inj)
    reqs = [eng.submit(x) for x in samples[:4]]
    eng.run_until_drained()
    rep = eng.report()
    assert rep.restarts == 3
    assert rep.dropped == 4                      # failed after max_retries
    for r in reqs:
        with pytest.raises(RuntimeError, match="injected failure"):
            r.wait(timeout=5)


# ---------------------------------------------------------------------------
# ServeReport percentiles (sync Design.serve gains the same tail fields)
# ---------------------------------------------------------------------------


def test_serve_report_has_percentiles(bound_design, samples):
    batch = np.concatenate(samples)
    report = bound_design.serve([batch] * 5, backend="tensor")
    assert report.p99_ms >= report.p95_ms >= report.p50_ms > 0.0
    assert "p50" in report.summary()


# ---------------------------------------------------------------------------
# percentiles() edge cases
# ---------------------------------------------------------------------------


def test_percentiles_empty_returns_zeros():
    from repro.serving.common import percentiles
    assert percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_percentiles_single_sample_all_equal():
    from repro.serving.common import percentiles
    assert percentiles([7.5]) == {"p50": 7.5, "p95": 7.5, "p99": 7.5}


def test_percentiles_filters_nan():
    from repro.serving.common import percentiles
    p = percentiles([1.0, float("nan"), 3.0])
    assert p["p50"] == 2.0                        # nan dropped, not sorted-in
    assert np.isfinite(p["p95"]) and np.isfinite(p["p99"])
    # all-NaN degrades like empty rather than propagating NaN
    assert percentiles([float("nan")] * 4) == \
        {"p50": 0.0, "p95": 0.0, "p99": 0.0}


# ---------------------------------------------------------------------------
# queue-depth telemetry (time-weighted, not sampled-at-dispatch-only)
# ---------------------------------------------------------------------------


def test_queue_depth_counts_idle_and_ramp_periods(bound_design, samples):
    """A burst of 8 queued requests must report a max depth of 8 and a
    time-weighted mean/p95 near the top, even though dispatch-time
    sampling alone would see the queue only as it drains (mean ~4)."""
    eng = bound_design.engine(backend="tensor", buckets=(1,))
    for x in samples[:8]:
        eng.submit(x)
    time.sleep(0.25)          # the queue sits at depth 8 the whole time
    eng.run_until_drained()
    rep = eng.report()
    assert rep.completed == 8
    assert rep.max_queue_depth == 8
    # the dwell at depth 8 dominates the drain transitions
    assert rep.p95_queue_depth >= 7
    assert rep.mean_queue_depth > 5


def test_queue_depth_stats_unit():
    from repro.serving.common import RequestQueue
    q = RequestQueue()
    # hand-build a step function: depth 2 for 1s, depth 10 for 9s
    q.depth_events = [(0.0, 2), (1.0, 10), (10.0, 0)]
    stats = q.depth_stats()
    assert stats["max"] == 10.0
    assert stats["mean"] == pytest.approx(0.1 * 2 + 0.9 * 10)
    assert stats["p95"] == 10.0
