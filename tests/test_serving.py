"""Serving engine: continuous batching, lane reuse, recurrent-state reset."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.nn import module, transformer
from repro.serving.engine import ServingEngine


def _engine(arch="qwen2.5-3b", max_batch=3, max_len=64):
    cfg = registry.get_tiny(arch)
    params = module.init_tree(transformer.model_specs(cfg),
                              jax.random.key(0))
    return ServingEngine(cfg, params, max_batch=max_batch, max_len=max_len)


def test_continuous_batching_drains_more_requests_than_lanes():
    eng = _engine(max_batch=2)
    rids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(5)]
    finished = eng.run_until_drained()
    assert len(finished) == 5
    assert sorted(r.rid for r in finished) == rids
    for r in finished:
        assert len(r.output) == 4
    s = eng.stats()
    assert s["generated_tokens"] == 20


def test_deterministic_outputs_independent_of_batching():
    """A request's tokens must not depend on lane traffic around it."""
    eng1 = _engine(max_batch=1)
    eng1.submit([5, 6, 7, 8], max_new_tokens=6)
    alone = eng1.run_until_drained()[0].output

    eng2 = _engine(max_batch=3)
    eng2.submit([9, 10], max_new_tokens=6)
    eng2.submit([5, 6, 7, 8], max_new_tokens=6)
    eng2.submit([11, 12, 13], max_new_tokens=6)
    packed = {r.rid: r.output for r in eng2.run_until_drained()}
    assert packed[1] == alone


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "recurrentgemma-9b"])
def test_lane_reuse_resets_recurrent_state(arch):
    """Recurrent state must not leak between requests sharing a lane."""
    eng = _engine(arch, max_batch=1, max_len=48)
    eng.submit([3, 4, 5], max_new_tokens=5)
    first = eng.run_until_drained()[-1].output

    # same prompt again through the SAME lane after other traffic
    eng.submit([20, 21, 22, 23, 24, 25], max_new_tokens=5)
    eng.run_until_drained()
    eng.submit([3, 4, 5], max_new_tokens=5)
    again = eng.run_until_drained()[-1].output
    assert again == first


def test_eos_stops_generation():
    eng = _engine(max_batch=1)
    # pick eos as whatever the model emits first so it stops at length 1
    eng.submit([1, 2], max_new_tokens=8)
    tok = eng.run_until_drained()[0].output[0]
    eng2 = _engine(max_batch=1)
    eng2.submit([1, 2], max_new_tokens=8, eos_id=tok)
    out = eng2.run_until_drained()[0].output
    assert out[0] == tok and len(out) == 1
