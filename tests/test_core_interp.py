"""Symbolic interpretation + behavioural testbenches (paper §3.1, §3.2).

These are the cocotb-style CI testbenches the paper describes: every layer
type is built as a loop nest, interpreted into a DFG, optimised, scheduled,
and compared against an independent numpy/jnp reference.
"""

import numpy as np
import pytest

from repro.core import Context, frontend, verify
from repro.core.ir import MEM_OPS


def test_conv2d_testbench():
    def build(ctx):
        inp = ctx.memref("input", (1, 2, 8, 8), "input")
        w = ctx.memref("w", (3, 2, 3, 3), "weight")
        b = ctx.memref("b", (3,), "weight")
        out = ctx.memref("out", (1, 3, 6, 6), "output")
        frontend.conv2d(ctx, inp, w, b, out)

    def ref(feeds):
        from repro.kernels.conv2d_vmem.ref import conv2d_ref
        outs = [np.asarray(conv2d_ref(feeds["input"][i],
                                      feeds["w"][i], feeds["b"][i]))
                for i in range(feeds["input"].shape[0])]
        return {"out": np.stack(outs, 0)}

    rep = verify.run_testbench("conv2d", build, ref_fn=ref, ref_atol=1e-3)
    assert rep.passed, rep.summary()


def test_addmm_testbench():
    def build(ctx):
        a = ctx.memref("a", (4, 6), "input")
        b = ctx.memref("b", (6, 5), "input")
        c = ctx.memref("c", (4, 5), "input")
        out = ctx.memref("out", (4, 5), "output")
        frontend.addmm(ctx, a, b, c, out)

    def ref(feeds):
        return {"out": np.einsum("bij,bjk->bik", feeds["a"], feeds["b"])
                + feeds["c"]}

    rep = verify.run_testbench("addmm", build, ref_fn=ref, ref_atol=1e-3)
    assert rep.passed, rep.summary()


def test_batch_norm_testbench():
    def build(ctx):
        inp = ctx.memref("input", (2, 2, 3, 3), "input")
        g = ctx.memref("gamma", (2,), "weight")
        bta = ctx.memref("beta", (2,), "weight")
        mu = ctx.memref("mean", (2,), "weight")
        out = ctx.memref("out", (2, 2, 3, 3), "output")
        var = ctx.memref("var", (2,), "weight")
        frontend.batch_norm_2d(ctx, inp, g, bta, mu, var, out)

    def ref(feeds):
        x, g, b = feeds["input"], feeds["gamma"], feeds["beta"]
        mu, var = feeds["mean"], feeds["var"]
        inv = 1.0 / np.sqrt(var + 1e-5)
        y = (g * inv)[:, None, :, None, None] * (
            x - mu[:, None, :, None, None]) + b[:, None, :, None, None]
        return {"out": y.astype(np.float32)}

    rep = verify.run_testbench(
        "batch_norm_2d", build, ref_fn=ref, ref_atol=5e-2, scale=0.5,
        seed=3, feed_transforms={"var": lambda v: np.abs(v) + 0.1})
    assert rep.passed, rep.summary()


def test_max_pool_testbench():
    def build(ctx):
        inp = ctx.memref("input", (1, 3, 8, 8), "input")
        out = ctx.memref("out", (1, 3, 3, 3), "output")
        frontend.max_pool_2d(ctx, inp, out, k=3, stride=2)

    def ref(feeds):
        x = feeds["input"]
        b = x.shape[0]
        out = np.zeros((b, 1, 3, 3, 3), np.float32)
        for i in range(3):
            for j in range(3):
                out[:, :, :, i, j] = x[:, :, :, 2 * i:2 * i + 3,
                                       2 * j:2 * j + 3].max((-1, -2))
        return {"out": out}

    rep = verify.run_testbench("max_pool_2d", build, ref_fn=ref,
                               ref_atol=1e-5)
    assert rep.passed, rep.summary()


def test_soft_max_testbench():
    def build(ctx):
        inp = ctx.memref("input", (3, 12), "input")
        out = ctx.memref("out", (3, 12), "output")
        frontend.soft_max(ctx, inp, out)

    def ref(feeds):
        x = feeds["input"]
        e = np.exp(x - x.max(-1, keepdims=True))
        return {"out": (e / e.sum(-1, keepdims=True)).astype(np.float32)}

    rep = verify.run_testbench("soft_max", build, ref_fn=ref, ref_atol=5e-2)
    assert rep.passed, rep.summary()


def test_store_load_forwarding_eliminates_memory_ops():
    """OpenHLS mode leaves no load/store in the DFG (paper §3.1)."""
    ctx = Context(forward=True)
    a = ctx.memref("a", (4, 4), "input")
    b = ctx.memref("b", (4, 4), "input")
    c = ctx.memref("c", (4, 4), "input")
    out = ctx.memref("out", (4, 4), "output")
    frontend.addmm(ctx, a, b, c, out)
    g = ctx.finalize()
    assert all(op.opcode not in MEM_OPS for op in g.ops)

    # baseline (Vitis-like) mode keeps them
    ctx2 = Context(forward=False)
    a2 = ctx2.memref("a", (4, 4), "input")
    b2 = ctx2.memref("b", (4, 4), "input")
    c2 = ctx2.memref("c", (4, 4), "input")
    out2 = ctx2.memref("out", (4, 4), "output")
    frontend.addmm(ctx2, a2, b2, c2, out2)
    g2 = ctx2.finalize()
    n_mem = sum(1 for op in g2.ops if op.opcode in MEM_OPS)
    assert n_mem > 0
    # both evaluate to the same function
    from repro.core import emit
    feeds = verify.random_feeds(g, batch=2, seed=1)
    o1 = emit.evaluate(g, feeds)
    o2 = emit.evaluate(g2, feeds)
    np.testing.assert_allclose(o1["out"], o2["out"], rtol=1e-6)


def test_parallel_write_disjointness_assertion():
    """The paper's runtime memory-dependence check (§3.1 item 1)."""
    ctx = Context()
    out = ctx.memref("out", (4,), "output")
    with pytest.raises(RuntimeError, match="memory-dependence violation"):
        for (i,) in ctx.parallel(4, label="bad"):
            out[0] = ctx.const(float(i))   # every instance writes slot 0


def test_uninitialised_read_raises():
    ctx = Context()
    t = ctx.temp("t", (2,))
    with pytest.raises(RuntimeError, match="uninitialised"):
        _ = t[0]


def test_unrolling_is_fast_where_static_analysis_is_hours():
    """Fig. 2's point: symbolic interpretation unrolls big conv nests in
    seconds.  (The paper measures 160 h for static store-load forwarding at
    128x128; we assert our interpreter stays sub-minute at 64x64.)"""
    import time
    ctx = Context()
    inp = ctx.memref("input", (1, 1, 64, 64), "input")
    w = ctx.memref("w", (1, 1, 3, 3), "weight")
    out = ctx.memref("out", (1, 1, 62, 62), "output")
    t0 = time.perf_counter()
    frontend.conv2d(ctx, inp, w, None, out)
    g = ctx.finalize()
    dt = time.perf_counter() - t0
    assert dt < 60.0
    assert g.num_arith_ops() >= 62 * 62 * 9
