"""The nn -> loop-nest bridge: fingerprint equivalence + vocabulary.

The acceptance criterion of the api_redesign: ``hls.compile`` of the jax
BraggNN module graph yields the same ``graph_fingerprint`` (and
CompiledDesign hash) as the hand-written ``frontend.braggnn`` path.
"""

import jax
import numpy as np
import pytest

import repro.hls as hls
from repro.core import frontend
from repro.core.pipeline import graph_fingerprint
from repro.models import braggnn
from repro.nn import graph as nng


# ---------------------------------------------------------------------------
# BraggNN equivalence (reduced img=7 keeps CI fast, as in test_braggnn_paper)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def session():
    return hls.Session()


@pytest.fixture(scope="module")
def bridged(session):
    return session.compile(braggnn.build(1, 7), name="braggnn_bridge")


def test_braggnn_fingerprint_equals_handwritten(bridged):
    g_hand = hls.trace(lambda ctx: frontend.braggnn(ctx, s=1, img=7))
    assert bridged.fingerprint == graph_fingerprint(g_hand)


def test_braggnn_design_hash_equals_handwritten(bridged, session):
    # same fingerprint + same config => the hand-written compile is served
    # from the very cache entry the bridged compile created
    hits = session.stats()["hits"]
    d_hand = session.compile(
        lambda ctx: frontend.braggnn(ctx, s=1, img=7), name="braggnn_hand")
    assert d_hand.design_hash == bridged.design_hash
    assert session.stats()["hits"] == hits + 1


def test_braggnn_module_runs_with_trained_weights(bridged):
    """Bound params flow through ``Design.run`` and match the tensor twin."""
    model = braggnn.build(1, 7)
    params = model.init_params(jax.random.key(0))
    design = hls.compile(model.bind(params), session=bridged.session,
                         name="braggnn_bound")
    rng = np.random.default_rng(0)
    x = rng.normal(0.0, 0.25, (2, 1, 7, 7)).astype(np.float32)
    out = design.run(x)["dense_3_out"]
    ref = braggnn.forward(params, x[:1])  # tensor model, first sample
    np.testing.assert_allclose(out[0, 0], np.asarray(ref)[0],
                               rtol=5e-2, atol=5e-3)


def test_braggnn_specs_match_module_graph():
    """models.braggnn.specs is derived from build(): same tree, shapes."""
    sp = braggnn.specs(1, 7)
    assert set(sp) == {"conv1", "nlb", "conv2a", "conv2b",
                       "dense0", "dense1", "dense2", "dense3"}
    assert sp["conv1"]["w"].shape == (16, 1, 3, 3)
    assert sp["nlb"]["theta"]["w"].shape == (8, 16, 1, 1)
    assert sp["dense3"]["w"].shape == (2, 4)
    assert sp["conv1"]["b"].init == "zeros"


# ---------------------------------------------------------------------------
# Vocabulary coverage (small shapes)
# ---------------------------------------------------------------------------


def _tiny_module(**kw):
    nodes = [
        nng.Conv2d("c1", in_channels=1, out_channels=2, kernel=3),
        nng.BatchNorm2d("bn", channels=2),
        nng.ReLU(name="r1"),
        nng.MaxPool2d(name="mp", kernel=2, stride=2),
        nng.Flatten(name="fl"),
        nng.Linear("fc", in_features=2 * 3 * 3, out_features=4),
        nng.Softmax(name="sm"),
    ]
    return nng.ModuleGraph("tiny", (1, 1, 8, 8), nodes, **kw)


def test_vocabulary_compiles_and_runs(session):
    m = _tiny_module()
    m = m.bind(m.init_params(jax.random.key(1)))
    x = np.random.default_rng(1).normal(0, 0.5, (3, 1, 8, 8)).astype(
        np.float32)
    design = session.compile(m, example_inputs=x)
    out = design.run()["sm_out"]
    assert out.shape == (3, 1, 4)
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-3)


def test_shapes_inference():
    m = _tiny_module()
    assert m.shapes() == [(1, 2, 6, 6), (1, 2, 6, 6), (1, 2, 6, 6),
                          (1, 2, 3, 3), (1, 18), (1, 4), (1, 4)]
    assert m.output_shape == (1, 4)


def test_weight_feeds_names_and_shapes():
    m = _tiny_module()
    params = m.init_params(jax.random.key(0))
    feeds = m.weight_feeds(params)
    assert set(feeds) == {"c1.weight", "c1.bias", "bn.gamma", "bn.beta",
                          "bn.mean", "bn.var", "fc.weight", "fc.bias"}
    assert feeds["c1.weight"].shape == (2, 1, 3, 3)
    assert feeds["fc.weight"].shape == (4, 18)


def test_module_graph_validates_vocabulary():
    class Alien:
        pass
    with pytest.raises(TypeError, match="vocabulary"):
        nng.ModuleGraph("bad", (1, 1, 4, 4), [Alien()])
    with pytest.raises(ValueError, match="last node"):
        nng.ModuleGraph("bad", (1, 1, 4, 4),
                        [nng.OutputReLU(), nng.ReLU(name="r")])


def test_unbound_module_requires_weight_feeds(session):
    m = _tiny_module()          # no params bound
    design = session.compile(m, name="tiny_unbound")
    x = np.zeros((1, 1, 8, 8), np.float32)
    with pytest.raises(KeyError, match="missing feed"):
        design.run(x)
