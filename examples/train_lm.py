"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
through the full production stack (pipeline -> train_step -> checkpoints ->
fault-tolerant driver), with a mid-run injected failure to demonstrate
checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import time

import jax

from repro import obs
from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.steps import make_train_step
from repro.nn import module, transformer
from repro.optim import adamw
from repro.runtime.fault import DriverConfig, FailureInjector, TrainingDriver

log = obs.get_logger(__name__)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    obs.setup_logging()

    # ~100M params: 8L x d512 GQA + gated MLP + 32k vocab
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32768,
        attn_pattern=("global",), head_dim=64, attn_block_size=256,
        remat="full")
    specs = transformer.model_specs(cfg)
    n = module.param_count(specs)
    log.info("model: %.1fM params", n / 1e6)

    params = module.init_tree(specs, jax.random.key(0))
    opt = adamw.init_state(params)
    step = jax.jit(make_train_step(
        cfg, adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=20,
                               total_steps=args.steps)),
        donate_argnums=(0, 1))
    pipe = SyntheticTokenPipeline(DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size))
    driver = TrainingDriver(
        DriverConfig(total_steps=args.steps, checkpoint_every=50),
        train_step=step, pipeline=pipe,
        ckpt=CheckpointManager(args.ckpt, keep=2),
        injector=FailureInjector((args.steps // 2,)))   # mid-run crash

    t0 = time.monotonic()
    report = driver.run(params, opt)
    dt = time.monotonic() - t0
    toks = args.steps * args.batch * args.seq
    log.info("done: %s steps, %.0f tok/s, restarts=%s (1 injected), "
             "stragglers=%s", args.steps, toks / dt, report.restarts,
             len(report.straggler_steps))
    log.info("loss: %.3f -> %.3f (next-token CE on synthetic Zipf stream)",
             report.losses[0], report.losses[-1])
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
