"""Quickstart: the OpenHLS pipeline end to end on one convolution.

    PYTHONPATH=src python examples/quickstart.py

Builds a conv2d loop nest, symbolically interprets it into an SSA DFG
(store-load forwarding included), optimises, schedules, behaviourally
verifies, quantises to FloPoCo (5,4), and runs the emitted SIMD design.
"""

import numpy as np

from repro.core import (Context, FP_5_4, emit, frontend, list_schedule,
                        passes, verify)


def main() -> None:
    # 1. describe the DNN operation as an scf-style loop nest
    ctx = Context()
    x = ctx.memref("input", (1, 3, 16, 16), "input")
    w = ctx.memref("weight", (8, 3, 3, 3), "weight")
    b = ctx.memref("bias", (8,), "weight")
    out = ctx.memref("out", (1, 8, 14, 14), "output")
    frontend.conv2d(ctx, x, w, b, out)

    # 2. symbolic interpretation -> fully unrolled SSA DFG
    g = ctx.finalize()
    print(f"raw DFG:      {len(g.ops):6d} ops "
          f"(no loads/stores — forwarding is built in)")

    # 3. optimisation passes (paper §3.2)
    g = passes.optimize(g)
    print(f"optimised:    {len(g.ops):6d} ops  {g.op_histogram()}")

    # 4. resource-constrained list scheduling (paper §3.3)
    sched = list_schedule(g)
    print(f"schedule:     {sched.makespan} intervals @10ns = "
          f"{sched.latency_us:.2f} us; resources {sched.resources()}")

    # 5. behavioural verification incl. the FloPoCo (5,4) functional model
    feeds = verify.random_feeds(g, batch=4, seed=0)
    ref = emit.evaluate(g, feeds)
    q54 = emit.evaluate(g, feeds, fmt=FP_5_4)
    print(f"(5,4) max abs deviation vs fp32: "
          f"{np.max(np.abs(ref['out'] - q54['out'])):.4f}")

    # 6. emitted SIMD design (jittable) matches the functional model
    import jax
    fn = jax.jit(emit.to_jax_fn(g))
    got = np.asarray(fn(feeds)["out"])
    np.testing.assert_allclose(got, ref["out"], rtol=1e-4, atol=1e-5)
    print("emitted SIMD design matches the functional simulation  [OK]")


if __name__ == "__main__":
    main()
