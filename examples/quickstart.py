"""Quickstart: the OpenHLS pipeline end to end on one convolution.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --pipeline cse,dce

One ``repro.hls.compile()`` call runs the whole Fig. 1 flow: the conv2d
loop nest is symbolically interpreted into an SSA DFG (store-load
forwarding included), optimised, scheduled, and returned as a ``Design``
handle.  We then behaviourally verify it, quantise to FloPoCo (5,4), and
run the emitted SIMD design.  ``--pipeline`` selects which registered
passes run (comma-separated, in order) instead of the default §3.2
pipeline.
"""

import argparse

import numpy as np

import repro.hls as hls
from repro import obs
from repro.core import FP_5_4, frontend
from repro.core.pipeline import DEFAULT_PIPELINE, parse_pipeline_spec

log = obs.get_logger(__name__)


def build(ctx) -> None:
    # 1. describe the DNN operation as an scf-style loop nest
    x = ctx.memref("input", (1, 3, 16, 16), "input")
    w = ctx.memref("weight", (8, 3, 3, 3), "weight")
    b = ctx.memref("bias", (8,), "weight")
    out = ctx.memref("out", (1, 8, 14, 14), "output")
    frontend.conv2d(ctx, x, w, b, out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pipeline", default=None, metavar="P1,P2,...",
                    help="comma-separated pass pipeline "
                         f"(default: {','.join(DEFAULT_PIPELINE)})")
    args = ap.parse_args(argv)
    obs.setup_logging()
    try:
        config = hls.CompilerConfig() if args.pipeline is None else \
            hls.CompilerConfig(pipeline=parse_pipeline_spec(args.pipeline))
    except ValueError as e:
        raise SystemExit(str(e))

    # 2. compile: trace -> passes -> schedule, one public entrypoint
    design = hls.compile(build, name="conv2d_quickstart", config=config)
    log.info("%s", design.report())

    # 3. one behavioural testbench covers it all (§3.2): optimised DFG and
    # emitted SIMD design vs the interpreter reference, plus the FloPoCo
    # (5,4) functional model
    report = design.verify(batch=4, seed=0, fmt=FP_5_4)
    log.info("%s", report.summary())
    log.info("(5,4) max abs deviation vs fp32: %.4f",
             report.max_abs_err_quant)
    assert report.passed, "behavioural verification failed"
    log.info("emitted SIMD design matches the functional "
             "simulation  [OK]")

    # 4. the deployable path: run a fresh batch through the jitted design
    import jax
    fn = jax.jit(design.jax_fn())
    from repro.core import verify
    feeds = verify.random_feeds(design.graph_opt, batch=4, seed=1)
    got = np.asarray(fn(feeds)["out"])
    log.info("served a batch of 4 through the SIMD design: out %s",
             got.shape)

    # 5. a second compile of the same program is a cache hit
    hls.compile(build, name="conv2d_quickstart", config=config,
                session=design.session)
    stats = design.session.stats()
    log.info("design cache: %s hit(s), %s miss(es), hash %s",
             stats["hits"], stats["misses"], design.design_hash[:12])


if __name__ == "__main__":
    main()
