"""Quickstart: the OpenHLS pipeline end to end on one convolution.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --pipeline cse,dce

One ``CompilerDriver.compile()`` call runs the whole Fig. 1 flow: the
conv2d loop nest is symbolically interpreted into an SSA DFG (store-load
forwarding included), optimised, scheduled, and bundled as a
``CompiledDesign``.  We then behaviourally verify it, quantise to FloPoCo
(5,4), and run the emitted SIMD design.  ``--pipeline`` selects which
registered passes run (comma-separated, in order) instead of the default
§3.2 pipeline.
"""

import argparse

import numpy as np

from repro.core import CompilerConfig, CompilerDriver, FP_5_4, frontend
from repro.core.pipeline import DEFAULT_PIPELINE, parse_pipeline_spec


def build(ctx) -> None:
    # 1. describe the DNN operation as an scf-style loop nest
    x = ctx.memref("input", (1, 3, 16, 16), "input")
    w = ctx.memref("weight", (8, 3, 3, 3), "weight")
    b = ctx.memref("bias", (8,), "weight")
    out = ctx.memref("out", (1, 8, 14, 14), "output")
    frontend.conv2d(ctx, x, w, b, out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pipeline", default=None, metavar="P1,P2,...",
                    help="comma-separated pass pipeline "
                         f"(default: {','.join(DEFAULT_PIPELINE)})")
    args = ap.parse_args(argv)
    try:
        config = CompilerConfig() if args.pipeline is None else \
            CompilerConfig(pipeline=parse_pipeline_spec(args.pipeline))
    except ValueError as e:
        raise SystemExit(str(e))

    # 2. compile: trace -> passes -> schedule, one entrypoint
    driver = CompilerDriver(config)
    design = driver.compile(build, name="conv2d_quickstart")
    print(f"pass pipeline: {', '.join(design.config.pipeline) or '(none)'}")
    print(f"raw DFG:      {len(design.graph_raw.ops):6d} ops "
          f"(no loads/stores — forwarding is built in)")
    print(f"optimised:    {len(design.graph_opt.ops):6d} ops  "
          f"{design.graph_opt.op_histogram()}")
    for rep in design.pass_reports:
        if rep.ops_delta:
            print(f"   pass {rep.summary()}")
    print(f"schedule:     {design.makespan} intervals @10ns = "
          f"{design.latency_us:.2f} us; resources "
          f"{design.schedule.resources()}")

    # 3. behavioural verification incl. the FloPoCo (5,4) functional model
    from repro.core import verify
    feeds = verify.random_feeds(design.graph_opt, batch=4, seed=0)
    ref = design.evaluate(feeds)
    q54 = design.evaluate(feeds, fmt=FP_5_4)
    print(f"(5,4) max abs deviation vs fp32: "
          f"{np.max(np.abs(ref['out'] - q54['out'])):.4f}")

    # 4. emitted SIMD design (jittable) matches the functional model
    import jax
    fn = jax.jit(design.jax_fn())
    got = np.asarray(fn(feeds)["out"])
    np.testing.assert_allclose(got, ref["out"], rtol=1e-4, atol=1e-5)
    print("emitted SIMD design matches the functional simulation  [OK]")

    # 5. a second compile of the same program is a cache hit
    driver.compile(build, name="conv2d_quickstart")
    print(f"design cache: {driver.cache.hits} hit(s), "
          f"{driver.cache.misses} miss(es), hash "
          f"{design.design_hash[:12]}")


if __name__ == "__main__":
    main()
