"""BraggNN low-latency inference — the paper's deployment scenario (§4.2).

    PYTHONPATH=src python examples/braggnn_serve.py

Trains BraggNN briefly on synthetic Bragg peaks, compiles the full OpenHLS
design (schedule + 3-stage pipeline report next to the paper's numbers),
then serves batched peak-localisation requests through the fused (5,4)
reduced-precision path and reports throughput.
"""

import os
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompilerDriver, frontend
from repro.core.schedule import CLOCK_NS
from repro.models import braggnn
from repro.nn import module
from repro.optim import adamw

#: On-disk design cache: the second run of this example (and any other
#: consumer compiling BraggNN(s=1)) serves the schedule from disk.
#: Per-user path — cache entries are pickles, never share them.
_UID = os.getuid() if hasattr(os, "getuid") else "u"
CACHE_DIR = Path(tempfile.gettempdir()) / f"repro_design_cache_{_UID}"


def main() -> None:
    # --- train briefly on synthetic peaks --------------------------------
    params = module.init_tree(braggnn.specs(1), jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup_steps=10,
                                total_steps=150, weight_decay=0.0)
    state = adamw.init_state(params)

    @jax.jit
    def step(p, s, x, y):
        def loss(pp):
            return jnp.mean((braggnn.forward(pp, x) - y * 10.0) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        p2, s2, _ = adamw.apply_updates(opt_cfg, p, g, s)
        return p2, s2, l

    key = jax.random.key(1)
    for i in range(150):
        x, y = braggnn.synthetic_peaks(jax.random.fold_in(key, i), 64)
        params, state, l = step(params, state, x, y)
    print(f"trained BraggNN: loss {float(l):.4f}")

    # --- the OpenHLS schedule (paper's deployment artifact), served from
    # --- the design cache on warm runs -------------------------------------
    driver = CompilerDriver(cache_dir=CACHE_DIR)
    t0 = time.perf_counter()
    design = driver.compile(lambda ctx: frontend.braggnn(ctx, s=1),
                            name="braggnn_s1")
    compile_s = time.perf_counter() - t0
    _, ii = design.partition(3)
    source = "cache" if driver.cache.hits else "cold compile"
    print(f"OpenHLS schedule ({source}, {compile_s:.1f}s): "
          f"{design.makespan} intervals total, 3-stage "
          f"II={ii} -> {ii * CLOCK_NS * 1e-3:.2f} us/sample "
          f"(paper: 1238 total, II=480 -> 4.8 us/sample)")

    # --- serve batches at (5,4) precision ----------------------------------
    infer = jax.jit(lambda p, xx: braggnn.forward(p, xx, fmt="5_4"))
    x, y = braggnn.synthetic_peaks(jax.random.key(7), 1024)
    jax.block_until_ready(infer(params, x))
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        pred = infer(params, x)
    jax.block_until_ready(pred)
    dt = time.perf_counter() - t0
    err_px = float(jnp.mean(jnp.abs(pred / 10.0 - y))) * 11
    print(f"served {reps * 1024} samples: "
          f"{dt / (reps * 1024) * 1e6:.2f} us/sample on CPU, "
          f"mean localisation error {err_px:.3f} px at (5,4)")


if __name__ == "__main__":
    main()
