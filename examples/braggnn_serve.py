"""BraggNN low-latency inference — the paper's deployment scenario (§4.2).

    PYTHONPATH=src python examples/braggnn_serve.py
    PYTHONPATH=src python examples/braggnn_serve.py --tuned
    PYTHONPATH=src python examples/braggnn_serve.py --pipeline cse,dce
    PYTHONPATH=src python examples/braggnn_serve.py --engine --save b.design
    PYTHONPATH=src python examples/braggnn_serve.py --engine --load b.design

Trains BraggNN briefly on synthetic Bragg peaks, binds the trained weights
into the declarative module graph (``models.braggnn.build``), and compiles
it through the public API — ``repro.hls.compile`` auto-lowers the module
to the paper's loop nests via the bridge (bit-identical to the hand-
written ``frontend.braggnn``).  Batched peak-localisation requests are
then served through ``Design.serve``'s fused reduced-precision tensor
path — (5,4) by default, or whatever format the tuned candidate carries.

``--tuned`` auto-loads the best known compile configuration from the
persistent ``TuningDB`` via ``Design.apply_tuned`` (populate it with
``python -m repro.tune --config braggnn``; a miss names the DB path it
probed); ``--pipeline`` overrides the pass pipeline by hand.  Designs are
cached under the shared versioned cache root (``cache=True``), so warm
runs serve the schedule from disk.

``--engine`` additionally fronts the design with the async adaptive-
batching engine (``Design.engine``) and prints its tail-latency summary;
``--save PATH`` persists the warm-boot artifact, ``--load PATH`` boots
from one instead of training + compiling (and is the engine's replica-
restart source).

``--trace-out PATH`` turns on :mod:`repro.obs` for the whole run and
exports the compile-and-serve timeline as Chrome-trace JSON (open in
``chrome://tracing`` or summarise with ``python -m repro.obs PATH``).
"""

import argparse
import time

import jax
import jax.numpy as jnp

import repro.hls as hls
from repro import obs
from repro.core.pipeline import parse_pipeline_spec
from repro.models import braggnn
from repro.optim import adamw

log = obs.get_logger(__name__)


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tuned", action="store_true",
                    help="load the best compile config from the TuningDB")
    ap.add_argument("--pipeline", default=None, metavar="P1,P2,...",
                    help="override the pass pipeline (comma-separated)")
    ap.add_argument("--db", default=None,
                    help="TuningDB path (default: shared cache root)")
    ap.add_argument("--engine", action="store_true",
                    help="also serve through the async adaptive-batching "
                         "engine and print its tail-latency summary")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="persist the warm-boot artifact (Design.save)")
    ap.add_argument("--load", default=None, metavar="PATH",
                    help="boot from a saved artifact instead of "
                         "training + compiling (hls.load)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable repro.obs and export the run's "
                         "Chrome-trace JSON to PATH")
    return ap.parse_args(argv)


def train(model: hls.ModuleGraph, steps: int = 150) -> dict:
    """Brief synthetic-peak training run; returns the trained param tree."""
    params = model.init_params(jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup_steps=10,
                                total_steps=steps, weight_decay=0.0)
    state = adamw.init_state(params)

    @jax.jit
    def step(p, s, x, y):
        def loss(pp):
            return jnp.mean((braggnn.forward(pp, x) - y * 10.0) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        p2, s2, _ = adamw.apply_updates(opt_cfg, p, g, s)
        return p2, s2, l

    key = jax.random.key(1)
    for i in range(steps):
        x, y = braggnn.synthetic_peaks(jax.random.fold_in(key, i), 64)
        params, state, l = step(params, state, x, y)
    log.info("trained BraggNN: loss %.4f", float(l))
    return params


def serve_engine(design, serve_fmt, save_path=None) -> None:
    """Front the design with the async engine; print the tail-latency
    summary (and where a poisoned replica would warm-boot from)."""
    x, y = braggnn.synthetic_peaks(jax.random.key(7), 256)
    samples = jnp.asarray(x)[:, None]            # (N, 1, img, img) memrefs
    eng = design.engine(backend="tensor", fmt=serve_fmt, max_batch=16,
                        max_delay_ms=2.0, artifact_path=save_path)
    with eng:
        reqs = [eng.submit(s) for s in samples]
        for r in reqs:
            r.wait(timeout=60)
    log.info("engine: %s", eng.report().summary())


def main(argv=None) -> None:
    args = parse_args(argv)
    obs.setup_logging()
    if args.trace_out:
        obs.enable()

    try:
        _run(args)
    finally:
        if args.trace_out:
            path = obs.export_chrome_trace(args.trace_out)
            log.info("obs: exported Chrome trace to %s "
                     "(chrome://tracing, or `python -m repro.obs %s`)",
                     path, path)


def _run(args) -> None:
    if args.load:
        # --- warm boot: one disk read, no training, no compile -------------
        t0 = time.perf_counter()
        design = hls.load(args.load)
        log.info("warm boot from %s: %.2fs (%s, hash %s)", args.load,
                 time.perf_counter() - t0, design.name,
                 design.design_hash[:12])
        serve_fmt = design.manifest.get("fmt")
        if args.engine:
            serve_engine(design, serve_fmt, save_path=args.load)
        else:
            x, _ = braggnn.synthetic_peaks(jax.random.key(7), 1024)
            log.info("%s", design.serve([x] * 10, fmt=serve_fmt,
                                        backend="tensor").summary())
        return

    # --- describe once, train, bind ----------------------------------------
    model = braggnn.build(s=1)
    model = model.bind(train(model))

    # --- compile through the public API (shared on-disk design cache) ------
    config, serve_fmt, source = hls.CompilerConfig(n_stages=3), "5_4", \
        "default"
    if args.pipeline is not None:
        try:
            names = parse_pipeline_spec(args.pipeline)
        except ValueError as e:
            raise SystemExit(str(e))
        config = hls.CompilerConfig(pipeline=names, n_stages=3)
        source = f"--pipeline {','.join(names) or '(none)'}"

    tuned_space = db = None
    if args.tuned:
        from repro.tune import TuningDB, braggnn_space
        tuned_space = braggnn_space()
        db = TuningDB(args.db) if args.db else None
    t0 = time.perf_counter()
    # the tuned config (if any) is resolved before the single compile; a
    # TuningDB miss prints which DB path was probed
    design = hls.compile(model, name="braggnn_s1", config=config,
                         cache=True, tuned=tuned_space, db=db)
    if design.tuned_candidate is not None:
        fmt = design.tuned_candidate.get("precision", "5_4")
        serve_fmt = None if fmt == "fp32" else fmt
        source = f"tuned ({design.tuned_candidate.label()})"
    compile_s = time.perf_counter() - t0

    # report the latency of the configuration actually deployed: stage II
    # when the config pipelines, plain makespan when it does not
    stage = (f"{design.config.n_stages}-stage II={design.stage_ii}"
             if design.stage_ii is not None else "unpipelined")
    served_from = "cache" if design.session.stats()["hits"] else \
        "cold compile"
    log.info("OpenHLS schedule [%s] (%s, %.1fs): %s intervals total, "
             "%s -> %.2f us/sample "
             "(paper: 1238 total, 3-stage II=480 -> 4.8 us/sample)",
             source, served_from, compile_s, design.makespan, stage,
             design.sample_latency_us)

    # --- serve batches at the deployed precision ---------------------------
    x, y = braggnn.synthetic_peaks(jax.random.key(7), 1024)
    report = design.serve([x] * 10, fmt=serve_fmt, backend="tensor",
                          collect=True)
    pred = report.outputs[-1]
    err_px = float(jnp.mean(jnp.abs(pred / 10.0 - y))) * 11
    log.info("%s; mean localisation error %.3f px", report.summary(),
             err_px)

    # --- warm-boot artifact + async engine ---------------------------------
    if args.save:
        path = design.save(args.save, backend="tensor", fmt=serve_fmt)
        log.info("saved warm-boot artifact: %s (%s bytes)", path,
                 f"{path.stat().st_size:,}")
    if args.engine:
        serve_engine(design, serve_fmt, save_path=args.save)


if __name__ == "__main__":
    main()
