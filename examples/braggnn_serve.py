"""BraggNN low-latency inference — the paper's deployment scenario (§4.2).

    PYTHONPATH=src python examples/braggnn_serve.py
    PYTHONPATH=src python examples/braggnn_serve.py --tuned
    PYTHONPATH=src python examples/braggnn_serve.py --pipeline cse,dce

Trains BraggNN briefly on synthetic Bragg peaks, compiles the full OpenHLS
design (schedule + pipeline report next to the paper's numbers), then
serves batched peak-localisation requests through the fused reduced-
precision path — (5,4) by default, or whatever format the tuned candidate
carries — and reports throughput.

``--tuned`` auto-loads the best known compile configuration from the
persistent ``TuningDB`` (populate it with
``python -m repro.tune --config braggnn``); ``--pipeline`` overrides the
pass pipeline by hand.  Designs are cached under the shared versioned
cache root, so warm runs serve the schedule from disk.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import CompilerConfig, CompilerDriver, cache_root, frontend
from repro.core.pipeline import parse_pipeline_spec
from repro.models import braggnn
from repro.nn import module
from repro.optim import adamw


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tuned", action="store_true",
                    help="load the best compile config from the TuningDB")
    ap.add_argument("--pipeline", default=None, metavar="P1,P2,...",
                    help="override the pass pipeline (comma-separated)")
    ap.add_argument("--db", default=None,
                    help="TuningDB path (default: shared cache root)")
    return ap.parse_args(argv)


def resolve_config(args, graph):
    """(compile config, serve fmt key, source tag): tuned > --pipeline >
    default.  ``graph`` is the already-traced BraggNN DFG (tracing is the
    dominant cost — never repeat it)."""
    if args.tuned:
        from repro.tune import TuningDB, best_config_for, braggnn_space
        space = braggnn_space()
        hit = best_config_for(graph, space, db=TuningDB(args.db))
        if hit is None:
            print("--tuned: no TuningDB entry for this design/space yet — "
                  "run `python -m repro.tune --config braggnn` first; "
                  "serving the default config")
            return CompilerConfig(n_stages=3), "5_4", "default"
        config, candidate = hit
        fmt = candidate.get("precision", "5_4")
        fmt = None if fmt == "fp32" else fmt
        return config, fmt, f"tuned ({candidate.label()})"
    if args.pipeline is not None:
        try:
            names = parse_pipeline_spec(args.pipeline)
        except ValueError as e:
            raise SystemExit(str(e))
        return CompilerConfig(pipeline=names, n_stages=3), "5_4", \
            f"--pipeline {','.join(names) or '(none)'}"
    return CompilerConfig(n_stages=3), "5_4", "default"


def main(argv=None) -> None:
    args = parse_args(argv)

    # --- train briefly on synthetic peaks --------------------------------
    params = module.init_tree(braggnn.specs(1), jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=2e-3, warmup_steps=10,
                                total_steps=150, weight_decay=0.0)
    state = adamw.init_state(params)

    @jax.jit
    def step(p, s, x, y):
        def loss(pp):
            return jnp.mean((braggnn.forward(pp, x) - y * 10.0) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        p2, s2, _ = adamw.apply_updates(opt_cfg, p, g, s)
        return p2, s2, l

    key = jax.random.key(1)
    for i in range(150):
        x, y = braggnn.synthetic_peaks(jax.random.fold_in(key, i), 64)
        params, state, l = step(params, state, x, y)
    print(f"trained BraggNN: loss {float(l):.4f}")

    # --- the OpenHLS schedule (paper's deployment artifact), served from
    # --- the shared design cache on warm runs ------------------------------
    driver = CompilerDriver(cache_dir=cache_root("designs"))
    t0 = time.perf_counter()
    graph = driver.trace(lambda ctx: frontend.braggnn(ctx, s=1))
    config, serve_fmt, source = resolve_config(args, graph)
    design = driver.compile(graph, name="braggnn_s1", config=config)
    compile_s = time.perf_counter() - t0
    # report the latency of the configuration actually deployed: stage II
    # when the config pipelines, plain makespan when it does not
    stage = (f"{design.config.n_stages}-stage II={design.stage_ii}"
             if design.stage_ii is not None else "unpipelined")
    served_from = "cache" if driver.cache.hits else "cold compile"
    print(f"OpenHLS schedule [{source}] ({served_from}, {compile_s:.1f}s): "
          f"{design.makespan} intervals total, {stage} -> "
          f"{design.sample_latency_us:.2f} us/sample "
          f"(paper: 1238 total, 3-stage II=480 -> 4.8 us/sample)")

    # --- serve batches at the deployed precision ---------------------------
    infer = jax.jit(lambda p, xx: braggnn.forward(p, xx, fmt=serve_fmt))
    x, y = braggnn.synthetic_peaks(jax.random.key(7), 1024)
    jax.block_until_ready(infer(params, x))
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        pred = infer(params, x)
    jax.block_until_ready(pred)
    dt = time.perf_counter() - t0
    err_px = float(jnp.mean(jnp.abs(pred / 10.0 - y))) * 11
    fmt_label = "fp32" if serve_fmt is None else \
        f"({serve_fmt.replace('_', ',')})"
    print(f"served {reps * 1024} samples: "
          f"{dt / (reps * 1024) * 1e6:.2f} us/sample on CPU, "
          f"mean localisation error {err_px:.3f} px at {fmt_label}")


if __name__ == "__main__":
    main()
