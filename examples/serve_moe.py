"""Continuous-batching serving of a (reduced) Mixtral-style MoE with SWA —
expert routing + rolling-window KV cache through the public engine API.

    PYTHONPATH=src python examples/serve_moe.py
"""

import time

import jax

from repro import obs
from repro.configs import registry
from repro.nn import module, transformer
from repro.serving.engine import ServingEngine

log = obs.get_logger(__name__)


def main() -> None:
    obs.setup_logging()
    cfg = registry.get_tiny("mixtral-8x7b")
    params = module.init_tree(transformer.model_specs(cfg),
                              jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=4, max_len=96)

    key = jax.random.key(1)
    n_requests = 10
    for i in range(n_requests):
        k = jax.random.fold_in(key, i)
        n = 3 + int(jax.random.randint(k, (), 0, 10))
        prompt = jax.random.randint(k, (n,), 1, cfg.vocab_size).tolist()
        engine.submit(prompt, max_new_tokens=12)

    t0 = time.monotonic()
    finished = engine.run_until_drained()
    dt = time.monotonic() - t0
    s = engine.stats()
    log.info("%s: %s requests / %s tokens in %.1fs "
             "(%.1f tok/s, 4 lanes, continuous batching)",
             cfg.name, s["requests"], s["generated_tokens"], dt,
             s["generated_tokens"] / dt)
    assert len(finished) == n_requests
    assert all(len(r.output) == 12 for r in finished)
    log.info("sample output: %s", finished[0].output)


if __name__ == "__main__":
    main()
