"""Serving engines: LM continuous batching + compiled-Design request engine.

Two engines over one set of queue/latency helpers (``repro.serving.common``):

- :class:`ServingEngine` — lane-based continuous batching for LM decode.
- :class:`DesignEngine` — async adaptive batching over a compiled
  :class:`repro.hls.Design` with warm-boot restarts (``repro.hls.load``)
  and fault-tolerant request re-queuing.
"""

from repro.serving.common import QueuedRequest, RequestQueue, percentiles
from repro.serving.design_engine import DesignEngine, EngineReport, default_buckets
from repro.serving.engine import Request, ServingEngine

__all__ = [
    "DesignEngine",
    "EngineReport",
    "QueuedRequest",
    "Request",
    "RequestQueue",
    "ServingEngine",
    "default_buckets",
    "percentiles",
]
