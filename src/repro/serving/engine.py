"""LM continuous-batching engine: lanes over one jitted decode step.

A fixed pool of ``max_batch`` lanes shares one jitted decode step (one
token per lane per tick).  Requests queue; a free lane prefill-feeds the
prompt through the decode path (teacher-forced, KV written per token —
exactly the deployment pattern of a statically scheduled design: ONE
compiled program, zero dynamic shapes, the OpenHLS discipline), then the
lane switches to generation.  Finished lanes are immediately refilled from
the queue — no global barrier between requests.

Per-lane state lives in the batched KV cache; lane resets write zeros into
that lane's slice.  Works with every decoder architecture in the registry
(KV, rolling-window, RG-LRU / xLSTM recurrent state) because the cache
layout is the model's own.

Queue/request bookkeeping and the latency percentiles are the shared
:mod:`repro.serving.common` machinery — the same helpers back the
compiled-``Design`` request engine (:mod:`repro.serving.design_engine`).
The default decode step comes from ``models.lm.serve_step``, imported
lazily at construction; pass ``step_fn`` to drive a pure-decode stack
without importing the LM model code at all.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import transformer
from repro.serving.common import QueuedRequest, RequestQueue, percentiles

if TYPE_CHECKING:                                    # annotation-only import
    from repro.configs.base import ModelConfig


@dataclasses.dataclass
class Request(QueuedRequest):
    """One generation request: shared lifecycle + LM-specific fields."""

    prompt: list = dataclasses.field(default_factory=list)
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: no early stop
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    first_token_t: float = 0.0


@dataclasses.dataclass
class _Lane:
    req: Optional[Request] = None
    pos: int = 0
    feeding: int = 0               # prompt tokens still to feed


class ServingEngine:
    def __init__(self, cfg: "ModelConfig", params, *, max_batch: int = 8,
                 max_len: int = 512, step_fn: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, max_batch, max_len)
        self.lanes = [_Lane() for _ in range(max_batch)]
        self.queue = RequestQueue()
        self.finished: list[Request] = []
        if step_fn is None:
            from repro.models import lm
            step_fn = lambda p, t, c, q: lm.serve_step(cfg, p, t, c, q)
        self._step = jax.jit(step_fn)
        self._ticks = 0

    # -- API -----------------------------------------------------------------

    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               eos_id: int = -1) -> int:
        req = Request(rid=-1, payload=None, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id)
        return self.queue.push(req).rid

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        while (len(self.queue) or any(l.req for l in self.lanes)) \
                and self._ticks < max_ticks:
            self.tick()
        return self.finished

    # -- internals -------------------------------------------------------------

    def _reset_lane_cache(self, lane_idx: int) -> None:
        """Reset one lane's cache slice to its init values.

        Necessary for recurrent state (RG-LRU h, xLSTM C/n/m carry across
        positions — unlike KV entries they are not position-masked) and for
        rolling-window ``kpos`` sentinels (-1 = empty).  Each leaf's fresh
        init is written into the lane: stacked leaves carry the lane on
        axis 1 (after the layer-stack dim), remainder leaves on axis 0.
        """
        fresh = transformer.init_cache(self.cfg, 1, self.max_len)

        def put(full, one, axis):
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(lane_idx, lane_idx + 1)
            return full.at[tuple(idx)].set(one.astype(full.dtype))

        self.cache = {
            "blocks": jax.tree_util.tree_map(
                lambda f, o: put(f, o, 1), self.cache["blocks"],
                fresh["blocks"]),
            "extra": jax.tree_util.tree_map(
                lambda f, o: put(f, o, 0), self.cache["extra"],
                fresh["extra"]),
        }

    def tick(self) -> None:
        """One engine step: schedule lanes, decode one token for all."""
        self._ticks += 1
        # 1) admit queued requests into free lanes
        for li, lane in enumerate(self.lanes):
            if lane.req is None and len(self.queue):
                req = self.queue.pop()
                lane.req = req
                lane.pos = 0
                lane.feeding = len(req.prompt) - 1  # last prompt token decodes
                self._reset_lane_cache(li)

        # 2) assemble the token batch
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for li, lane in enumerate(self.lanes):
            if lane.req is None:
                continue
            req = lane.req
            if lane.pos < len(req.prompt):
                tokens[li, 0] = req.prompt[lane.pos]
            else:
                tokens[li, 0] = req.output[-1]
            pos[li] = lane.pos

        # 3) one fused decode step for the whole pool
        next_tok, self.cache = self._step(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(pos))
        next_tok = np.asarray(next_tok)

        # 4) per-lane bookkeeping
        import time
        for li, lane in enumerate(self.lanes):
            if lane.req is None:
                continue
            req = lane.req
            lane.pos += 1
            if lane.pos < len(req.prompt):
                continue                      # still feeding the prompt
            tok = int(next_tok[li])
            if not req.output:
                req.first_token_t = time.monotonic()
            req.output.append(tok)
            done = (len(req.output) >= req.max_new_tokens
                    or tok == req.eos_id
                    or lane.pos >= self.max_len - 1)
            if done:
                req.finish(result=req.output)
                self.finished.append(req)
                lane.req = None

    # -- metrics ----------------------------------------------------------------

    def stats(self) -> dict:
        lat = [r.latency_s for r in self.finished if r.done_t]
        ttft = [r.first_token_t - r.submit_t for r in self.finished
                if r.first_token_t]
        toks = sum(len(r.output) for r in self.finished)
        pct = percentiles(lat)
        return {"requests": len(self.finished), "generated_tokens": toks,
                "ticks": self._ticks,
                "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
                "p50_latency_s": pct["p50"], "p95_latency_s": pct["p95"],
                "p99_latency_s": pct["p99"],
                "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
                "max_queue_depth": self.queue.max_depth,
                "mean_queue_depth": self.queue.mean_depth}
