"""Async request-queue serving engine over compiled ``Design`` artifacts.

``Design.serve`` is a warmed *synchronous* loop: the caller owns batching
and blocks per batch.  This engine is the deployment-shaped front: callers
:meth:`~DesignEngine.submit` single samples from any thread; a dispatcher
accumulates them in a thread-safe queue and fires a batch when either

  * **size trigger** — the queue reaches the largest bucket, or
  * **deadline trigger** — the oldest request has waited ``max_delay_ms``

whichever comes first.  Dispatched batch sizes are snapped to a small set
of pre-warmed **bucket** shapes (padding up to the next bucket when a
deadline flush catches a partial batch), so every dispatch hits an
already-jitted program — no recompiles on the hot path, the OpenHLS
static-shape discipline applied to serving.

Fault tolerance wires :mod:`repro.runtime.fault` in: an optional
``FailureInjector`` poisons chosen dispatches (tests), any dispatch
exception triggers a replica restart — re-booting from the saved
``Design.save`` artifact when ``artifact_path`` is given — and the failed
batch is re-queued at the head *in order*, so no request is dropped and a
drained rerun is bit-identical to an uninterrupted one.  A
``StepWatchdog`` records straggler dispatches.

All three emission backends serve: ``tensor`` (fused jit forward),
``simd`` (emitted design), ``pallas`` (compiled rendering).  The engine
reports sustained QPS, p50/p95/p99 latency and queue depth — the numbers
``benchmarks/bench_serving.py`` tracks instead of µs/sample-in-a-warm-loop.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from pathlib import Path
from typing import Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.runtime.fault import FailureInjector, StepWatchdog
from repro.serving.common import QueuedRequest, RequestQueue, percentiles


def default_buckets(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to ``max_batch`` (plus ``max_batch`` itself): the
    pre-warmed dispatch shapes."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets = []
    b = 1
    while b < max_batch:
        buckets.append(b)
        b *= 2
    buckets.append(max_batch)
    return tuple(buckets)


@dataclasses.dataclass
class EngineReport:
    """Telemetry of one :class:`DesignEngine` lifetime.

    Comparable with :class:`repro.hls.ServeReport` — both carry
    p50/p95/p99 latency and queue-depth fields, so the sync and async
    serving paths land in one table.
    """

    backend: str
    fmt: Optional[str]
    #: last replica boot time (runner build + bucket warm-up), seconds
    boot_s: float = 0.0
    #: source of every replica boot, in order: "memory" or "artifact"
    boots: list = dataclasses.field(default_factory=list)
    submitted: int = 0
    completed: int = 0
    dropped: int = 0
    retried: int = 0
    restarts: int = 0
    dispatches: int = 0
    #: bucket size -> dispatch count
    batch_hist: dict = dataclasses.field(default_factory=dict)
    padded_samples: int = 0
    wall_s: float = 0.0
    #: cumulative on-device batch compute time across dispatches, seconds
    compute_s: float = 0.0
    qps: float = 0.0
    p50_ms: float = 0.0
    p95_ms: float = 0.0
    p99_ms: float = 0.0
    mean_ms: float = 0.0
    max_queue_depth: int = 0
    #: time-weighted over the full queue-depth transition log (idle and
    #: ramp periods included), not just the instants a dispatch sampled
    mean_queue_depth: float = 0.0
    p95_queue_depth: float = 0.0
    straggler_dispatches: list = dataclasses.field(default_factory=list)
    #: what actually served (the Pallas plan summary when applicable)
    served: Optional[str] = None
    fallbacks: list = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        fmt = "fp32" if self.fmt in (None, "fp32") else \
            f"({self.fmt.replace('_', ',')})"
        hist = ", ".join(f"{b}x{n}" for b, n in sorted(self.batch_hist.items()))
        return (f"served {self.completed}/{self.submitted} requests @ "
                f"{self.qps:.1f} req/s: p50 {self.p50_ms:.2f} / "
                f"p95 {self.p95_ms:.2f} / p99 {self.p99_ms:.2f} ms "
                f"[{self.served or self.backend} backend, {fmt}; "
                f"{self.dispatches} dispatches ({hist}), "
                f"max queue {self.max_queue_depth}, "
                f"{self.restarts} restarts, {self.dropped} dropped; "
                f"boot {self.boot_s:.2f}s]")


class DesignEngine:
    """Adaptive-batching request engine fronting one compiled ``Design``.

    Construct via :meth:`repro.hls.Design.engine` (which defaults
    ``backend``/``fmt``/``buckets`` from the saved artifact's warmed-bucket
    manifest when the design was loaded with ``hls.load``).

    Two run modes:

      * **threaded** — ``start()`` (or the context manager) spawns the
        dispatcher; ``submit`` from any thread; ``stop()`` drains and
        joins.  The open-loop load generators drive this mode.
      * **synchronous** — without ``start()``, ``submit`` everything and
        call :meth:`run_until_drained`; dispatch grouping is then
        deterministic (head-of-queue batches of ``min(pending,
        max_batch)``), which is what the bit-identity tests rely on.
    """

    def __init__(self, design, *, backend: Optional[str] = None,
                 fmt: Optional[str] = None,
                 buckets: Optional[Sequence[int]] = None,
                 max_batch: int = 32, max_delay_ms: float = 2.0,
                 artifact_path: Optional[Union[str, Path]] = None,
                 injector: Optional[FailureInjector] = None,
                 watchdog: Optional[StepWatchdog] = None,
                 max_restarts: int = 4, max_retries: int = 2,
                 pallas_kw: Optional[dict] = None, warm: bool = True):
        if backend is None:
            module = design.module
            backend = ("tensor" if module is not None
                       and module.forward_fn is not None
                       and module.params is not None else "simd")
        self.backend = backend
        self.fmt = fmt
        self.buckets = (tuple(sorted(set(int(b) for b in buckets)))
                        if buckets else default_buckets(max_batch))
        if self.buckets[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1: {self.buckets}")
        self.max_batch = self.buckets[-1]
        self.max_delay_s = max_delay_ms * 1e-3
        self.artifact_path = Path(artifact_path) if artifact_path else None
        self.injector = injector or FailureInjector()
        self.watchdog = watchdog or StepWatchdog()
        self.max_restarts = max_restarts
        self.max_retries = max_retries
        self.pallas_kw = dict(pallas_kw or {})

        self._design = design
        self._input_name, self._input_shape = design._input_memref()
        if backend == "tensor" and self._input_shape[0] != 1:
            raise ValueError(
                f"tensor backend batches over the memref's leading "
                f"singleton axis; input {self._input_name!r} has shape "
                f"{self._input_shape}")
        self._queue = RequestQueue()
        self._finished: list[QueuedRequest] = []
        self._report = EngineReport(backend=backend, fmt=fmt)
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._run_one = None
        if warm:
            self._boot("memory")

    # -- replica lifecycle --------------------------------------------------

    def _boot(self, source: str) -> float:
        """(Re)build the serving replica and warm every bucket shape.

        ``source='artifact'`` re-loads the design from ``artifact_path``
        (the warm-boot path a restarted replica takes); ``'memory'``
        rebuilds from the in-process design.  Returns the boot wall time.
        """
        import jax
        t0 = time.perf_counter()
        with obs.span("serve.boot", cat="serve", source=source,
                      backend=self.backend, buckets=list(self.buckets)):
            if source == "artifact":
                import repro.hls as hls
                self._design = hls.load(self.artifact_path)
            self._run_one, served, fallbacks = self._design._runner(
                self.backend, self.fmt, self.pallas_kw)
            self._report.served = served
            self._report.fallbacks = list(fallbacks)
            for b in self.buckets:                   # pre-warm every shape
                zeros = np.zeros((b,) + self._input_shape, np.float32)
                jax.block_until_ready(
                    self._run_one(self._as_backend_batch(zeros)))
        boot_s = time.perf_counter() - t0
        obs.inc("serve.boots")
        self._report.boot_s = boot_s
        self._report.boots.append(source)
        return boot_s

    # -- submission ---------------------------------------------------------

    def _coerce_sample(self, x) -> np.ndarray:
        arr = np.asarray(x, dtype=np.float32)
        shape = self._input_shape
        if arr.shape == tuple(shape):
            return arr
        if shape[0] == 1 and arr.shape == tuple(shape)[1:]:
            return arr[None]
        raise ValueError(
            f"sample shape {arr.shape} does not match input memref "
            f"{self._input_name!r} shape {tuple(shape)}")

    def submit(self, x) -> QueuedRequest:
        """Enqueue one sample; returns the request (its own future —
        ``req.wait()`` blocks for the per-sample output)."""
        if self._stop_evt.is_set():
            raise RuntimeError("engine is stopped")
        req = self._queue.submit(self._coerce_sample(x))
        if self._t_first is None:
            self._t_first = req.submit_t
        return req

    def submit_many(self, xs) -> list[QueuedRequest]:
        return [self.submit(x) for x in xs]

    # -- dispatch -----------------------------------------------------------

    def _as_backend_batch(self, stacked: np.ndarray):
        """A (bucket,)+memref batch -> what this backend's runner takes."""
        if self.backend == "tensor":
            # collapse the memref's per-sample singleton batch axis into
            # the throughput batch (the fused forward is (B, C, H, W))
            return stacked.reshape(stacked.shape[0],
                                   *self._input_shape[1:])
        return stacked        # simd/pallas runners coerce via design.feeds

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_batch

    def _split(self, out, i: int):
        if isinstance(out, dict):
            return {k: np.asarray(v)[i] for k, v in out.items()}
        return np.asarray(out)[i]

    def _dispatch(self, reqs: list[QueuedRequest]) -> None:
        """Run one snapped batch; on failure, restart the replica and
        re-queue the batch at the head (never dropped, never reordered)."""
        import jax
        rep = self._report
        idx = rep.dispatches
        rep.dispatches += 1
        bucket = self._bucket_for(len(reqs))
        now = time.monotonic()
        for r in reqs:
            r.start_t = now
        stacked = np.stack([r.payload for r in reqs])
        if bucket > len(reqs):
            rep.padded_samples += bucket - len(reqs)
            pad = np.zeros((bucket - len(reqs),) + self._input_shape,
                           np.float32)
            stacked = np.concatenate([stacked, pad])
        obs.inc("serve.dispatches")
        obs.inc("serve.padded_samples", bucket - len(reqs))
        obs.observe("serve.batch_occupancy", len(reqs) / bucket)
        with obs.span("serve.dispatch", cat="serve", dispatch=idx,
                      n=len(reqs), bucket=bucket,
                      padded=bucket - len(reqs)) as disp_sp:
            t0 = time.perf_counter()
            try:
                self.injector.check(idx)
                out = jax.block_until_ready(
                    self._run_one(self._as_backend_batch(stacked)))
            except Exception as exc:
                rep.restarts += 1
                obs.inc("serve.restarts")
                disp_sp.set(error=type(exc).__name__)
                if rep.restarts > self.max_restarts:
                    for r in reqs:
                        r.finish(error=exc)
                    rep.dropped += len(reqs)
                    obs.inc("serve.requests_dropped", len(reqs))
                    self._record_request_spans(reqs, idx, bucket)
                    self._finished.extend(reqs)
                    return
                keep = [r for r in reqs if r.retries < self.max_retries]
                for r in reqs:
                    if r.retries >= self.max_retries:
                        r.finish(error=exc)
                        rep.dropped += 1
                        obs.inc("serve.requests_dropped")
                        self._record_request_spans([r], idx, bucket)
                        self._finished.append(r)
                rep.retried += len(keep)
                self._queue.requeue_front(keep)
                self._boot("artifact" if self.artifact_path else "memory")
                return
            dt = time.perf_counter() - t0
            disp_sp.set(compute_ms=round(dt * 1e3, 3))
        self.watchdog.observe(idx, dt)
        rep.compute_s += dt
        rep.batch_hist[bucket] = rep.batch_hist.get(bucket, 0) + 1
        for i, r in enumerate(reqs):
            r.finish(result=self._split(out, i))
        rep.completed += len(reqs)
        obs.inc("serve.requests_completed", len(reqs))
        self._record_request_spans(reqs, idx, bucket)
        self._finished.extend(reqs)
        self._t_last = time.monotonic()

    def _record_request_spans(self, reqs: list[QueuedRequest], idx: int,
                              bucket: int) -> None:
        """One async span per finished request (submit -> complete),
        linked to its dispatch by the ``dispatch`` attribute."""
        if not obs.enabled():
            return
        for r in reqs:
            obs.record_span(
                "serve.request", r.submit_t, r.done_t, cat="serve",
                kind="async", rid=r.rid, dispatch=idx, bucket=bucket,
                retries=r.retries, error=type(r.error).__name__
                if r.error is not None else None,
                queued_ms=round((r.start_t - r.submit_t) * 1e3, 3))

    def _dispatch_ready(self, *, flush: bool) -> bool:
        """Dispatch one batch if a trigger fired; True when work was done.

        Size trigger: pending >= the largest bucket (dispatched unpadded).
        Deadline trigger (or ``flush``): oldest request waited past
        ``max_delay_ms`` — dispatch what is pending, padded up to the next
        bucket so the shape is pre-warmed.
        """
        n = len(self._queue)
        if n == 0:
            return False
        if n < self.max_batch and not flush:
            age = self._queue.oldest_age_s()
            if age is None or age < self.max_delay_s:
                return False
        reqs = self._queue.pop_batch(min(n, self.max_batch))
        if reqs:
            self._dispatch(reqs)
        return bool(reqs)

    def run_until_drained(self) -> None:
        """Synchronous mode: dispatch head-of-queue batches until empty."""
        while self._dispatch_ready(flush=True):
            pass

    # -- threaded mode ------------------------------------------------------

    #: dispatcher-loop queue-depth sampling interval (timer-driven, so
    #: idle/ramp depth lands in the telemetry between dispatches)
    DEPTH_SAMPLE_S = 0.005

    def _loop(self) -> None:
        last_sample = time.monotonic()
        while True:
            now = time.monotonic()
            if now - last_sample >= self.DEPTH_SAMPLE_S:
                last_sample = now
                self._queue.sample_depth()
            if self._stop_evt.is_set():
                if not self._dispatch_ready(flush=True):
                    return
                continue
            if not self._queue.wait_for_work(timeout=0.005):
                continue
            if not self._dispatch_ready(flush=False):
                # a partial batch inside its deadline window: sleep a
                # slice, re-check (the queue may reach the size trigger)
                age = self._queue.oldest_age_s()
                if age is not None:
                    time.sleep(max(0.0, min(self.max_delay_s - age, 1e-3)))

    def start(self) -> "DesignEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="design-engine", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue, then stop the dispatcher."""
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        else:
            self.run_until_drained()

    def __enter__(self) -> "DesignEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- reporting ----------------------------------------------------------

    def report(self) -> EngineReport:
        rep = self._report
        rep.submitted = self._queue.submitted
        lats = [r.latency_s for r in self._finished if r.error is None]
        pct = percentiles(lats)
        rep.p50_ms = pct["p50"] * 1e3
        rep.p95_ms = pct["p95"] * 1e3
        rep.p99_ms = pct["p99"] * 1e3
        rep.mean_ms = float(np.mean(lats)) * 1e3 if lats else 0.0
        depth = self._queue.depth_stats()
        rep.max_queue_depth = depth["max"]
        rep.mean_queue_depth = round(depth["mean"], 2)
        rep.p95_queue_depth = round(depth["p95"], 2)
        rep.straggler_dispatches = list(self.watchdog.stragglers)
        if self._t_first is not None and self._t_last is not None \
                and self._t_last > self._t_first:
            rep.wall_s = self._t_last - self._t_first
            rep.qps = rep.completed / rep.wall_s
        if rep.completed and rep.compute_s:
            obs.gauge(f"serve.us_per_sample.{self.backend}",
                      rep.compute_s / rep.completed * 1e6)
        return rep
