"""Queue/lane bookkeeping shared by both serving engines.

The LM continuous-batching engine (:mod:`repro.serving.engine`) and the
compiled-``Design`` request engine (:mod:`repro.serving.design_engine`)
need the same machinery: request identity + lifecycle timestamps, a
thread-safe FIFO with depth telemetry, and tail-latency percentiles.  It
lives here once instead of being copy-pasted per engine; nothing in this
module imports models, configs or the compiler, so either engine can be
used standalone.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional, Sequence

import numpy as np


def percentiles(values: Sequence[float],
                pcts: Sequence[int] = (50, 95, 99)) -> dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` over ``values`` (0.0 when
    empty) — the tail-latency summary both serve reports share."""
    if not len(values):
        return {f"p{p}": 0.0 for p in pcts}
    arr = np.asarray(values, dtype=np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in pcts}


@dataclasses.dataclass
class QueuedRequest:
    """One queued unit of work plus its lifecycle timestamps.

    ``payload`` is engine-defined (an input sample for the design engine, a
    token prompt for the LM engine).  The submit/start/done timestamps give
    per-request latency; ``retries`` counts re-queues after a replica
    failure.  ``wait()``/``ready`` make the request its own future: the
    dispatching engine fills ``result`` (or ``error``) and sets the event.
    """

    rid: int
    payload: Any
    submit_t: float = 0.0
    start_t: float = 0.0
    done_t: float = 0.0
    retries: int = 0
    result: Any = None
    error: Optional[BaseException] = None
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def ready(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        """Block until the engine finished this request; returns the result
        (re-raising the engine-side error, if any)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done "
                               f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    def finish(self, result: Any = None,
               error: Optional[BaseException] = None) -> None:
        self.done_t = time.monotonic()
        self.result = result
        self.error = error
        self._done.set()

    @property
    def latency_s(self) -> float:
        return self.done_t - self.submit_t if self.done_t else 0.0


class RequestQueue:
    """Thread-safe FIFO of :class:`QueuedRequest` with depth telemetry.

    Owns rid assignment and the submit timestamp so every engine reports
    comparable latencies.  ``depth_samples`` records the queue depth at
    each submit/pop — max/mean queue depth is the load-generator-facing
    congestion signal.  ``requeue_front`` puts a failed batch back at the
    head *in order*, which is what keeps replica restarts from dropping
    or reordering in-flight requests.
    """

    def __init__(self):
        self._items: list[QueuedRequest] = []
        self._cond = threading.Condition()
        self._next_rid = 0
        self.submitted = 0
        self.depth_samples: list[int] = []

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    def submit(self, payload: Any) -> QueuedRequest:
        return self.push(QueuedRequest(rid=-1, payload=payload))

    def push(self, req: QueuedRequest) -> QueuedRequest:
        """Enqueue a pre-built request (engines subclass
        :class:`QueuedRequest` with their own fields); the queue owns rid
        assignment and the submit timestamp."""
        with self._cond:
            req.rid = self._next_rid
            req.submit_t = time.monotonic()
            self._next_rid += 1
            self._items.append(req)
            self.submitted += 1
            self.depth_samples.append(len(self._items))
            self._cond.notify_all()
            return req

    def pop(self) -> Optional[QueuedRequest]:
        """Pop the oldest request (None when empty)."""
        batch = self.pop_batch(1)
        return batch[0] if batch else None

    def pop_batch(self, n: int) -> list[QueuedRequest]:
        """Pop up to ``n`` requests preserving FIFO order."""
        with self._cond:
            taken, self._items = self._items[:n], self._items[n:]
            if taken:
                self.depth_samples.append(len(self._items))
            return taken

    def requeue_front(self, reqs: Sequence[QueuedRequest]) -> None:
        """Put ``reqs`` back at the head (in the given order) after a
        replica failure; bumps each request's retry counter."""
        with self._cond:
            for r in reqs:
                r.retries += 1
            self._items[:0] = list(reqs)
            self._cond.notify_all()

    def oldest_age_s(self) -> Optional[float]:
        """Age of the head request (None when empty) — the deadline
        trigger's input."""
        with self._cond:
            if not self._items:
                return None
            return time.monotonic() - self._items[0].submit_t

    def wait_for_work(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is non-empty (or timeout); True if work."""
        with self._cond:
            if self._items:
                return True
            self._cond.wait(timeout)
            return bool(self._items)

    # -- telemetry ----------------------------------------------------------

    @property
    def max_depth(self) -> int:
        return max(self.depth_samples, default=0)

    @property
    def mean_depth(self) -> float:
        return (float(np.mean(self.depth_samples))
                if self.depth_samples else 0.0)
