"""Queue/lane bookkeeping shared by the serving engines and the trigger.

The LM continuous-batching engine (:mod:`repro.serving.engine`), the
compiled-``Design`` request engine (:mod:`repro.serving.design_engine`)
and the hard-real-time trigger loop (:mod:`repro.trigger.stream`) need
the same machinery: request identity + lifecycle timestamps, thread-safe
queues with depth telemetry, and tail-latency percentiles.  It lives
here once instead of being copy-pasted per engine; nothing in this
module imports models, configs or the compiler, so every consumer can be
used standalone.

Two queue disciplines, two worlds:

  * :class:`RequestQueue` — unbounded FIFO; a slow server grows the
    queue (request/response serving, where dropping is the failure);
  * :class:`DropOldestRing` — bounded ring that *never* blocks or grows;
    a slow consumer loses the **oldest** entries (streaming front-ends,
    where back-pressuring the producer — a detector — is the failure).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Optional, Sequence

import numpy as np

from repro import obs


def percentiles(values: Sequence[float],
                pcts: Sequence[int] = (50, 95, 99)) -> dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` over ``values`` (0.0 when
    empty) — the tail-latency summary both serve reports share.  NaNs are
    rejected rather than poisoning every percentile; an all-NaN or empty
    input reports zeros."""
    if not len(values):
        return {f"p{p}": 0.0 for p in pcts}
    arr = np.asarray(values, dtype=np.float64)
    arr = arr[~np.isnan(arr)]
    if not arr.size:
        return {f"p{p}": 0.0 for p in pcts}
    return {f"p{p}": float(np.percentile(arr, p)) for p in pcts}


@dataclasses.dataclass
class QueuedRequest:
    """One queued unit of work plus its lifecycle timestamps.

    ``payload`` is engine-defined (an input sample for the design engine, a
    token prompt for the LM engine).  The submit/start/done timestamps give
    per-request latency; ``retries`` counts re-queues after a replica
    failure.  ``wait()``/``ready`` make the request its own future: the
    dispatching engine fills ``result`` (or ``error``) and sets the event.
    """

    rid: int
    payload: Any
    submit_t: float = 0.0
    start_t: float = 0.0
    done_t: float = 0.0
    retries: int = 0
    result: Any = None
    error: Optional[BaseException] = None
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def ready(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        """Block until the engine finished this request; returns the result
        (re-raising the engine-side error, if any)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done "
                               f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    def finish(self, result: Any = None,
               error: Optional[BaseException] = None) -> None:
        self.done_t = time.monotonic()
        self.result = result
        self.error = error
        self._done.set()

    @property
    def latency_s(self) -> float:
        return self.done_t - self.submit_t if self.done_t else 0.0


class RequestQueue:
    """Thread-safe FIFO of :class:`QueuedRequest` with depth telemetry.

    Owns rid assignment and the submit timestamp so every engine reports
    comparable latencies.  Depth telemetry is recorded two ways: the
    legacy ``depth_samples`` value list, and ``depth_events`` — the full
    ``(monotonic_t, depth)`` transition log from every push/pop/requeue
    plus any timer-driven ``sample_depth()`` calls.  ``depth_stats()``
    integrates that step function for *time-weighted* mean/p95/max, so a
    bursty queue that sits deep between dispatches is reported at its
    true depth instead of only at the instants the engine touched it.
    ``requeue_front`` puts a failed batch back at the head *in order*,
    which is what keeps replica restarts from dropping or reordering
    in-flight requests.
    """

    def __init__(self):
        self._items: list[QueuedRequest] = []
        self._cond = threading.Condition()
        self._next_rid = 0
        self.submitted = 0
        self.depth_samples: list[int] = []
        self.depth_events: list[tuple[float, int]] = []

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    def submit(self, payload: Any) -> QueuedRequest:
        return self.push(QueuedRequest(rid=-1, payload=payload))

    def push(self, req: QueuedRequest) -> QueuedRequest:
        """Enqueue a pre-built request (engines subclass
        :class:`QueuedRequest` with their own fields); the queue owns rid
        assignment and the submit timestamp."""
        with self._cond:
            req.rid = self._next_rid
            req.submit_t = time.monotonic()
            self._next_rid += 1
            self._items.append(req)
            self.submitted += 1
            self._note_depth()
            self._cond.notify_all()
            return req

    def pop(self) -> Optional[QueuedRequest]:
        """Pop the oldest request (None when empty)."""
        batch = self.pop_batch(1)
        return batch[0] if batch else None

    def pop_batch(self, n: int) -> list[QueuedRequest]:
        """Pop up to ``n`` requests preserving FIFO order."""
        with self._cond:
            taken, self._items = self._items[:n], self._items[n:]
            if taken:
                self._note_depth()
            return taken

    def requeue_front(self, reqs: Sequence[QueuedRequest]) -> None:
        """Put ``reqs`` back at the head (in the given order) after a
        replica failure; bumps each request's retry counter."""
        with self._cond:
            for r in reqs:
                r.retries += 1
            self._items[:0] = list(reqs)
            if reqs:
                self._note_depth()
            self._cond.notify_all()

    def oldest_age_s(self) -> Optional[float]:
        """Age of the head request (None when empty) — the deadline
        trigger's input."""
        with self._cond:
            if not self._items:
                return None
            return time.monotonic() - self._items[0].submit_t

    def wait_for_work(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is non-empty (or timeout); True if work."""
        with self._cond:
            if self._items:
                return True
            self._cond.wait(timeout)
            return bool(self._items)

    # -- telemetry ----------------------------------------------------------

    def _note_depth(self) -> None:
        """Record the current depth (call under ``self._cond``)."""
        depth = len(self._items)
        self.depth_samples.append(depth)
        self.depth_events.append((time.monotonic(), depth))
        obs.observe("serve.queue_depth", depth)

    def sample_depth(self) -> int:
        """Timer-driven depth observation (the engine loop calls this so
        idle/ramp periods appear in the telemetry, not just the instants a
        push or dispatch happened to touch the queue)."""
        with self._cond:
            self._note_depth()
            return len(self._items)

    @property
    def max_depth(self) -> int:
        return max(self.depth_samples, default=0)

    @property
    def mean_depth(self) -> float:
        return (float(np.mean(self.depth_samples))
                if self.depth_samples else 0.0)

    def depth_stats(self) -> dict[str, float]:
        """Time-weighted depth statistics over the transition log.

        Each recorded depth holds from its event until the next one; the
        step function is integrated exactly, so 300 ms spent at depth 8
        dominates a handful of instantaneous dispatch touches.  With
        fewer than two events this degrades to the plain values.  Returns
        ``{"max", "mean", "p95"}``.
        """
        with self._cond:
            events = list(self.depth_events)
        if not events:
            return {"max": 0, "mean": 0.0, "p95": 0.0}
        if len(events) == 1:
            d = float(events[0][1])
            return {"max": int(d), "mean": d, "p95": d}
        total = events[-1][0] - events[0][0]
        if total <= 0:
            vals = [d for _, d in events]
            return {"max": max(vals), "mean": float(np.mean(vals)),
                    "p95": float(np.percentile(vals, 95))}
        weight: dict[int, float] = {}
        for (t0, d), (t1, _) in zip(events, events[1:]):
            weight[d] = weight.get(d, 0.0) + (t1 - t0)
        mean = sum(d * w for d, w in weight.items()) / total
        p95 = float(max(weight))       # fallback if rounding never trips
        acc = 0.0
        for d in sorted(weight):
            acc += weight[d]
            if acc >= 0.95 * total:
                p95 = float(d)
                break
        return {"max": max(d for _, d in events), "mean": mean, "p95": p95}


class DropOldestRing:
    """Bounded buffer whose producer can never be blocked or slowed.

    Pushing onto a full ring evicts the **oldest** entry (returned to the
    caller, counted in ``dropped``) instead of blocking, growing, or
    refusing — the overrun policy of a hard-real-time front-end: a
    trigger must never back-pressure the detector, and when it falls
    behind the *stalest* frames are the right ones to lose.  A single
    mutex guards O(1) deque operations, so the producer-side critical
    section is a few dozen nanoseconds — not lock-free, but never
    producer-visible at detector frame rates.

    FIFO otherwise: ``pop``/``pop_many`` return survivors oldest-first.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._items: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self.pushed = 0
        self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def push(self, item: Any) -> Optional[Any]:
        """Append ``item``; returns the evicted oldest entry on overrun
        (``None`` when the ring had room)."""
        with self._lock:
            evicted = None
            if len(self._items) >= self.capacity:
                evicted = self._items.popleft()
                self.dropped += 1
            self._items.append(item)
            self.pushed += 1
        if evicted is not None:
            obs.inc("trigger.dropped_frames")
        return evicted

    def pop(self) -> Optional[Any]:
        """The oldest surviving entry, or ``None`` when empty."""
        with self._lock:
            return self._items.popleft() if self._items else None

    def pop_many(self, n: int) -> list:
        """Up to ``n`` oldest survivors, oldest-first."""
        with self._lock:
            out = []
            while self._items and len(out) < n:
                out.append(self._items.popleft())
            return out

    def clear(self) -> None:
        with self._lock:
            self._items.clear()
