"""repro: OpenHLS reproduced as a JAX/TPU framework.

Subpackages:
    hls         — THE public API: ``hls.compile(model) -> Design`` with
                  run/verify/tune/serve/report, plus the nn -> loop-nest
                  auto-lowering bridge
    core        — the paper's compiler (symbolic interpretation, passes,
                  scheduling, precision, binding, verification); stable
                  internal layer under ``repro.hls``
    nn          — model substrate (layers, attention, MoE, RG-LRU, xLSTM)
    models      — assembled models (CausalLM, BraggNN, encoder-decoder)
    kernels     — Pallas TPU kernels with jnp oracles
    configs     — assigned architectures + shapes
    launch      — mesh construction, dry-run, roofline, train/serve drivers
    trigger     — hard-real-time streaming trigger: part catalog,
                  latency/resource budgets, deadline-accounted stream loop
    data/optim/checkpoint/runtime/serving — production substrate
"""

__version__ = "1.0.0"
