"""The nn -> loop-nest auto-lowering bridge (the headline of ``repro.hls``).

Walks a :class:`repro.nn.graph.ModuleGraph` and emits the corresponding
``repro.core.frontend`` loop nests under a symbolic-interpretation
``Context`` — the missing link between "model described once, at the
tensor level" and the paper's scalar loop-nest programs.  Every node type
lowers through the *same* frontend function the hand-written programs use
(``conv2d``, ``linear``, ``non_local_block``, ...), so a module graph whose
names pin the hand-written memref/label scheme produces a bit-identical
DFG: ``hls.compile(models.braggnn.build(s))`` and the hand-written
``frontend.braggnn`` share one ``graph_fingerprint`` (proved by
``tests/test_hls_bridge.py``), and therefore one design-cache and
``TuningDB`` identity — tuning wins found on either path serve both.
"""

from __future__ import annotations

from typing import Callable

from repro.core import frontend
from repro.core.interp import Context, MemRef
from repro.nn.graph import (MLP, Attention, BatchNorm2d, Conv2d, Flatten,
                            Linear, MaxPool2d, ModuleGraph, NonLocalBlock,
                            OutputReLU, ReLU, RMSNorm, Softmax)


def _emit_conv2d(ctx: Context, node: Conv2d, cur: MemRef,
                 shape: tuple, kind: str) -> MemRef:
    w = ctx.memref(f"{node.prefix}.weight",
                   (node.out_channels, node.in_channels, node.kernel,
                    node.kernel), "weight")
    b = ctx.memref(f"{node.prefix}.bias", (node.out_channels,), "weight") \
        if node.bias else None
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.conv2d(ctx, cur, w, b, out, stride=node.stride,
                    padding=node.padding, label=node.label)
    return out


def _emit_linear(ctx: Context, node: Linear, cur: MemRef,
                 shape: tuple, kind: str) -> MemRef:
    w = ctx.memref(f"{node.prefix}.weight",
                   (node.out_features, node.in_features), "weight")
    b = ctx.memref(f"{node.prefix}.bias", (node.out_features,), "weight") \
        if node.bias else None
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.linear(ctx, cur, w, b, out, label=node.label)
    return out


def _emit_batch_norm(ctx: Context, node: BatchNorm2d, cur: MemRef,
                     shape: tuple, kind: str) -> MemRef:
    mems = {leaf: ctx.memref(f"{node.prefix}.{leaf}", (node.channels,),
                             "weight")
            for leaf in ("gamma", "beta", "mean", "var")}
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.batch_norm_2d(ctx, cur, mems["gamma"], mems["beta"],
                           mems["mean"], mems["var"], out, eps=node.eps,
                           label=node.label)
    return out


def _emit_relu(ctx: Context, node: ReLU, cur: MemRef,
               shape: tuple, kind: str) -> MemRef:
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.relu_layer(ctx, cur, out, label=node.label)
    return out


def _emit_output_relu(ctx: Context, node: OutputReLU, cur: MemRef,
                      shape: tuple, kind: str) -> MemRef:
    # in-place: rewrite the previous node's (output) symbol table, one
    # sequential nest per element — frontend.braggnn's final-ReLU form
    for idx in list(cur.table.keys()):
        with ctx.sequential(label=node.label):
            cur.table[idx] = ctx.relu(cur.table[idx])
    return cur


def _emit_max_pool(ctx: Context, node: MaxPool2d, cur: MemRef,
                   shape: tuple, kind: str) -> MemRef:
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.max_pool_2d(ctx, cur, out, k=node.kernel, stride=node.stride,
                         label=node.label)
    return out


def _emit_softmax(ctx: Context, node: Softmax, cur: MemRef,
                  shape: tuple, kind: str) -> MemRef:
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.soft_max(ctx, cur, out, taylor_order=node.taylor_order,
                      label=node.label)
    return out


def _emit_nlb(ctx: Context, node: NonLocalBlock, cur: MemRef,
              shape: tuple, kind: str) -> MemRef:
    if kind == "output":
        raise ValueError("NonLocalBlock cannot be the output node")
    return frontend.non_local_block(
        ctx, cur, channels=node.channels, mid_channels=node.mid_channels,
        prefix=node.prefix, taylor_order=node.taylor_order)


def _emit_flatten(ctx: Context, node: Flatten, cur: MemRef,
                  shape: tuple, kind: str) -> MemRef:
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.copy_reshape(cur, out)
    return out


def _emit_rms_norm(ctx: Context, node: RMSNorm, cur: MemRef,
                   shape: tuple, kind: str) -> MemRef:
    gamma = ctx.memref(f"{node.prefix}.gamma", (shape[-1],), "weight")
    out = ctx.memref(node.out_name, node.out_shape(shape), kind)
    frontend.rms_norm(ctx, cur, gamma, out, eps=node.eps, label=node.label)
    return out


def _emit_attention(ctx: Context, node: Attention, cur: MemRef,
                    shape: tuple, kind: str) -> MemRef:
    l, d = shape
    h, dh = node.n_heads, node.head_dim
    src = cur
    if node.pre_norm:
        gamma = ctx.memref(f"{node.prefix}.norm.gamma", (d,), "weight")
        src = ctx.temp(f"{node.name}_norm", (l, d))
        frontend.rms_norm(ctx, cur, gamma, src, eps=node.eps,
                          label=f"{node.label}.norm")
    wq = ctx.memref(f"{node.prefix}.q.kernel", (d, h, dh), "weight")
    wk = ctx.memref(f"{node.prefix}.k.kernel", (d, h, dh), "weight")
    wv = ctx.memref(f"{node.prefix}.v.kernel", (d, h, dh), "weight")
    wo = ctx.memref(f"{node.prefix}.o.kernel", (h, dh, d), "weight")
    mix = ctx.temp(f"{node.name}_mix", (l, d)) if node.residual \
        else ctx.memref(node.out_name, (l, d), kind)
    frontend.attention(ctx, src, wq, wk, wv, wo, mix, n_heads=h,
                       taylor_order=node.taylor_order, label=node.label)
    if not node.residual:
        return mix
    out = ctx.memref(node.out_name, (l, d), kind)
    frontend.add_residual(ctx, mix, cur, out, label=f"{node.label}.residual")
    return out


def _emit_mlp(ctx: Context, node: MLP, cur: MemRef,
              shape: tuple, kind: str) -> MemRef:
    l, d = shape
    src = cur
    if node.pre_norm:
        gamma = ctx.memref(f"{node.prefix}.norm.gamma", (d,), "weight")
        src = ctx.temp(f"{node.name}_norm", (l, d))
        frontend.rms_norm(ctx, cur, gamma, src, eps=node.eps,
                          label=f"{node.label}.norm")
    w1 = ctx.memref(f"{node.prefix}.fc1.weight", (node.hidden, d), "weight")
    b1 = ctx.memref(f"{node.prefix}.fc1.bias", (node.hidden,), "weight")
    w2 = ctx.memref(f"{node.prefix}.fc2.weight", (d, node.hidden), "weight")
    b2 = ctx.memref(f"{node.prefix}.fc2.bias", (d,), "weight")
    fc = ctx.temp(f"{node.name}_fc", (l, d)) if node.residual \
        else ctx.memref(node.out_name, (l, d), kind)
    frontend.mlp(ctx, src, w1, b1, w2, b2, fc, label=node.label)
    if not node.residual:
        return fc
    out = ctx.memref(node.out_name, (l, d), kind)
    frontend.add_residual(ctx, fc, cur, out, label=f"{node.label}.residual")
    return out


_EMITTERS: dict[type, Callable] = {
    Conv2d: _emit_conv2d,
    Linear: _emit_linear,
    BatchNorm2d: _emit_batch_norm,
    ReLU: _emit_relu,
    OutputReLU: _emit_output_relu,
    MaxPool2d: _emit_max_pool,
    Softmax: _emit_softmax,
    NonLocalBlock: _emit_nlb,
    Flatten: _emit_flatten,
    RMSNorm: _emit_rms_norm,
    Attention: _emit_attention,
    MLP: _emit_mlp,
}


def emit_module(ctx: Context, module: ModuleGraph) -> MemRef:
    """Lower ``module`` to loop nests under ``ctx``; returns the output
    memref.  The *last memref-allocating* node writes an ``output``-kind
    memref (an ``OutputReLU`` tail rewrites it in place)."""
    shapes = module.shapes()
    last_alloc = max(i for i, n in enumerate(module.nodes)
                    if not isinstance(n, OutputReLU))
    cur = ctx.memref(module.input_name, module.input_shape, "input")
    shape = module.input_shape
    for i, node in enumerate(module.nodes):
        kind = "output" if i == last_alloc else "temp"
        cur = _EMITTERS[type(node)](ctx, node, cur, shape, kind)
        shape = shapes[i]
    return cur


def build_fn(module: ModuleGraph) -> Callable[[Context], None]:
    """The ``Context -> None`` build callable the ``CompilerDriver`` traces —
    ``hls.compile`` uses this to accept a ``ModuleGraph`` anywhere a
    hand-written build function is accepted."""
    def build(ctx: Context) -> None:
        emit_module(ctx, module)
    return build
