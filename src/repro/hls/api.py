"""``repro.hls`` implementation: ``compile() -> Design`` and ``Session``.

The hls4ml-shaped front door (``convert(model) -> hls_model`` with
``.predict()/.build()``): one ``compile`` call accepts a jax-level
``ModuleGraph`` (auto-lowered through :mod:`repro.hls.bridge`), a
hand-written loop-nest build function, or an already-traced ``Graph``, and
returns a rich :class:`Design` handle over the internal
``CompiledDesign`` artifact — run, verify, tune, serve, report, all from
one object.  ``repro.core`` remains the stable internal layer underneath;
nothing here re-implements the flow.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Iterable, Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.core.cachedir import cache_root
from repro.core.ir import Graph
from repro.core.interp import Context
from repro.core.pipeline import (CompiledDesign, CompilerConfig,
                                 CompilerDriver, DesignCache,
                                 graph_fingerprint)
from repro.hls import bridge
from repro.nn.graph import ModuleGraph

log = obs.get_logger(__name__)

#: What ``compile`` accepts: a jax-level module graph, a loop-nest build
#: callable (``Context -> None``), or an already-traced DFG.
Model = Union[ModuleGraph, Callable[[Context], None], Graph]


def _as_program(model: Model):
    """-> (program for the driver, ModuleGraph or None)."""
    if isinstance(model, ModuleGraph):
        return bridge.build_fn(model), model
    if isinstance(model, Graph) or callable(model):
        return model, None
    raise TypeError(
        f"hls.compile expects a ModuleGraph, a build callable "
        f"(Context -> None) or a traced Graph, got {type(model).__name__}")


def _np_tree(tree):
    """Nested dict of arrays -> numpy (stable pickling for artifacts)."""
    if isinstance(tree, dict):
        return {k: _np_tree(v) for k, v in tree.items()}
    return np.asarray(tree)


def _default_name(model: Model, module: Optional[ModuleGraph]) -> str:
    if module is not None:
        return module.name
    if isinstance(model, Graph):
        return "design"
    return getattr(model, "__name__", "design").replace("<lambda>", "design")


# ---------------------------------------------------------------------------
# Serving report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeReport:
    """Throughput accounting for one :meth:`Design.serve` run.

    Carries the same tail-latency/queue-depth fields as the async
    engine's ``EngineReport`` (``repro.serving.design_engine``), so the
    synchronous and async serving paths are comparable in one table.  For
    this caller-driven loop the queue depth is always 0 — there is no
    queue; the percentiles are over per-batch dispatch latencies.
    """

    backend: str
    fmt: Optional[str]
    batches: int = 0
    samples: int = 0
    wall_s: float = 0.0
    warmup_s: float = 0.0
    #: per-batch outputs, only kept when ``collect=True``
    outputs: Optional[list] = None
    #: what actually served — the Pallas lowering's plan summary (tier,
    #: fused kernels, fallback count); equals ``backend`` otherwise
    served: Optional[str] = None
    #: per-group / per-node tensor-path fallbacks the Pallas lowering took
    fallbacks: list = dataclasses.field(default_factory=list)
    #: per-batch dispatch-latency percentiles (milliseconds)
    p50_ms: float = 0.0
    p95_ms: float = 0.0
    p99_ms: float = 0.0
    #: always 0 for the sync loop; the async engine reports real depths
    max_queue_depth: int = 0
    mean_queue_depth: float = 0.0

    @property
    def us_per_sample(self) -> float:
        return self.wall_s / self.samples * 1e6 if self.samples else 0.0

    def summary(self) -> str:
        fmt = "fp32" if self.fmt in (None, "fp32") else \
            f"({self.fmt.replace('_', ',')})"
        served = self.served or self.backend
        return (f"served {self.samples} samples in {self.batches} batches: "
                f"{self.us_per_sample:.2f} us/sample, batch p50 "
                f"{self.p50_ms:.2f} / p95 {self.p95_ms:.2f} / p99 "
                f"{self.p99_ms:.2f} ms [{served} backend, {fmt}; "
                f"warm-up {self.warmup_s:.2f}s]")


# ---------------------------------------------------------------------------
# The Design handle
# ---------------------------------------------------------------------------


class Design:
    """A compiled design plus everything you do with one.

    Wraps the internal ``CompiledDesign`` artifact (available as
    ``.compiled``; its fields — ``graph_raw``, ``graph_opt``,
    ``schedule``, ``timings``, ``pass_reports``, ``design_hash``, ... —
    are delegated, so ``design.makespan`` etc. work directly) and keeps
    the session, source program and module-graph context needed for the
    verbs: :meth:`run`, :meth:`jax_fn`, :meth:`verify`, :meth:`tune`,
    :meth:`apply_tuned`, :meth:`with_config`, :meth:`serve`,
    :meth:`report`.
    """

    def __init__(self, compiled: CompiledDesign, session: "Session", *,
                 program=None, module: Optional[ModuleGraph] = None,
                 example_inputs=None,
                 tuned_candidate=None):
        self._compiled = compiled
        self._session = session
        self._program = program
        self._module = module
        self._tuned_candidate = tuned_candidate
        #: warmed-bucket manifest when this design came from ``hls.load``
        self.manifest: Optional[dict] = None
        self.example_inputs = example_inputs
        if example_inputs is not None:           # early shape validation
            if isinstance(example_inputs, dict):
                unknown = set(example_inputs) - set(compiled.graph_raw.inputs)
                if unknown:
                    raise ValueError(
                        f"example_inputs name unknown memrefs {sorted(unknown)}; "
                        f"graph inputs: {sorted(compiled.graph_raw.inputs)}")
            else:
                self._coerce_input(example_inputs)

    # -- delegation ---------------------------------------------------------

    @property
    def compiled(self) -> CompiledDesign:
        """The underlying ``CompiledDesign`` (stable internal artifact)."""
        return self._compiled

    @property
    def session(self) -> "Session":
        return self._session

    @property
    def module(self) -> Optional[ModuleGraph]:
        return self._module

    @property
    def tuned_candidate(self):
        """The ``Candidate`` this design was tuned to, if any."""
        return self._tuned_candidate

    @property
    def precision(self) -> Optional[str]:
        """FloPoCo format key carried by the tuned candidate (None=fp32)."""
        if self._tuned_candidate is None:
            return None
        fmt = self._tuned_candidate.get("precision")
        return None if fmt in (None, "fp32") else fmt

    @property
    def fingerprint(self) -> str:
        """Content hash of the traced DFG (the tuning/cache identity)."""
        return graph_fingerprint(self._compiled.graph_raw)

    def __getattr__(self, name: str):
        # everything else (makespan, schedule, timings, partition, ...) is
        # the artifact's business — delegate rather than mirror
        try:
            compiled = self.__dict__["_compiled"]
        except KeyError:
            raise AttributeError(name) from None
        return getattr(compiled, name)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Design({self._compiled.summary()})"

    # -- feeds --------------------------------------------------------------

    def _input_memref(self) -> tuple[str, tuple[int, ...]]:
        if self._module is not None:
            return self._module.input_name, self._module.input_shape
        g = self._compiled.graph_raw
        data = [n for n in g.inputs if n not in g.weight_names]
        if len(data) != 1:
            raise ValueError(
                f"cannot infer the input memref (non-weight inputs: {data}) "
                f"— pass a feed dict instead of a bare array")
        from repro.core.verify import input_shapes
        return data[0], input_shapes(g)[data[0]]

    def _coerce_input(self, x) -> dict[str, np.ndarray]:
        name, shape = self._input_memref()
        arr = np.asarray(x, dtype=np.float32)
        if arr.shape == tuple(shape) or arr.shape[1:] == tuple(shape):
            return {name: arr}
        if shape[0] == 1 and arr.shape[1:] == tuple(shape)[1:]:
            # natural batch (B, *shape[1:]) -> (B,) + shape
            return {name: arr[:, None]}
        raise ValueError(
            f"input shape {arr.shape} does not match memref {name!r} "
            f"shape {tuple(shape)} (optionally with a leading batch axis)")

    def _batch_size(self, x) -> int:
        """Samples in one batch (a bare array or a feed dict)."""
        name, shape = self._input_memref()
        if isinstance(x, dict):
            if name not in x:
                return 1
            x = x[name]
            arr = np.asarray(x)
            return int(arr.shape[0]) if arr.ndim == len(shape) + 1 else 1
        arr = np.asarray(x)
        if arr.shape == tuple(shape):
            return 1
        return int(arr.shape[0])

    def feeds(self, inputs=None) -> dict[str, np.ndarray]:
        """A full feed dict: ``inputs`` (array or partial dict, or the
        ``example_inputs`` given at compile time) merged with the bound
        module weights."""
        if inputs is None:
            inputs = self.example_inputs
        if inputs is None:
            raise ValueError("no inputs given and no example_inputs bound")
        feeds = dict(inputs) if isinstance(inputs, dict) \
            else self._coerce_input(inputs)
        if self._module is not None:
            for k, v in self._module.weight_feeds().items():
                feeds.setdefault(k, v)
        return feeds

    # -- execution ----------------------------------------------------------

    def run(self, inputs=None, *, fmt=None, raw: bool = False
            ) -> dict[str, np.ndarray]:
        """Vectorised functional simulation of the design.

        ``inputs``: a feed dict, a bare (optionally batched) input array,
        or None to use ``example_inputs``.  Module weights bound at build
        time are fed automatically.  ``fmt`` quantises through the FloPoCo
        functional model; ``raw=True`` evaluates the unoptimised DFG.
        """
        return self._compiled.evaluate(self.feeds(inputs), fmt=fmt, raw=raw)

    def jax_fn(self, *, backend: str = "simd", **pallas_kw) -> Callable:
        """The emitted design as a callable.

        ``backend='simd'`` (default): the jittable SIMD interpretation.
        ``backend='pallas'``: the compiled Pallas rendering — fused
        levelised op groups, registry kernels for bridged modules (the
        source ``ModuleGraph`` is passed automatically when the design was
        compiled from one); extra keywords (``fmt=``, ``mode=``,
        ``use_pallas=``, ...) forward to
        :func:`repro.core.emit_pallas.to_pallas_fn`, and the result
        carries its lowering ``.plan``.
        """
        from repro.core.emit import EMIT_BACKENDS
        if backend not in EMIT_BACKENDS:
            raise ValueError(f"unknown emission backend {backend!r} "
                             f"(valid: {', '.join(EMIT_BACKENDS)})")
        if backend == "pallas":
            pallas_kw.setdefault("module", self._module)
            return self._compiled.jax_fn(backend="pallas", **pallas_kw)
        return self._compiled.jax_fn()

    # -- verification -------------------------------------------------------

    def verify(self, *, ref_fn=None, batch: int = 4, seed: int = 0,
               scale: float = 1.0, fmt=None, atol: float = 1e-3,
               ref_atol: float = 5e-2, **kw):
        """Behavioural testbench vs the interpreter reference (paper §3.2).

        Random vectors through the raw DFG, the optimised DFG, the
        emitted SIMD design, and (with ``fmt``) the FloPoCo functional
        model; returns a ``TestbenchReport`` whose ``passed`` folds the
        tolerances.  ``ref_fn`` optionally adds an independent
        tensor-level reference.
        """
        from repro.core.verify import run_testbench
        return run_testbench(self.name, design=self._compiled, ref_fn=ref_fn,
                             batch=batch, seed=seed, scale=scale, fmt=fmt,
                             atol=atol, ref_atol=ref_atol, **kw)

    # -- reconfiguration ----------------------------------------------------

    def with_config(self, config: CompilerConfig, *,
                    name: Optional[str] = None) -> "Design":
        """Recompile under a different config, sharing the traced graph
        (and the session's pass-stage memo) whenever the trace mode
        (``config.forward``) allows it."""
        if config.forward != self._compiled.config.forward:
            if self._program is None or isinstance(self._program, Graph):
                raise ValueError(
                    "config.forward differs from this design's trace mode "
                    "and no build program is available to re-trace")
            program = self._program          # re-trace in the other mode
        else:
            program = self._compiled.graph_raw
        compiled = self._session.driver.compile(
            program, name=name or self.name, config=config)
        return Design(compiled, self._session, program=self._program,
                      module=self._module,
                      example_inputs=self.example_inputs)

    # -- tuning -------------------------------------------------------------

    def tune(self, space, *, strategy: str = "hillclimb", budget=8,
             db=None, dry: bool = True, force: bool = False,
             target_us: Optional[float] = None, on_trial=None,
             batch: int = 2, seed: int = 0, scale: float = 0.4,
             tol_abs: float = 1e-3, tol_rel: float = 5e-2,
             measure_reps: int = 5, trigger_budget=None, part=None,
             trials: Optional[int] = None):
        """Search ``space`` over this design (delegates to ``repro.tune``).

        Results auto-persist to the ``TuningDB`` (the shared versioned
        cache root unless ``db`` overrides) keyed by this design's
        fingerprint; a covered rerun is served from the DB without
        searching.  Candidates compile through this design's session, so
        they share the trace, the design cache and the pass-stage memo.
        Returns a ``TuneResult``; apply the win with :meth:`apply_tuned`.

        ``budget`` is the trial count (int) — but a
        :class:`repro.trigger.TriggerBudget` passed here (or via the
        explicit ``trigger_budget=`` / ``part=`` keywords) becomes a hard
        feasibility gate instead: a candidate whose compiled schedule
        blows the latency/II/resource envelope scores ``None`` and can
        never win, mirroring the numerics gate.  When ``budget`` carries
        the envelope, the trial count comes from ``trials`` (default 8).
        """
        from repro.tune import Evaluator, Tuner, TuningDB
        from repro.tune.strategies import Bisection, make_strategy
        from repro.trigger import TriggerBudget
        if isinstance(budget, TriggerBudget):
            if trigger_budget is not None:
                raise ValueError("pass the TriggerBudget either as budget= "
                                 "or trigger_budget=, not both")
            trigger_budget, budget = budget, (trials or 8)
        elif trials is not None:
            budget = trials
        if part is not None:
            import dataclasses as _dc
            trigger_budget = (TriggerBudget(part=part)
                              if trigger_budget is None
                              else _dc.replace(trigger_budget, part=part))
        db = db if db is not None else TuningDB()
        if space.base.forward == self._compiled.config.forward:
            program = self._compiled.graph_raw
        elif self._program is not None and not isinstance(self._program,
                                                          Graph):
            program = self._program
        else:
            raise ValueError(
                "space.base.forward differs from this design's trace mode "
                "and no build program is available to re-trace")
        evaluator = Evaluator(program, space, driver=self._session.driver,
                              name=self.name, batch=batch, seed=seed,
                              scale=scale, tol_abs=tol_abs, tol_rel=tol_rel,
                              measure=not dry, measure_reps=measure_reps,
                              budget=trigger_budget)
        strat = (Bisection(target_us=target_us) if strategy == "bisect"
                 else make_strategy(strategy)) if isinstance(strategy, str) \
            else strategy
        tuner = Tuner(evaluator, strat, db=db, budget=budget,
                      on_trial=on_trial)
        return tuner.run(force=force)

    def apply_tuned(self, space, *, db=None, verbose: bool = True
                    ) -> tuple["Design", Optional[Any]]:
        """Auto-load the best tuned config for this design from the DB.

        Returns ``(tuned design, candidate)`` on a hit; on a miss returns
        ``(self, None)`` and — no silent fallback — says exactly which DB
        path was probed and how to populate it.
        """
        from repro.tune import TuningDB, best_config_for
        db = db if db is not None else TuningDB()
        hit = best_config_for(self._compiled.graph_raw, space, db=db)
        if hit is None:
            if verbose:
                log.warning(
                    "no tuned config for design %s / space %r: probed "
                    "TuningDB %s (cache root %s) — run "
                    "`python -m repro.tune` or design.tune(space) first; "
                    "keeping the current config",
                    self.fingerprint[:12], space.name, db.path,
                    db.path.parent)
            return self, None
        config, candidate = hit
        design = self.with_config(config)
        design._tuned_candidate = candidate
        return design, candidate

    # -- serving ------------------------------------------------------------

    def serve(self, batch_iter: Iterable, *, fmt: Optional[str] = None,
              backend: Optional[str] = None, collect: bool = False,
              on_batch=None, pallas_kw: Optional[dict] = None
              ) -> ServeReport:
        """The warmed batched serving loop.

        ``backend='tensor'`` jits the module's fused tensor-level forward
        (requires a bound ``ModuleGraph`` with a ``forward_fn``) at FloPoCo
        format key ``fmt``; ``backend='simd'`` jits the emitted SIMD design
        (fp32); ``backend='pallas'`` runs the compiled Pallas rendering
        (registry kernels / fused op-group segments — extra lowering
        keywords via ``pallas_kw``), recording which tier actually served
        and any per-group tensor fallbacks in the report.  Default: tensor
        when available, else simd.  The first batch warms the jit (timed
        separately); every batch is then blocked-on individually,
        server-style.  ``on_batch(i, out)`` is called per batch;
        ``collect=True`` additionally keeps outputs.
        """
        import jax
        if backend is None:
            backend = ("tensor" if self._module is not None
                       and self._module.forward_fn is not None
                       and self._module.params is not None else "simd")
        run_one, served, fallbacks = self._runner(backend, fmt, pallas_kw)

        report = ServeReport(backend=backend, fmt=fmt,
                             outputs=[] if collect else None,
                             served=served, fallbacks=fallbacks)
        it = iter(batch_iter)
        try:
            first = next(it)
        except StopIteration:
            return report
        t0 = time.perf_counter()
        with obs.span("serve.warmup", cat="serve", backend=backend,
                      design=self.name):
            jax.block_until_ready(run_one(first))    # compile + warm
        report.warmup_s = time.perf_counter() - t0

        import itertools
        batch_s: list[float] = []
        for i, x in enumerate(itertools.chain((first,), it)):
            t0 = time.perf_counter()
            with obs.span("serve.batch", cat="serve", backend=backend,
                          batch=i):
                out = jax.block_until_ready(run_one(x))
            batch_s.append(time.perf_counter() - t0)
            report.wall_s += batch_s[-1]
            report.batches += 1
            report.samples += self._batch_size(x)
            if on_batch is not None:
                on_batch(i, out)
            if collect:
                report.outputs.append(out)
        from repro.serving.common import percentiles
        pct = percentiles(batch_s)
        report.p50_ms = pct["p50"] * 1e3
        report.p95_ms = pct["p95"] * 1e3
        report.p99_ms = pct["p99"] * 1e3
        if report.samples:
            obs.gauge(f"serve.us_per_sample.{backend}",
                      report.us_per_sample)
        return report

    def _runner(self, backend: str, fmt: Optional[str],
                pallas_kw: Optional[dict]):
        """``(run_one, served, fallbacks)`` for one serving backend.

        ``run_one`` takes one batch — a bare input array or a feed dict
        for ``simd``/``pallas`` (module weights merged via :meth:`feeds`),
        the fused forward's ``(B, ...)`` array for ``tensor`` — and
        returns the outputs.  Shared by :meth:`serve` and the async
        :class:`~repro.serving.design_engine.DesignEngine`, so both paths
        serve through identical compiled programs.
        """
        import jax
        served = None
        fallbacks: list = []
        if backend == "tensor":
            if (self._module is None or self._module.forward_fn is None
                    or self._module.params is None):
                raise ValueError("tensor backend needs a ModuleGraph with "
                                 "bound params and a forward_fn")
            params = self._module.params
            fwd = self._module.forward_fn
            fn = jax.jit(lambda p, x: fwd(p, x, fmt=fmt))
            run_one = lambda x: fn(params, x)
        elif backend == "simd":
            if fmt not in (None, "fp32"):
                raise ValueError("the emitted SIMD design runs fp32; use "
                                 "backend='tensor' for quantised serving")
            jfn = jax.jit(self._compiled.jax_fn())
            # feeds() accepts bare input arrays or (partial) feed dicts and
            # merges any bound module weights
            run_one = lambda x: jfn(self.feeds(x))
        elif backend == "pallas":
            # already internally jitted; the nest tier normalises weight
            # feeds host-side, so no extra jax.jit wrapper here
            pfn = self.jax_fn(backend="pallas", fmt=fmt,
                              **(pallas_kw or {}))
            served = pfn.plan.summary()
            fallbacks = list(pfn.plan.fallbacks)
            run_one = lambda x: pfn(self.feeds(x))
        else:
            raise ValueError(f"unknown backend {backend!r} "
                             f"(expected 'tensor', 'simd' or 'pallas')")
        return run_one, served, fallbacks

    def engine(self, **kw):
        """An async adaptive-batching engine over this design.

        Returns a :class:`repro.serving.design_engine.DesignEngine`:
        requests queue and dispatch in bucket-snapped pre-warmed batches
        (size or deadline trigger), with fault-tolerant replica restart.
        ``backend``/``fmt``/``buckets`` default from the saved artifact's
        manifest when this design came from :func:`load`; pass
        ``artifact_path=`` so replica restarts warm-boot from disk.  All
        :class:`DesignEngine` keywords forward.
        """
        from repro.serving.design_engine import DesignEngine
        manifest = self.manifest or {}
        for key in ("backend", "fmt"):
            if kw.get(key) is None and manifest.get(key) is not None:
                kw[key] = manifest[key]
        # the saved warmed-bucket set only defaults when the caller pinned
        # neither buckets nor max_batch — an explicit max_batch must win
        # (the engine derives its buckets from it)
        if kw.get("buckets") is None and "max_batch" not in kw \
                and manifest.get("buckets"):
            kw["buckets"] = manifest["buckets"]
        if kw.get("artifact_path") is None and manifest.get("path"):
            kw["artifact_path"] = manifest["path"]
        return DesignEngine(self, **kw)

    # -- hard-real-time trigger ----------------------------------------------

    def check_budget(self, budget=None, *, part=None):
        """Check this design against a trigger envelope.

        ``budget`` is a :class:`repro.trigger.TriggerBudget`; ``part`` is
        a named/synthetic :class:`repro.trigger.Part` (shorthand for a
        resource-caps-only budget, and an override of the budget's own
        part when both are given).  Returns the structured
        :class:`repro.trigger.BudgetReport` — ``.passed``, ``.failures``
        (named offending constraints), ``.summary()``,
        ``.raise_if_failed()``::

            design.check_budget(part="alveo_u280").raise_if_failed()
        """
        from repro.trigger import check_design
        return check_design(self, budget, part=part)

    def trigger(self, **kw):
        """A streaming trigger loop over this design.

        Returns a :class:`repro.trigger.TriggerLoop` (pre-warmed on
        construction): feed it a :class:`repro.trigger.DetectorFeed` via
        ``loop.run(feed, n_frames, realtime=...)`` for accept/reject
        decisions with per-window deadline accounting.  All
        ``TriggerLoop`` keywords forward (``backend``, ``budget``,
        ``threshold``, ``window``, ``capacity``...).
        """
        from repro.trigger import TriggerLoop
        return TriggerLoop(self, **kw)

    # -- persistence (warm-boot artifacts) -----------------------------------

    def save(self, path: Union[str, Path], *,
             buckets: Optional[Sequence[int]] = None,
             backend: Optional[str] = None,
             fmt: Optional[str] = None) -> Path:
        """Persist a warm-boot artifact: design + weights + bucket manifest.

        The artifact bundles the full ``CompiledDesign`` (graphs, schedule,
        pass reports), the bound module with its trained params (numpy-
        ified; an unpicklable ``forward_fn`` is dropped, disabling only the
        tensor backend), the example inputs, and a serving manifest
        (``buckets``/``backend``/``fmt`` defaults for :meth:`engine`).
        :func:`load` boots a replica from it without re-tracing or
        re-running passes — and the engine's restart path re-loads it when
        a replica is poisoned.  Written through the versioned pickle layer
        (:func:`repro.core.pipeline.save_artifact`), so format bumps
        invalidate saved artifacts loudly.
        """
        from repro.core.pipeline import save_artifact
        module = self._module
        module_payload = None
        if module is not None:
            params = _np_tree(module.params) \
                if module.params is not None else None
            fwd = module.forward_fn
            if fwd is not None:
                import pickle
                try:
                    pickle.dumps(fwd)
                except Exception:
                    fwd = None      # lambda forward: artifact serves via
                    #                 simd/pallas only
            module_payload = ModuleGraph(
                module.name, module.input_shape, module.nodes,
                input_name=module.input_name, params=params,
                forward_fn=fwd, meta=module.meta)
        if buckets is None:
            from repro.serving.design_engine import default_buckets
            buckets = default_buckets(32)
        manifest = {"buckets": list(buckets), "backend": backend,
                    "fmt": fmt, "name": self.name,
                    "design_hash": self.design_hash,
                    "fingerprint": self.fingerprint}
        example = self.example_inputs
        if example is not None:
            example = _np_tree(example) if isinstance(example, dict) \
                else np.asarray(example)
        return save_artifact(path, {
            "design": self._compiled, "module": module_payload,
            "example_inputs": example, "manifest": manifest})

    # -- reporting ----------------------------------------------------------

    def report(self, *, budget=None, part=None) -> str:
        """Pass / schedule / latency summary of the whole artifact.

        With ``budget=`` (a :class:`repro.trigger.TriggerBudget`) and/or
        ``part=`` a budget-check section is appended — the same
        structured verdict :meth:`check_budget` returns, rendered one
        constraint per line.

        For the live span/metric view of a compile-and-serve run, enable
        :mod:`repro.obs` (``obs.enable()`` or ``REPRO_OBS=1``): an extra
        ``obs`` line then summarises the recorded spans and cache
        counters, ``obs.metrics.snapshot()`` has the full metric dump,
        and ``obs.export_chrome_trace(path)`` writes the timeline for
        ``chrome://tracing`` (terminal view:
        ``python -m repro.obs <trace.json>``).
        """
        d = self._compiled
        res = d.schedule.resources()
        lines = [d.summary()]
        lines.append(
            f"  pipeline : {', '.join(d.config.pipeline) or '(none)'}")
        for rep in d.pass_reports:
            if rep.ops_delta:
                lines.append(f"    {rep.summary()}")
        skipped = sum(1 for r in d.pass_reports if r.skipped)
        if skipped:
            lines.append(f"    ({skipped} pass applications skipped by the "
                         f"incremental fixpoint)")
        stage = (f"{d.config.n_stages}-stage pipeline, II={d.stage_ii}"
                 if d.stage_ii is not None else "unpipelined")
        lines.append(f"  schedule : {d.makespan} intervals "
                     f"({d.latency_us:.2f} us end-to-end), {stage} -> "
                     f"{d.sample_latency_us:.2f} us/sample")
        lines.append(f"  resources: {res}")
        t = d.timings
        lines.append(f"  compile  : {t.get('total_s', 0.0):.2f}s "
                     f"(trace {t.get('trace_s', 0.0):.2f} / passes "
                     f"{t.get('passes_s', 0.0):.2f} / schedule "
                     f"{t.get('schedule_s', 0.0):.2f})")
        if self._tuned_candidate is not None:
            lines.append(f"  tuned    : {self._tuned_candidate.label()}")
        if budget is not None or part is not None:
            rep = self.check_budget(budget, part=part)
            lines += ["  " + ln for ln in rep.summary().splitlines()]
        if obs.enabled():
            counters = obs.snapshot()["counters"]
            lines.append(
                f"  obs      : {len(obs.tracer.spans())} spans recorded, "
                f"cache {counters.get('design_cache.hits', 0):.0f} hits / "
                f"{counters.get('design_cache.misses', 0):.0f} misses — "
                f"obs.export_chrome_trace(path), then "
                f"`python -m repro.obs <trace.json>`")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Sessions + the module-level front door
# ---------------------------------------------------------------------------


class Session:
    """One compiler instance: config + design cache + pass-stage memo.

    Every ``Design`` remembers its session, so recompiles
    (:meth:`Design.with_config`) and tuning runs share the trace and the
    caches.  The module-level :func:`compile` uses a process default; make
    your own for benchmark isolation (``max_memory_entries``) or a private
    on-disk cache (``cache_dir``).
    """

    def __init__(self, *, config: Optional[CompilerConfig] = None,
                 cache_dir: Optional[Union[str, Path]] = None,
                 max_memory_entries: Optional[int] = None):
        self.driver = CompilerDriver(
            config, cache=DesignCache(cache_dir,
                                      max_memory_entries=max_memory_entries))

    def compile(self, model: Model, *, name: Optional[str] = None,
                config: Optional[CompilerConfig] = None,
                example_inputs=None, tuned=None, db=None) -> Design:
        program, module = _as_program(model)
        to_compile: Union[Graph, Callable] = program
        candidate = None
        if tuned is not None:
            # resolve the tuned config BEFORE the (only) compile: trace,
            # probe the TuningDB by fingerprint, then lower once.  ``tuned``
            # is a SearchSpace; a miss keeps ``config`` and says which DB
            # path was probed (never a silent fallback).
            from repro.tune import TuningDB, best_config_for
            db = db if db is not None else TuningDB()
            cfg_fwd = (config or self.driver.config).forward
            if not isinstance(to_compile, Graph):
                to_compile = self.driver.trace(program, forward=cfg_fwd)
            hit = best_config_for(to_compile, tuned, db=db)
            if hit is not None:
                config, candidate = hit
                if config.forward != cfg_fwd:
                    if isinstance(program, Graph):
                        raise ValueError(
                            "tuned config.forward differs from the given "
                            "graph's trace mode; pass a build callable")
                    to_compile = self.driver.trace(program,
                                                   forward=config.forward)
            else:
                from repro.core.pipeline import graph_fingerprint
                log.warning(
                    "no tuned config for design %s / space %r: probed "
                    "TuningDB %s — run `python -m repro.tune` or "
                    "design.tune(space) first; compiling the given config",
                    graph_fingerprint(to_compile)[:12], tuned.name, db.path)
        compiled = self.driver.compile(
            to_compile, name=name or _default_name(model, module),
            config=config)
        return Design(compiled, self, program=program, module=module,
                      example_inputs=example_inputs,
                      tuned_candidate=candidate)

    def stats(self) -> dict[str, int]:
        """Compile-side telemetry of this session.

        ``hits``/``misses`` are the design-cache counters (the serving
        warm-path signal), ``recompiles`` counts full (non-cache-served)
        builds, and the entry counts size the in-memory design cache and
        the pass-stage memo.  The same counters feed the process metrics
        (``design_cache.*`` in ``repro.obs``) when observability is on.
        """
        return {"hits": self.driver.cache.hits,
                "misses": self.driver.cache.misses,
                "recompiles": self.driver.recompiles,
                "memory_entries": len(self.driver.cache.memory),
                "pass_memo_entries": len(self.driver._opt_memo),
                "pass_memo_hits": self.driver.pass_memo_hits}


#: process-default sessions, one per cache location ("" = memory-only)
_sessions: dict[str, Session] = {}


def _default_session(cache: Union[bool, str, Path, None] = False) -> Session:
    if cache is True:
        cache_dir: Optional[Path] = cache_root("designs")
    elif cache:
        cache_dir = Path(cache)
    else:
        cache_dir = None
    key = str(cache_dir or "")
    if key not in _sessions:
        _sessions[key] = Session(cache_dir=cache_dir)
    return _sessions[key]


def compile(model: Model, *, name: Optional[str] = None,
            config: Optional[CompilerConfig] = None, example_inputs=None,
            cache: Union[bool, str, Path, None] = False,
            session: Optional[Session] = None, tuned=None,
            db=None) -> Design:
    """Compile a model to a deployable :class:`Design` (the front door).

    ``model`` is a :class:`~repro.nn.graph.ModuleGraph` (auto-lowered to
    loop nests through the bridge), a hand-written build callable
    (``Context -> None``) or an already-traced ``Graph``.
    ``example_inputs`` optionally binds (and shape-checks) a default input
    batch for :meth:`Design.run`.  ``cache=True`` persists designs under
    the shared versioned cache root (``cache=<path>`` under a private
    one); repeated compiles are then served from disk across processes.
    ``tuned`` (a ``SearchSpace``) resolves the best known config from the
    ``TuningDB`` (``db`` overrides the shared one) before the single
    compile — a miss prints the probed DB path and keeps ``config``.
    """
    s = session if session is not None else _default_session(cache)
    return s.compile(model, name=name, config=config,
                     example_inputs=example_inputs, tuned=tuned, db=db)


def load(path: Union[str, Path], *,
         session: Optional[Session] = None) -> Design:
    """Warm-boot a :class:`Design` from a ``Design.save`` artifact.

    No re-trace, no passes, no scheduling: the pickled ``CompiledDesign``
    (plus the bound module weights and example inputs) is rehydrated as-is,
    so a replica serves its first request after one disk read — the
    cold-boot-vs-warm-boot gap ``benchmarks/bench_serving.py`` measures.
    The artifact's warmed-bucket manifest rides along on
    ``design.manifest`` and defaults :meth:`Design.engine`'s
    backend/fmt/buckets; the manifest also remembers this path, so engine
    replica restarts re-load from it automatically.
    """
    from repro.core.pipeline import load_artifact
    record = load_artifact(path)
    s = session if session is not None else _default_session()
    compiled = record["design"]
    design = Design(compiled, s, module=record.get("module"),
                    example_inputs=record.get("example_inputs"))
    design.manifest = dict(record.get("manifest") or {})
    design.manifest["path"] = str(path)
    # seed the session's design cache: a warm boot also warms recompiles
    s.driver.cache.memory.setdefault(compiled.design_hash, compiled)
    return design


def trace(model: Model, *, forward: bool = True) -> Graph:
    """Just the trace: symbolically interpret ``model`` into its DFG.

    The cheap way to a ``graph_fingerprint`` (design identity for cache /
    TuningDB probes) without running passes or the scheduler.
    """
    program, _ = _as_program(model)
    if isinstance(program, Graph):
        return program
    ctx = Context(forward=forward)
    program(ctx)
    return ctx.finalize()
