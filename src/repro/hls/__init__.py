"""``repro.hls`` — the one public compile-to-serve API.

High-level representations of DNNs in, deployable low-level designs out
(the paper's pitch, hls4ml's ``convert(model) -> hls_model`` shape)::

    import repro.hls as hls
    from repro.models import braggnn

    model = braggnn.build(s=1, params=trained_params)   # described once
    design = hls.compile(model, config=hls.CompilerConfig(n_stages=3))
    report = design.serve(batches, fmt="5_4")           # warmed, batched

``compile`` accepts a jax-level :class:`~repro.nn.graph.ModuleGraph`
(auto-lowered to the paper's loop nests by :mod:`repro.hls.bridge` —
bit-identical to the hand-written programs), a loop-nest build callable,
or a traced ``Graph``.  The returned :class:`Design` carries the verbs:
``run`` (vectorised evaluate), ``jax_fn`` (emitted SIMD design),
``verify`` (behavioural testbench), ``tune`` / ``apply_tuned``
(``repro.tune`` search, persisted + auto-loaded via the ``TuningDB``),
``with_config`` (recompile sharing the trace), ``serve`` (warmed batched
loop) and ``report``.

Deployment round-trips through warm-boot artifacts:
``design.save(path)`` persists the compiled design + bound weights +
warmed-bucket manifest, ``hls.load(path)`` boots it back without
re-compiling, and ``design.engine()`` fronts it with the async
adaptive-batching engine (``repro.serving.design_engine``).

``repro.core`` stays importable as the stable internal layer; this
package adds no compiler logic, only the front door.
"""

from repro.core.pipeline import CompiledDesign, CompilerConfig
from repro.hls.api import (Design, ServeReport, Session, compile, load,
                           trace, _default_session)
from repro.nn.graph import ModuleGraph

__all__ = [
    "compile",
    "load",
    "trace",
    "Design",
    "Session",
    "ServeReport",
    "CompilerConfig",
    "CompiledDesign",
    "ModuleGraph",
]
