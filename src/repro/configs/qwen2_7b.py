"""qwen2-7b [dense] — GQA with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2407.10671].
Untied embeddings.  28 heads / 4 KV heads don't divide the 16-wide model
axis, so attention shards over head_dim instead (heads replicated) — see
DESIGN.md §binding.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=8,
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
    attn_block_size=256,  # replicated-head scores: keep blocks small
    tie_embeddings=False,
    rules_overrides=(("heads", None), ("kv_heads", None),
                     ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="qwen2-tiny", n_layers=3, d_model=64, n_heads=7, n_kv_heads=1,
        d_ff=160, vocab_size=256, head_dim=16, attn_block_size=64)
