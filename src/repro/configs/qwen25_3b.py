"""qwen2.5-3b [dense] — GQA with QKV bias, tied embeddings.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5 family].  kv=2 doesn't divide the model axis: attention
shards over head_dim (see DESIGN.md §binding).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=4,
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    head_dim=128,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
    attn_block_size=256,  # replicated-head scores: keep blocks small
    tie_embeddings=True,
    rules_overrides=(("heads", None), ("kv_heads", None),
                     ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="qwen25-tiny", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=256, head_dim=16, attn_block_size=64)
