"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) expert_d_ff=14336 vocab=32000
[arXiv:2401.04088].  All layers use SWA (window 4096) per the assignment,
making decode state bounded: long_500k RUNS with a rolling-buffer cache.

Sharding: 8 experts < 16 model-axis shards, so experts replicate and TP
runs *inside* each expert (expert_mlp -> model, 14336/16 = 896).  kv=8
doesn't divide 16 either: attention shards over head_dim.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    seq_shard_train=True,
    microbatches=4,
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=32000,
    head_dim=128,
    attn_pattern=("local",),       # SWA everywhere
    window=4096,
    rope_theta=1e6,
    n_experts=8,
    n_experts_padded=8,
    experts_per_token=2,
    expert_d_ff=14336,
    capacity_factor=1.25,
    moe_token_chunks=32,
    norm="rmsnorm",
    act="silu",
    attn_block_size=128,  # replicated-head scores: keep blocks small
    tie_embeddings=False,
    rules_overrides=(("experts", None), ("expert_mlp", "model"),
                     ("expert_embed", "data"),  # FSDP on expert weights:
                     # 47B fp32 cannot replicate over 8-way-indivisible EP
                     ("heads", None), ("kv_heads", None),
                     ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="mixtral-tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        vocab_size=256, head_dim=16, window=8, n_experts=4,
        n_experts_padded=4, experts_per_token=2, expert_d_ff=96,
        attn_block_size=64)
