"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + shared experts.

24L d_model=2048 16H (GQA kv=16) expert_d_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B].  60 experts padded to 64 for EP over the
16-wide model axis (padding experts masked from routing); shared-expert
block of width 5632 (= 4 x 1408, the "4 shared" of the assignment).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=4,
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                       # all FFN compute is MoE
    vocab_size=151936,
    head_dim=128,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1e6,
    n_experts=60,
    n_experts_padded=64,
    experts_per_token=4,
    expert_d_ff=1408,
    n_shared_experts=4,
    shared_d_ff=5632,
    capacity_factor=1.25,
    moe_token_chunks=32,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="qwen2-moe-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, vocab_size=256, head_dim=16, n_experts=6,
        n_experts_padded=8, experts_per_token=2, expert_d_ff=32,
        n_shared_experts=2, shared_d_ff=64, attn_block_size=64)
