"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000
[arXiv:2402.19427].  Pattern (rec, rec, attn) x 12 + 2 remainder recurrent
layers (38 = 12*3 + 2).  Local attention window 2048.  Sub-quadratic:
long_500k runs (recurrent state + bounded window).

Sharding notes: MQA kv=1 cannot shard over the 16-wide model axis — KV
projections/cache replicate (kv_heads -> None); q heads shard normally.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=8,
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    attn_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    rope_theta=10000.0,
    norm="rmsnorm",
    zero_centered_norm=True,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    rules_overrides=(("kv_heads", None),),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="recurrentgemma-tiny", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab_size=256, head_dim=16, lru_width=64,
        window=8, attn_block_size=64)
