"""BraggNN (the paper's case-study DNN, Listing 5) as a selectable config.

Not part of the assigned LM pool — this is the OpenHLS deployment target:
Bragg-diffraction-peak characterisation at 1 MHz sampling (goal 1 us/sample;
paper achieves 4.8 us/sample on an Alveo U280 at FloPoCo (5,3) precision).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class BraggNNConfig:
    name: str = "braggnn"
    family: str = "cnn"
    scale: int = 1                 # the paper's s parameter
    img: int = 11                  # input patch side
    quant_format: str = "5_4"      # FloPoCo format for deployment
    taylor_order: int = 8          # exp expansion order (softmax)
    pipeline_stages: int = 3       # paper §4.2 deployment


CONFIG = BraggNNConfig()


def tiny() -> BraggNNConfig:
    return dataclasses.replace(CONFIG, name="braggnn-tiny", img=7)
