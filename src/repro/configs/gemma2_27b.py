"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118].  Sandwich (pre+post) norms, zero-centred RMSNorm,
GeGLU, attn softcap 50, final softcap 30, window 4096 on local layers.
long_500k skipped: global layers are full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=16,
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    attn_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10000.0,
    norm="rmsnorm",
    zero_centered_norm=True,
    post_norms=True,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="gemma2-tiny", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=256, head_dim=16, window=8, attn_block_size=64)
