"""Architecture registry + per-cell input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the lowered step function (weak-type-correct, shardable, no device
allocation) — the dry-run contract.  Modality frontends are stubs per the
brief: whisper gets precomputed frame embeddings, qwen2-vl gets precomputed
patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import (braggnn, gemma2_27b, mixtral_8x7b, qwen2_7b,
                           qwen2_moe_a27b, qwen2_vl_2b, qwen25_3b,
                           recurrentgemma_9b, stablelm_3b, whisper_tiny,
                           xlstm_1_3b)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, supports_shape

_MODULES = {
    "recurrentgemma-9b": recurrentgemma_9b,
    "gemma2-27b": gemma2_27b,
    "qwen2-7b": qwen2_7b,
    "stablelm-3b": stablelm_3b,
    "qwen2.5-3b": qwen25_3b,
    "whisper-tiny": whisper_tiny,
    "qwen2-moe-a2.7b": qwen2_moe_a27b,
    "mixtral-8x7b": mixtral_8x7b,
    "xlstm-1.3b": xlstm_1_3b,
    "qwen2-vl-2b": qwen2_vl_2b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch == "braggnn":
        return braggnn.CONFIG
    return _MODULES[arch].CONFIG


def get_tiny(arch: str):
    if arch == "braggnn":
        return braggnn.tiny()
    return _MODULES[arch].tiny()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name, supported, reason) for all 40 cells."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = supports_shape(cfg, shape)
            if ok or include_skipped:
                yield arch, sname, ok, why


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the step function's data inputs.

    train:    {tokens, targets[, patches | frames]}
    prefill:  {tokens[, patches | frames]}
    decode:   {tokens (B,1), pos (B,)}   (cache specs are built separately)
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.activation_dtype)

    if getattr(cfg, "is_encoder_decoder", False):
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), act)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "targets": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((b,), i32)}

    if shape.kind in ("train", "prefill"):
        out = {}
        n_text = s
        if cfg.n_patches:
            out["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), act)
            n_text = s - cfg.n_patches
        out["tokens"] = jax.ShapeDtypeStruct((b, n_text), i32)
        if shape.kind == "train":
            out["targets"] = jax.ShapeDtypeStruct((b, n_text), i32)
        return out

    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32)}


def input_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical axes matching ``input_specs`` (resolved by BindingRules)."""
    if getattr(cfg, "is_encoder_decoder", False):
        if shape.kind == "train":
            return {"frames": ("batch", None, None),
                    "tokens": ("batch", None), "targets": ("batch", None)}
        if shape.kind == "prefill":
            return {"frames": ("batch", None, None),
                    "tokens": ("batch", None)}
        return {"tokens": ("batch", None), "pos": ("batch",)}
    if shape.kind in ("train", "prefill"):
        out = {"tokens": ("batch", None)}
        if cfg.n_patches:
            out["patches"] = ("batch", None, None)
        if shape.kind == "train":
            out["targets"] = ("batch", None)
        return out
    return {"tokens": ("batch", None), "pos": ("batch",)}
