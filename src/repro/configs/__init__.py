"""Assigned architectures x shapes (see registry)."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, supports_shape

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "supports_shape"]
