"""Configuration schema: architectures and input shapes.

Every assigned architecture is a ``ModelConfig``; every workload cell is a
(ModelConfig, ShapeConfig) pair.  ``tiny()`` derives a reduced same-family
config for CPU smoke tests (the full configs are exercised only through the
dry-run's ShapeDtypeStructs, never allocated).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # temporal-mixing pattern, cycled over layers
    attn_pattern: tuple = ("global",)
    window: int = 0                # local/SWA window (0 = none)
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    mrope_sections: tuple = ()
    # MoE
    n_experts: int = 0
    n_experts_padded: int = 0      # padded to mesh divisibility (EP)
    experts_per_token: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_token_chunks: int = 1
    # recurrent (RG-LRU / xLSTM)
    lru_width: int = 0
    conv_width: int = 4
    mlstm_proj_factor: int = 2
    mlstm_chunk: int = 256
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500
    learned_positions: bool = False
    max_position: int = 0
    # VLM (qwen2-vl)
    n_patches: int = 0
    # norms / activations
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    zero_centered_norm: bool = False
    post_norms: bool = False       # gemma2 sandwich norms
    act: str = "silu"
    tie_embeddings: bool = True
    embed_scale: bool = False
    # execution
    activation_dtype: str = "bfloat16"
    quant_format: Optional[str] = None   # paper (wE,wF) weight quantisation
    remat: str = "none"                  # none | full | dots
    attn_block_size: int = 1024          # blockwise attention block
    scan_layers: bool = True
    microbatches: int = 1                # grad-accumulation microbatches
    # sharding rule overrides: tuple of (logical_axis, mesh_axes)
    rules_overrides: tuple = ()
    # mesh axes the batch dim of activations is pinned to (set by the
    # launcher per cell; empty = no explicit constraint).  GSPMD sometimes
    # loses batch sharding through blockwise-attention reshapes and
    # replicates multi-GB score tensors (measured on mixtral train_4k).
    batch_mesh_axes: tuple = ()
    # sequence-parallel activation sharding (Korthikanti-style): pin the
    # seq dim of the residual stream to these axes during train/prefill —
    # shrinks the remat stash model_axis-fold.  Opt-in via seq_shard_train;
    # the launcher fills seq_mesh_axes per cell.
    seq_shard_train: bool = False
    seq_mesh_axes: tuple = ()
    # perf knobs (hillclimb levers; see EXPERIMENTS.md §Perf)
    bf16_reduce: bool = False     # cross-device partial sums in bf16
    serve_dtype: str = ""         # cast params for decode/prefill cells

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_period(self) -> int:
        return len(self.attn_pattern)

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_remainder_layers(self) -> int:
        return self.n_layers % self.pattern_period

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state is bounded (window/recurrent only) —
        the long_500k eligibility rule."""
        bounded = {"local", "rglru", "mlstm", "slstm"}
        kinds = set(self.attn_pattern)
        if not kinds <= bounded:
            return False
        return all(k != "local" or self.window > 0 for k in kinds)

    def layer_kind(self, i: int) -> str:
        return self.attn_pattern[i % self.pattern_period]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not).  Encodes the skip rules of the brief."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention layers present: 500k decode cache is "
                       "not sub-quadratic (skip per brief, see DESIGN.md)")
    if cfg.is_encoder_decoder and shape.kind == "decode" \
            and shape.name == "long_500k":
        return False, "encoder-decoder: no 500k decoder context"
    return True, ""
