"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks.

48L d_model=2048 4H d_ff=0 vocab=50304 [arXiv:2405.04517].  Pattern
xLSTM[7:1]: seven mLSTM blocks then one sLSTM block, six superblocks of
eight (48 = 6 x 8).  d_ff = 0: all FFN compute lives inside the blocks
(mLSTM projection factor 2, sLSTM gated FFN factor 4/3).  Constant-size
state: long_500k runs.

Sharding: 4 heads don't divide the 16-wide model axis — head_dim shards
(mLSTM head dim 1024 -> 64/device, sLSTM unit width 512 -> 32/device).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=8,
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2,
    mlstm_chunk=256,
    conv_width=4,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    rules_overrides=(("heads", None), ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="xlstm-tiny", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=256, attn_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        mlstm_chunk=8, attn_block_size=64)
