"""stablelm-3b [dense] — MHA, LayerNorm, partial rotary embeddings.

32L d_model=2560 32H (kv=32, full MHA) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b family].  Rotary fraction 0.25.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=4,
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    attn_pattern=("global",),
    rope_theta=10000.0,
    rope_fraction=0.25,
    norm="layernorm",
    norm_eps=1e-5,
    act="silu",
    tie_embeddings=False,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="stablelm-tiny", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        attn_block_size=64)
