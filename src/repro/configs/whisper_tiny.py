"""whisper-tiny [audio] — encoder-decoder backbone, conv frontend stubbed.

4L (enc) + 4L (dec), d_model=384 6H (kv=6) d_ff=1536 vocab=51865
[arXiv:2212.04356].  Per the brief, the audio frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1500, 384).
Decoder positions are learned; the table is sized 4096 and clamped for the
synthetic 32k decode shapes (whisper's trained max is 448 — these cells are
shape exercises; noted in DESIGN.md).  vocab 51865 is padded to 51872
(+7 dead tokens) for 16-way vocab sharding — standard practice.  6 heads
don't divide 16: head_dim sharding.  long_500k: skipped (enc-dec).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=8,
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51872,  # 51865 padded +7 for 16-way vocab sharding
    head_dim=64,
    attn_pattern=("global",),
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_len=1500,
    learned_positions=True,
    max_position=4096,
    norm="layernorm",
    norm_eps=1e-5,
    act="gelu",
    tie_embeddings=True,
    attn_block_size=256,
    rules_overrides=(("heads", None), ("kv_heads", None),
                     ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="whisper-micro", n_layers=2, n_encoder_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        encoder_len=24, max_position=64, attn_block_size=64)
