"""qwen2-vl-2b [vlm] — M-RoPE, dynamic-resolution vision (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191].
Per the brief the vision frontend is a STUB: ``input_specs`` provides 1024
precomputed patch embeddings (B, 1024, 1536) which are prepended to the
token stream; M-RoPE rotates (t, h, w) position streams over frequency
sections (16, 24, 24) of the 128-wide head dim.  12 heads / 2 kv heads
don't divide 16: head_dim sharding.  long_500k: skipped (full attention).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    remat="full",
    microbatches=4,
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    n_patches=1024,
    norm="rmsnorm",
    act="silu",
    attn_block_size=256,  # replicated-head scores: keep blocks small
    tie_embeddings=True,
    rules_overrides=(("heads", None), ("kv_heads", None),
                     ("head_dim", "model")),
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        microbatches=1, name="qwen2-vl-tiny", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=256, head_dim=16,
        mrope_sections=(4, 2, 2), n_patches=4, attn_block_size=64)
