"""Process-wide metrics: counters, gauges, histograms.

One ``MetricsRegistry`` (the module singleton lives in ``repro.obs``)
holds every metric by dotted name — ``design_cache.hits``,
``serve.queue_depth`` — lazily created on first touch so instrumentation
sites never pre-register.  ``snapshot()`` returns a plain dict (embedded
into ``BENCH_<date>.json`` and Chrome-trace ``otherData``);
``to_prometheus()`` renders the text exposition format for scrape-style
consumers.

Stdlib-only on purpose (manual percentiles, no numpy): the registry must
be importable — and near-free when disabled — everywhere the compiler
is.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Tuple, Union

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _percentile(sorted_vals: List[float], pct: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    rank = max(0, min(len(sorted_vals) - 1,
                      math.ceil(pct / 100.0 * len(sorted_vals)) - 1))
    return sorted_vals[rank]


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Sample distribution with exact count/sum/min/max and percentiles
    over the kept samples.  Keeps at most ``max_samples`` raw values
    (first-N; count/sum/min/max stay exact beyond the cap) so a
    long-lived server cannot grow without bound."""

    __slots__ = ("name", "_lock", "_samples", "_count", "_sum",
                 "_min", "_max", "max_samples")

    def __init__(self, name: str, max_samples: int = 100_000):
        self.name = name
        self._lock = threading.Lock()
        self._samples: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self.max_samples = max_samples

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            return
        with self._lock:
            self._count += 1
            self._sum += value
            self._min = value if self._min is None else min(self._min, value)
            self._max = value if self._max is None else max(self._max, value)
            if len(self._samples) < self.max_samples:
                self._samples.append(value)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def stats(self) -> Dict[str, float]:
        with self._lock:
            n, total = self._count, self._sum
            lo, hi = self._min, self._max
            vals = sorted(self._samples)
        if n == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": n,
            "sum": round(total, 6),
            "min": lo if lo is not None else 0.0,
            "max": hi if hi is not None else 0.0,
            "mean": round(total / n, 6),
            "p50": _percentile(vals, 50),
            "p95": _percentile(vals, 95),
            "p99": _percentile(vals, 99),
        }


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named metrics, created lazily on first use.  Re-requesting a name
    with a different kind raises — one name, one kind, process-wide."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls: type) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- convenience write paths (used by the guarded obs.* helpers) ------
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, value: float) -> None:
        self.gauge(name).set(value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    # -- export -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Plain-dict view: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count,sum,min,max,mean,p50,p95,p99}}}``."""
        with self._lock:
            items: List[Tuple[str, Metric]] = sorted(self._metrics.items())
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.stats()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counters, gauges, and histograms
        as summaries with quantile labels)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: List[str] = []
        for name, m in items:
            pname = _prom_name(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value:g}")
            else:
                st = m.stats()
                lines.append(f"# TYPE {pname} summary")
                for q in (50, 95, 99):
                    lines.append(
                        f'{pname}{{quantile="0.{q}"}} {st[f"p{q}"]:g}')
                lines.append(f"{pname}_sum {st['sum']:g}")
                lines.append(f"{pname}_count {st['count']:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
