"""Logging conventions for the repo: one ``repro`` root logger.

Library modules call ``get_logger(__name__)`` and emit at the usual
levels; nothing under ``src/repro`` ever installs handlers.  The CLIs
(examples, benchmarks, ``python -m repro.tune``) call
``setup_logging()`` exactly once, which attaches a single stdout handler
to the ``repro`` root logger — idempotent, so a CLI importing another
CLI's module does not double-log.  ``REPRO_LOG_LEVEL`` overrides the
level (e.g. ``REPRO_LOG_LEVEL=DEBUG``).
"""

from __future__ import annotations

import logging
import os
import sys
from typing import Optional

ROOT = "repro"
_FORMAT = "%(message)s"


def get_logger(name: Optional[str] = None) -> logging.Logger:
    """Module-level logger under the ``repro`` hierarchy.  Pass
    ``__name__``; names outside the hierarchy (``examples.*``,
    ``benchmarks.*``) are re-rooted so one ``setup_logging()`` call
    governs them all."""
    if not name:
        return logging.getLogger(ROOT)
    if name != ROOT and not name.startswith(ROOT + "."):
        name = f"{ROOT}.{name}"
    return logging.getLogger(name)


def setup_logging(level: Optional[str] = None, stream=None,
                  force: bool = False) -> logging.Logger:
    """Configure the ``repro`` root logger once (CLI entry points only).

    Attaches a plain-format handler writing to ``stream`` (default
    ``sys.stdout``, so CLI progress reads like the prints it replaced)
    and sets the level from ``level`` or ``$REPRO_LOG_LEVEL`` (default
    INFO).  Re-invocations are no-ops unless ``force=True``.
    """
    root = logging.getLogger(ROOT)
    if root.handlers and not force:
        return root
    for h in list(root.handlers):
        root.removeHandler(h)
    handler = logging.StreamHandler(stream or sys.stdout)
    handler.setFormatter(logging.Formatter(_FORMAT))
    root.addHandler(handler)
    lvl = (level or os.environ.get("REPRO_LOG_LEVEL") or "INFO").upper()
    root.setLevel(getattr(logging, lvl, logging.INFO))
    root.propagate = False
    return root
