"""repro.obs — unified tracing, metrics, and profiling for compile + serve.

One process-wide :class:`~repro.obs.trace.Tracer` and one
:class:`~repro.obs.metrics.MetricsRegistry`, shared by the compiler
(``CompilerDriver.compile`` and every pass round), the pallas emission
backend (per-kernel timings, plan counters), the serving stack
(``DesignEngine`` request lifecycle, queue-depth histogram), and the
hard-real-time trigger (one ``trigger.window`` span per dispatched
window; ``trigger.deadline_misses`` / ``trigger.dropped_frames`` /
``trigger.accepts`` / ``trigger.rejects`` counters).

Disabled by default: every helper here checks one module flag and
returns a shared no-op before touching the clock, so library users pay
nothing.  Enable with :func:`enable` or ``REPRO_OBS=1`` in the
environment; export the recorded run with :func:`export_chrome_trace`
(opens in ``chrome://tracing`` / Perfetto) and summarise it with
``python -m repro.obs <trace.json>``.

    from repro import obs
    obs.enable()
    with obs.span("compile", design="braggnn"):
        ...
    obs.inc("design_cache.misses")
    obs.observe("serve.queue_depth", depth)
    obs.export_chrome_trace("trace.json")
    print(obs.metrics.to_prometheus())
"""

from __future__ import annotations

import os
import pathlib
import time
from typing import Any, Dict, Optional

from repro.obs.logs import get_logger, setup_logging
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP_SPAN, Span, Tracer
from repro.obs import export as _export

__all__ = [
    "tracer", "metrics", "enable", "disable", "enabled", "reset",
    "span", "record_span", "event", "inc", "gauge", "observe",
    "snapshot", "export_chrome_trace", "chrome_trace",
    "get_logger", "setup_logging", "Tracer", "Span", "MetricsRegistry",
    "NOOP_SPAN",
]

#: process-wide singletons — instrumentation sites and exporters share
#: these; swap only in tests (prefer ``reset()``)
tracer = Tracer()
metrics = MetricsRegistry()

_enabled = False


def enable() -> None:
    """Turn recording on process-wide (spans + metrics)."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Return to the no-op default."""
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Drop all recorded spans and metrics (keeps the enabled flag)."""
    tracer.clear()
    metrics.clear()


# -- guarded fast-path helpers -------------------------------------------
# Each returns/does nothing after a single flag check when disabled; this
# is the contract that keeps instrumented hot paths near-free by default.

def span(name: str, cat: str = "", **attrs: Any):
    """``with obs.span("passes.cse", ops=n) as sp:`` — a nested span on
    the process tracer, or the shared no-op when disabled."""
    if not _enabled:
        return NOOP_SPAN
    return tracer.span(name, cat, **attrs)


def record_span(name: str, t0: float, t1: float, **kwargs: Any):
    """Retroactive span from explicit ``time.monotonic()`` bounds."""
    if not _enabled:
        return NOOP_SPAN
    return tracer.record(name, t0, t1, **kwargs)


def event(name: str, cat: str = "", **attrs: Any):
    if not _enabled:
        return NOOP_SPAN
    return tracer.event(name, cat, **attrs)


def inc(name: str, n: float = 1.0) -> None:
    if _enabled:
        metrics.inc(name, n)


def gauge(name: str, value: float) -> None:
    if _enabled:
        metrics.set_gauge(name, value)


def observe(name: str, value: float) -> None:
    if _enabled:
        metrics.observe(name, value)


def snapshot() -> Dict[str, Any]:
    """The metrics snapshot dict (always available, even when disabled —
    it is just empty then)."""
    return metrics.snapshot()


def chrome_trace() -> Dict[str, Any]:
    """The Chrome-trace document for the current recording."""
    return _export.chrome_trace(tracer, metrics.snapshot())


def export_chrome_trace(path) -> pathlib.Path:
    """Write spans + metrics as Chrome-trace JSON; returns the path."""
    return _export.export_chrome_trace(path, tracer, metrics.snapshot())


def now() -> float:
    """The tracer's clock (``time.monotonic``), for retroactive spans."""
    return time.monotonic()


if os.environ.get("REPRO_OBS", "").strip().lower() not in ("", "0", "false"):
    enable()
