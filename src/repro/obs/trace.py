"""Zero-dependency tracing: nested spans over a process-wide ``Tracer``.

A span is one timed region (``with tracer.span("passes.cse", ops=n):``)
with a name, a category, wall-clock bounds on the shared monotonic
clock, the recording thread, free-form attributes, and a parent link so
nesting survives the flat event list.  Nesting is tracked per thread
(thread-local span stack), the finished-span list is lock-protected, and
retroactive spans can be recorded from explicit timestamps
(``tracer.record(...)``) — that is how per-request serving spans are
reconstructed from ``QueuedRequest`` timestamps after the fact.

The module is stdlib-only by design: it must import (and no-op) in any
environment the compiler runs in, including ones without jax/numpy.
Chrome-trace rendering of the recorded spans lives in
:mod:`repro.obs.export`.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, Iterator, List, Optional


@dataclasses.dataclass
class Span:
    """One finished timed region.  ``t0``/``t1`` are ``time.monotonic()``
    seconds (same clock as ``serving.common.QueuedRequest``)."""

    name: str
    cat: str = ""
    t0: float = 0.0
    t1: float = 0.0
    tid: int = 0
    thread: str = ""
    span_id: int = 0
    parent_id: Optional[int] = None
    kind: str = "complete"          # "complete" | "async" | "instant"
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes to an open (or finished) span."""
        self.attrs.update(attrs)
        return self


class _NoopSpan:
    """Shared do-nothing span: the disabled-tracer fast path.  A single
    module-level instance is returned from every ``obs.span(...)`` call
    while tracing is off, so the disabled cost is one attribute load and
    one truthiness check — no allocation, no clock read."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class _ActiveSpan:
    """Context manager binding one ``Span`` to a ``Tracer``: entry reads
    the clock and pushes onto the thread-local nesting stack, exit pops
    and appends the finished span to the tracer."""

    __slots__ = ("span", "_tracer")

    def __init__(self, tracer: "Tracer", span: Span):
        self.span = span
        self._tracer = tracer

    def __enter__(self) -> Span:
        stack = self._tracer._stack()
        if stack:
            self.span.parent_id = stack[-1].span_id
        stack.append(self.span)
        self.span.t0 = time.monotonic()
        return self.span

    def __exit__(self, *exc: Any) -> bool:
        self.span.t1 = time.monotonic()
        stack = self._tracer._stack()
        if stack and stack[-1] is self.span:
            stack.pop()
        elif self.span in stack:        # unbalanced exit; stay consistent
            stack.remove(self.span)
        self._tracer._append(self.span)
        return False


class Tracer:
    """Thread-safe collector of finished spans.

    ``span()`` opens a nested region on the calling thread; ``record()``
    logs a span retroactively from explicit timestamps; ``event()`` logs
    an instant.  ``spans()`` snapshots the finished list.  The collector
    caps at ``max_spans`` and counts overflow in ``dropped`` rather than
    growing without bound on long-lived servers.
    """

    def __init__(self, max_spans: int = 200_000):
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._ids = itertools.count(1)
        self._local = threading.local()
        self.max_spans = max_spans
        self.dropped = 0
        #: monotonic origin for trace-relative timestamps (export uses it)
        self.epoch = time.monotonic()

    # -- internals --------------------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _append(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(span)
            else:
                self.dropped += 1

    def _new_span(self, name: str, cat: str, kind: str,
                  attrs: Dict[str, Any]) -> Span:
        th = threading.current_thread()
        return Span(name=name, cat=cat, tid=th.ident or 0, thread=th.name,
                    span_id=next(self._ids), kind=kind, attrs=attrs)

    # -- recording --------------------------------------------------------
    def span(self, name: str, cat: str = "", **attrs: Any) -> _ActiveSpan:
        """``with tracer.span("compile.passes", ops=n) as sp:`` — nested
        under whatever span is currently open on this thread."""
        return _ActiveSpan(self, self._new_span(name, cat, "complete", attrs))

    def record(self, name: str, t0: float, t1: float, *, cat: str = "",
               kind: str = "complete", parent_id: Optional[int] = None,
               **attrs: Any) -> Span:
        """Record a span retroactively from explicit ``time.monotonic()``
        bounds (e.g. a request's submit→complete window)."""
        span = self._new_span(name, cat, kind, attrs)
        span.t0, span.t1, span.parent_id = t0, t1, parent_id
        self._append(span)
        return span

    def event(self, name: str, cat: str = "", **attrs: Any) -> Span:
        """Record an instantaneous event at the current time."""
        now = time.monotonic()
        return self.record(name, now, now, cat=cat, kind="instant", **attrs)

    def current(self) -> Optional[Span]:
        """The innermost open span on the calling thread, if any."""
        stack = self._stack()
        return stack[-1] if stack else None

    # -- inspection -------------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def __iter__(self) -> Iterator[Span]:
        return iter(self.spans())

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0
