"""Terminal summary of an exported Chrome trace.

    PYTHONPATH=src python -m repro.obs trace.json [--top 15]

Prints the top-k slowest complete spans, a per-name aggregate (count /
total / mean), reconstructed async request spans, and the metric table
embedded under ``otherData.metrics`` — the quick look before reaching
for chrome://tracing.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def _complete_spans(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    spans = [e for e in events if e.get("ph") == "X"]
    # async b/e pairs -> synthesised spans so requests show up too
    opens: Dict[Any, Dict[str, Any]] = {}
    for e in events:
        key = (e.get("name"), e.get("id"))
        if e.get("ph") == "b":
            opens[key] = e
        elif e.get("ph") == "e" and key in opens:
            b = opens.pop(key)
            spans.append({**b, "ph": "X",
                          "dur": e.get("ts", 0) - b.get("ts", 0)})
    return spans


def summarise(doc: Dict[str, Any], top: int = 15) -> str:
    events = doc.get("traceEvents", [])
    spans = _complete_spans(events)
    lines: List[str] = []

    lines.append(f"{len(events)} events, {len(spans)} spans")
    lines.append("")
    lines.append(f"slowest {min(top, len(spans))} spans:")
    for e in sorted(spans, key=lambda e: -e.get("dur", 0))[:top]:
        args = e.get("args") or {}
        attrs = ", ".join(f"{k}={v}" for k, v in sorted(args.items())
                          if k != "parent_span")
        lines.append(f"  {_fmt_us(e.get('dur', 0)):>10}  {e['name']}"
                     + (f"  [{attrs}]" if attrs else ""))

    agg: Dict[str, List[float]] = {}
    for e in spans:
        agg.setdefault(e["name"], []).append(e.get("dur", 0))
    lines.append("")
    lines.append("by span name (count / total / mean):")
    for name, durs in sorted(agg.items(), key=lambda kv: -sum(kv[1])):
        lines.append(f"  {name:<28} {len(durs):>6}  "
                     f"{_fmt_us(sum(durs)):>10}  "
                     f"{_fmt_us(sum(durs) / len(durs)):>10}")

    snap = (doc.get("otherData") or {}).get("metrics") or {}
    counters = snap.get("counters") or {}
    gauges = snap.get("gauges") or {}
    hists = snap.get("histograms") or {}
    if counters or gauges or hists:
        lines.append("")
        lines.append("metrics:")
        for name, v in sorted(counters.items()):
            lines.append(f"  {name:<32} {v:g}")
        for name, v in sorted(gauges.items()):
            lines.append(f"  {name:<32} {v:g}")
        for name, st in sorted(hists.items()):
            lines.append(
                f"  {name:<32} n={st.get('count', 0)} "
                f"mean={st.get('mean', 0):g} p50={st.get('p50', 0):g} "
                f"p95={st.get('p95', 0):g} max={st.get('max', 0):g}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarise an exported repro.obs Chrome trace")
    ap.add_argument("trace", help="path to the trace JSON "
                                  "(obs.export_chrome_trace output)")
    ap.add_argument("--top", type=int, default=15,
                    help="how many slowest spans to list (default 15)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    try:
        print(summarise(doc, top=args.top))
    except BrokenPipeError:                 # `... | head` is normal usage
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
