"""Chrome-trace / Perfetto JSON export of the recorded spans + metrics.

``export_chrome_trace(path)`` writes one JSON document loadable in
``chrome://tracing`` / https://ui.perfetto.dev:

- ``"complete"`` spans -> ``ph: "X"`` complete events (``ts``/``dur`` in
  microseconds relative to the tracer epoch), with span attributes under
  ``args`` — the nested compiler timeline renders directly from these.
- ``"async"`` spans (per-request serving lifecycles that overlap
  arbitrarily) -> paired ``ph: "b"``/``"e"`` async events keyed by span
  id, so concurrent requests stack in their own track rather than
  fighting for the thread's synchronous lane.
- ``"instant"`` spans -> ``ph: "i"`` thread-scoped instants.
- one ``ph: "M"`` thread-name metadata record per recording thread.

The metrics snapshot rides along under ``otherData.metrics`` so a single
file carries the full run: open it in the trace viewer, or feed it to
``python -m repro.obs trace.json`` for a terminal summary.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, List, Optional

from repro.obs.trace import Span, Tracer

_PID = 1  # single-process trace; chrome://tracing wants some pid


def _args(span: Span) -> Dict[str, Any]:
    args = {k: v for k, v in span.attrs.items()}
    if span.parent_id is not None:
        args["parent_span"] = span.parent_id
    return args


def chrome_trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """Convert the tracer's finished spans to Chrome-trace events."""
    epoch = tracer.epoch
    events: List[Dict[str, Any]] = []
    threads: Dict[int, str] = {}
    for span in tracer.spans():
        threads.setdefault(span.tid, span.thread)
        ts = round((span.t0 - epoch) * 1e6, 3)
        base = {"name": span.name, "cat": span.cat or "repro",
                "pid": _PID, "tid": span.tid, "ts": ts, "args": _args(span)}
        if span.kind == "async":
            ident = str(span.attrs.get("rid", span.span_id))
            events.append({**base, "ph": "b", "id": ident})
            events.append({**base, "ph": "e", "id": ident,
                           "ts": round((span.t1 - epoch) * 1e6, 3),
                           "args": {}})
        elif span.kind == "instant":
            events.append({**base, "ph": "i", "s": "t"})
        else:
            events.append({**base, "ph": "X",
                           "dur": round(span.dur_s * 1e6, 3)})
    for tid, name in sorted(threads.items()):
        events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                       "tid": tid, "args": {"name": name or f"thread-{tid}"}})
    return events


def chrome_trace(tracer: Tracer,
                 metrics_snapshot: Optional[Dict[str, Any]] = None,
                 ) -> Dict[str, Any]:
    """The full trace document: events plus metadata."""
    doc: Dict[str, Any] = {
        "traceEvents": chrome_trace_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": {"source": "repro.obs",
                      "dropped_spans": tracer.dropped},
    }
    if metrics_snapshot is not None:
        doc["otherData"]["metrics"] = metrics_snapshot
    return doc


def export_chrome_trace(path, tracer: Tracer,
                        metrics_snapshot: Optional[Dict[str, Any]] = None,
                        ) -> pathlib.Path:
    """Write the Chrome-trace JSON to ``path`` and return it."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = chrome_trace(tracer, metrics_snapshot)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True, default=str))
    return path
