"""Fault-tolerant training driver: watchdog, failure injection, restart.

The driver owns the full production loop:
    pipeline.get(step) -> train_step -> metrics -> periodic async checkpoint

and layers three protections around it:

  * **checkpoint/restart** — on any step exception the driver restores the
    latest complete checkpoint, seeks the (seekable) data pipeline, and
    replays from there; bounded by ``max_restarts``.  Because both the
    pipeline and the optimizer are deterministic, a restarted run is
    bit-exact with an uninterrupted one (asserted in tests).
  * **step watchdog** — steps slower than ``deadline_factor`` x the running
    median are recorded as stragglers (on real pods: the signal for
    preemptive re-scheduling / hot-spare promotion).
  * **failure injection** — ``FailureInjector`` raises at configured steps,
    used by the integration tests to prove the restart path.

``FailureInjector`` and ``StepWatchdog`` are deliberately generic: the
serving engines (``repro.serving.design_engine``) wire the same pair
around their dispatch loop, so a poisoned replica restarts from its saved
artifact with in-flight requests re-queued — the serving twin of the
checkpoint/restart discipline here.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline


class FailureInjector:
    """Raises RuntimeError at each step in ``fail_at`` exactly once.

    Shared by the training driver (step index) and the serving engines
    (dispatch index): both call ``check`` once per unit of work, so tests
    can poison a specific step/dispatch and assert the restart path.
    """

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.remaining = set(fail_at)
        self.fired: list[int] = []

    def check(self, step: int) -> None:
        if step in self.remaining:
            self.remaining.discard(step)
            self.fired.append(step)
            raise RuntimeError(f"injected failure at step {step}")


class StepWatchdog:
    """Flags steps slower than ``deadline_factor`` x the running median.

    The straggler detector both the training driver and the serving
    engines layer around their work loop: feed each step's wall time to
    :meth:`observe`; once ``min_history`` durations are recorded, a step
    beyond ``deadline_factor`` times the median of the last ``window``
    durations (including the current one) is recorded in ``stragglers``.
    On real pods this is the signal for preemptive re-scheduling /
    hot-spare promotion; here it is telemetry in the reports.
    """

    def __init__(self, deadline_factor: float = 3.0, *, window: int = 20,
                 min_history: int = 5):
        self.deadline_factor = deadline_factor
        self.window = window
        self.min_history = min_history
        self.durations: list[float] = []
        self.stragglers: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record one step duration; True when it is a straggler."""
        self.durations.append(dt)
        if len(self.durations) >= self.min_history:
            med = statistics.median(self.durations[-self.window:])
            if dt > self.deadline_factor * med:
                self.stragglers.append(step)
                return True
        return False


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    checkpoint_every: int = 10
    max_restarts: int = 3
    deadline_factor: float = 3.0


@dataclasses.dataclass
class DriverReport:
    steps_run: int
    restarts: int
    straggler_steps: list
    final_metrics: dict
    losses: list


class TrainingDriver:
    def __init__(self, cfg: DriverConfig, *, train_step: Callable,
                 pipeline: SyntheticTokenPipeline,
                 ckpt: CheckpointManager,
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.injector = injector or FailureInjector()

    def run(self, params: Any, opt_state: Any) -> DriverReport:
        state = {"params": params, "opt": opt_state}
        start_step = 0
        restarts = 0
        losses: list[float] = []
        watchdog = StepWatchdog(self.cfg.deadline_factor)
        metrics: dict = {}

        while True:
            try:
                self.pipeline.seek(start_step)
                step = start_step
                while step < self.cfg.total_steps:
                    t0 = time.monotonic()
                    batch = self.pipeline.get(step)
                    self.injector.check(step)
                    new_params, new_opt, metrics = self.train_step(
                        state["params"], state["opt"], batch)
                    jax.block_until_ready(metrics["loss"])
                    state = {"params": new_params, "opt": new_opt}
                    losses.append(float(metrics["loss"]))
                    watchdog.observe(step, time.monotonic() - t0)
                    step += 1
                    if step % self.cfg.checkpoint_every == 0:
                        self.ckpt.save_async(step, state)
                self.ckpt.wait()
                self.ckpt.save(self.cfg.total_steps, state)
                break
            except Exception:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    start_step = 0          # restart from scratch
                else:
                    state, start_step = (
                        self.ckpt.restore(state, latest)[0], latest)
        self.pipeline.stop()
        return DriverReport(steps_run=self.cfg.total_steps,
                            restarts=restarts,
                            straggler_steps=watchdog.stragglers,
                            final_metrics={k: float(v)
                                           for k, v in metrics.items()},
                            losses=losses)
