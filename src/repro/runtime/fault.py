"""Fault-tolerant training driver: watchdog, failure injection, restart.

The driver owns the full production loop:
    pipeline.get(step) -> train_step -> metrics -> periodic async checkpoint

and layers three protections around it:

  * **checkpoint/restart** — on any step exception the driver restores the
    latest complete checkpoint, seeks the (seekable) data pipeline, and
    replays from there; bounded by ``max_restarts``.  Because both the
    pipeline and the optimizer are deterministic, a restarted run is
    bit-exact with an uninterrupted one (asserted in tests).
  * **step watchdog** — steps slower than ``deadline_factor`` x the running
    median are recorded as stragglers (on real pods: the signal for
    preemptive re-scheduling / hot-spare promotion).
  * **failure injection** — ``FailureInjector`` raises at configured steps,
    used by the integration tests to prove the restart path.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline


class FailureInjector:
    """Raises RuntimeError at each step in ``fail_at`` exactly once."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.remaining = set(fail_at)
        self.fired: list[int] = []

    def check(self, step: int) -> None:
        if step in self.remaining:
            self.remaining.discard(step)
            self.fired.append(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    checkpoint_every: int = 10
    max_restarts: int = 3
    deadline_factor: float = 3.0


@dataclasses.dataclass
class DriverReport:
    steps_run: int
    restarts: int
    straggler_steps: list
    final_metrics: dict
    losses: list


class TrainingDriver:
    def __init__(self, cfg: DriverConfig, *, train_step: Callable,
                 pipeline: SyntheticTokenPipeline,
                 ckpt: CheckpointManager,
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.injector = injector or FailureInjector()

    def run(self, params: Any, opt_state: Any) -> DriverReport:
        state = {"params": params, "opt": opt_state}
        start_step = 0
        restarts = 0
        stragglers: list[int] = []
        losses: list[float] = []
        durations: list[float] = []
        metrics: dict = {}

        while True:
            try:
                self.pipeline.seek(start_step)
                step = start_step
                while step < self.cfg.total_steps:
                    t0 = time.monotonic()
                    batch = self.pipeline.get(step)
                    self.injector.check(step)
                    new_params, new_opt, metrics = self.train_step(
                        state["params"], state["opt"], batch)
                    jax.block_until_ready(metrics["loss"])
                    state = {"params": new_params, "opt": new_opt}
                    losses.append(float(metrics["loss"]))
                    dt = time.monotonic() - t0
                    durations.append(dt)
                    if len(durations) >= 5:
                        med = statistics.median(durations[-20:])
                        if dt > self.cfg.deadline_factor * med:
                            stragglers.append(step)
                    step += 1
                    if step % self.cfg.checkpoint_every == 0:
                        self.ckpt.save_async(step, state)
                self.ckpt.wait()
                self.ckpt.save(self.cfg.total_steps, state)
                break
            except Exception:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    start_step = 0          # restart from scratch
                else:
                    state, start_step = (
                        self.ckpt.restore(state, latest)[0], latest)
        self.pipeline.stop()
        return DriverReport(steps_run=self.cfg.total_steps,
                            restarts=restarts, straggler_steps=stragglers,
                            final_metrics={k: float(v)
                                           for k, v in metrics.items()},
                            losses=losses)
