from repro.runtime import elastic, fault

__all__ = ["elastic", "fault"]
