"""Elastic scaling: reshard a checkpoint onto a different mesh.

The checkpoint stores leaves unsharded (see ``repro.checkpoint.ckpt``), so
scaling a job from mesh A to mesh B is: rebuild the param/opt shardings from
the SAME logical axes on the new mesh (divisibility pruning adapts
automatically), then ``device_put`` each restored leaf.  The binding rules
being the single source of truth (core.binding) is what makes this safe —
there is no per-mesh layout metadata to migrate.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.checkpoint.ckpt import CheckpointManager
from repro.launch import shardings as sh
from repro.nn import module as module_lib


def reshard_checkpoint(ckpt: CheckpointManager, cfg, new_mesh: Mesh,
                       *, step=None) -> tuple[Any, int]:
    """Restore {params, opt} onto ``new_mesh`` with freshly derived
    shardings.  Works across any device count whose axes divide (pruned
    otherwise)."""
    from repro.models import encdec
    from repro.nn import transformer
    from repro.optim import adamw

    rules = sh.rules_for(cfg)
    if getattr(cfg, "is_encoder_decoder", False):
        specs = encdec.model_specs(cfg)
    else:
        specs = transformer.model_specs(cfg)
    abstract = module_lib.abstract_tree(specs)
    axes = module_lib.axes_tree(specs)
    p_sh = sh.tree_shardings(abstract, axes, new_mesh, rules)
    o_sh = sh.tree_shardings(adamw.abstract_state(abstract),
                             adamw.state_axes(axes), new_mesh, rules)
    like = {"params": abstract, "opt": adamw.abstract_state(abstract)}
    like_host = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), like)
    tree, got_step = ckpt.restore(like_host, step,
                                  shardings={"params": p_sh, "opt": o_sh})
    return tree, got_step
