"""AdamW with cosine schedule, global-norm clipping, and optional int8
error-feedback gradient compression (see ``repro.optim.compress``).

Pure-pytree implementation (no optax dependency in this offline container);
the optimizer state is sharded like the parameters (first-moment/second-
moment trees inherit the param logical axes), which the launcher exploits
for ZeRO-style state sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.end_lr + 0.5 * (cfg.peak_lr - cfg.end_lr) * (
        1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any) -> dict:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(abstract_params: Any) -> dict:
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), abstract_params)
    return {"mu": z, "nu": z, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(param_axes: Any) -> dict:
    """Optimizer-state logical axes: the parameters' axes, with ``embed``
    additionally bound to the data axis (rule ``opt_embed -> data``).

    This is ZeRO-style optimizer-state sharding: mu/nu shard over BOTH mesh
    axes wherever a tensor has an embed dimension (every projection, norm
    and embedding does), cutting per-device optimizer bytes 16x.  GSPMD
    materialises the reduce-scatter (grads -> opt sharding) and all-gather
    (updated params -> compute sharding) that ZeRO implies.
    """
    def remap(axes):
        return tuple("opt_embed" if a == "embed" else a for a in axes)

    mapped = jax.tree_util.tree_map(
        remap, param_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
    return {"mu": mapped, "nu": mapped, "step": ()}


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, state: dict,
                  ) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / b1c
        nu_hat = nu / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
