"""Gradient compression with error feedback (distributed-optimization trick).

Int8 stochastic-free (deterministic RNE) quantisation with per-tensor
scales and an error-feedback accumulator: the quantisation residual is
carried to the next step, so compression bias vanishes asymptotically
(Karimireddy et al., "Error Feedback Fixes SignSGD").

The quantised gradients are what crosses the network: under data
parallelism the all-reduce payload drops 4x (f32 -> i8 + one f32 scale).
In the JAX SPMD model the reduction itself is emitted by the partitioner;
we expose both (a) a transparent optimizer wrapper (quantise -> dequantise
around the psum boundary — the compiler reduces the i32-upcast payload) and
(b) a shard_map collective for explicit control (used in the hillclimb).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, err: Any) -> tuple[Any, Any]:
    """Quantise (grads + carried error); return (dequantised grads, new err).

    The dequantised value is what the optimizer consumes; the difference is
    carried.  Communication happens on the int8 payload.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def compressed_psum(axis_name: str):
    """shard_map-level compressed all-reduce: int8 payload, i32 reduction.

    Usage inside shard_map:  g = compressed_psum('data')(g_local)
    """
    def reduce_fn(x: jax.Array) -> jax.Array:
        q, s = quantize_int8(x.astype(jnp.float32))
        # payload on the wire: int8 (upcast to i32 for the reduction) + scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per shard: reduce the max scale for a safe bound
        s_max = jax.lax.pmax(s, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        del n
        return (total.astype(jnp.float32) * s_max).astype(x.dtype)
    return reduce_fn
