"""A whisper_tiny-shaped transformer encoder block in JAX — the tensor-level
twin of the scalar loop-nest program in
``repro.core.frontend.transformer_encoder_block``.

The block is the million-op scaling target for the compile path (ISSUE: the
default geometry below traces to ~1.7M raw ops) and the first sequence
model through the nn -> loop-nest bridge:

    x = x + Attn(RMS(x));  x = x + MLP(RMS(x));  out = RMS(x)

``forward`` mirrors the DFG's *functional model* — the softmax uses the
paper's Taylor-exp approximation (order-k series with 2^r range reduction),
not ``jax.nn.softmax`` — so the fp32 DFG matches it tightly, not just to
approximation error.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import FORMATS, quantize
from repro.nn import graph as nng
from repro.nn.attention import out_project, qkv_project


def build(seq: int = 16, d_model: int = 64, n_heads: int = 4,
          ffn: int = 256, *, params=None,
          taylor_order: int = 8, eps: float = 1e-5) -> nng.ModuleGraph:
    """The encoder block as a declarative :class:`~repro.nn.graph.ModuleGraph`.

    Node names pin the hand-written
    ``frontend.transformer_encoder_block`` memref/label scheme, so the
    bridged DFG is bit-identical (same ``graph_fingerprint``) to the
    hand-written one.  Defaults are whisper_tiny-shaped but trimmed to a
    16-token window; ``params`` optionally binds a trained tree.
    """
    nodes = [
        nng.Attention("attn", d_model=d_model, n_heads=n_heads,
                      taylor_order=taylor_order, eps=eps),
        nng.MLP("mlp", d_model=d_model, hidden=ffn, eps=eps),
        nng.RMSNorm("ln_post", dim=d_model, eps=eps),
    ]
    return nng.ModuleGraph(
        "encoder_block", (seq, d_model), nodes, params=params,
        forward_fn=functools.partial(forward, n_heads=n_heads,
                                     taylor_order=taylor_order, eps=eps),
        meta={"seq": seq, "d_model": d_model, "n_heads": n_heads,
              "ffn": ffn, "taylor_order": taylor_order})


def specs(seq: int = 16, d_model: int = 64, n_heads: int = 4,
          ffn: int = 256) -> dict:
    """The ParamSpec tree (derived from :func:`build` — one description)."""
    return build(seq, d_model, n_heads, ffn).specs()


def taylor_exp(x: jax.Array, *, order: int = 8,
               range_reduce: int = 2) -> jax.Array:
    """exp(x) the way the DFG computes it: k-th order Taylor series on
    x/2^r, squared r times (``Context.exp`` + ``frontend.soft_max``)."""
    z = x * (1.0 / (1 << range_reduce))
    acc = jnp.ones_like(z) + z
    zk = z
    fact = 1.0
    for k in range(2, order + 1):
        zk = zk * z
        fact *= k
        acc = acc + zk * (1.0 / fact)
    for _ in range(range_reduce):
        acc = acc * acc
    return acc


def _softmax_taylor(scores: jax.Array, *, order: int) -> jax.Array:
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = taylor_exp(scores - m, order=order)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _rms(x: jax.Array, gamma: jax.Array, *, eps: float) -> jax.Array:
    # sum * (1/D), matching the DFG's reduction + const-multiply form
    ms = jnp.sum(x * x, axis=-1, keepdims=True) * (1.0 / x.shape[-1])
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma


def forward(params: dict, x: jax.Array, *, n_heads: int,
            taylor_order: int = 8, eps: float = 1e-5,
            fmt: Optional[str] = None) -> jax.Array:
    """x: (B, L, d_model) -> (B, L, d_model).

    fmt: FloPoCo format key ('5_11' | '5_4' | '5_3') — quantises weights
    and inter-layer activations, modelling the reduced-precision datapath
    (coarser than the DFG's per-op functional model, so quantised
    comparisons need the loose BraggNN-style tolerances).
    """
    q = (lambda a: quantize(a, FORMATS[fmt])) if fmt else (lambda a: a)
    p = jax.tree_util.tree_map(q, params)
    x = q(jnp.asarray(x, dtype=jnp.float32))

    # --- attention sub-block ------------------------------------------------
    h = q(_rms(x, p["attn"]["norm"]["gamma"], eps=eps))
    qh, kh, vh = qkv_project(p["attn"], h)                 # (B,L,H,dh)
    dh = qh.shape[-1]
    scores = q(jnp.einsum("bshk,bthk->bhst", qh, kh) / jnp.sqrt(
        jnp.float32(dh)))
    attn = q(_softmax_taylor(scores, order=taylor_order))
    y = q(jnp.einsum("bhst,bthk->bshk", attn, vh))
    x = q(x + q(out_project(p["attn"], y)))

    # --- MLP sub-block ------------------------------------------------------
    h = q(_rms(x, p["mlp"]["norm"]["gamma"], eps=eps))
    h = q(jax.nn.relu(h @ p["mlp"]["fc1"]["w"].T + p["mlp"]["fc1"]["b"]))
    h = q(h @ p["mlp"]["fc2"]["w"].T + p["mlp"]["fc2"]["b"])
    x = q(x + h)

    return q(_rms(x, p["ln_post"]["gamma"], eps=eps))
