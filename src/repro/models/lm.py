"""Causal language model: loss, train forward, serve step.

``train_loss`` is the objective lowered by the train_4k cells;
``serve_step`` (one token, cached) is what the decode cells lower.
``prefill`` is the prefill_32k workload: full-sequence forward that also
returns the logits of the last position (the serving prefill contract).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import transformer

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def train_loss(cfg: ModelConfig, params, batch: dict) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy.  batch: {tokens, targets[, patches]}.

    Returns (loss, metrics).
    """
    logits, aux = transformer.forward(
        cfg, params, batch["tokens"], patches=batch.get("patches"))
    targets = batch["targets"]
    if logits.shape[1] != targets.shape[1]:      # VLM: drop patch positions
        logits = logits[:, -targets.shape[1]:, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + AUX_WEIGHT * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": jnp.sum(mask)}


def prefill(cfg: ModelConfig, params, tokens: jax.Array,
            patches: Optional[jax.Array] = None) -> jax.Array:
    """Prefill workload: logits at the final position, (B, vocab).

    Only the last position is unembedded — materialising (B, S, vocab)
    logits at 32k prefill would cost GBs of HBM and S x the unembed FLOPs
    for values that are thrown away.
    """
    logits, _ = transformer.forward(cfg, params, tokens, patches=patches,
                                    last_logit_only=True)
    return logits[:, -1, :]


def serve_step(cfg: ModelConfig, params, tokens: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step: greedy next token + updated cache.

    tokens: (B, 1) current token; pos: (B,) its position index.
    Returns (next_token (B,), new_cache).
    """
    logits, new_cache = transformer.decode_step(cfg, params, tokens, cache,
                                                pos)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, new_cache


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS = 6·N (dense) or 6·N_active (MoE) per token (§Roofline)."""
    from repro.nn import module as module_lib
    specs = transformer.model_specs(cfg)
    if cfg.n_experts == 0:
        n = module_lib.param_count(specs)
        # embeddings participate once (unembed matmul), not 6x; keep the
        # standard 6N convention which already approximates this.
        return 6.0 * n
    # MoE: count non-expert params fully + only top-k of routed experts
    import numpy as np
    total = 0
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, module_lib.ParamSpec))[0]
    for path, spec in leaves_with_path:
        keys = [getattr(k, "key", str(k)) for k in path]
        size = int(np.prod(spec.shape))
        if "experts" in keys:
            e = cfg.n_experts_padded or cfg.n_experts
            size = size // e * cfg.experts_per_token
        total += size
    return 6.0 * total
