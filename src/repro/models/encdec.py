"""Encoder-decoder backbone (whisper-tiny).

Per the brief, the conv/audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model).  The encoder is a
bidirectional transformer over frames with learned positions; the decoder is
a causal transformer with cross-attention into the encoder output.  Decoder
positions are sized from the assigned shape (synthetically extended past
whisper's trained 448 — shape exercise only; see DESIGN.md).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import attention, layers, module, transformer

ACCUM = jnp.float32


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.layernorm_specs(cfg.d_model),
        "attn": attention.attn_specs(cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.resolved_head_dim),
        "ln2": layers.layernorm_specs(cfg.d_model),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.layernorm_specs(cfg.d_model),
        "self_attn": attention.attn_specs(cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads,
                                          cfg.resolved_head_dim),
        "ln_cross": layers.layernorm_specs(cfg.d_model),
        "cross_attn": attention.attn_specs(cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads,
                                           cfg.resolved_head_dim),
        "ln2": layers.layernorm_specs(cfg.d_model),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, gated=False),
    }


def model_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": layers.embedding_specs(cfg.vocab_size, cfg.d_model),
        "enc_pos": {"table": module.ParamSpec(
            (cfg.encoder_len, cfg.d_model), (None, "embed"), scale=0.02)},
        "dec_pos": {"table": module.ParamSpec(
            (cfg.max_position, cfg.d_model), (None, "embed"), scale=0.02)},
        "encoder": module.stack(_enc_layer_specs(cfg), cfg.n_encoder_layers),
        "decoder": module.stack(_dec_layer_specs(cfg), cfg.n_layers),
        "enc_norm": layers.layernorm_specs(cfg.d_model),
        "dec_norm": layers.layernorm_specs(cfg.d_model),
    }


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, d) stubbed frontend embeddings."""
    dt = jnp.dtype(cfg.activation_dtype)
    x = frames.astype(dt)
    t = x.shape[1]
    pos = params["enc_pos"]["table"].astype(dt)
    x = x + pos[jnp.minimum(jnp.arange(t), pos.shape[0] - 1)]
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, p):
        h = layers.layernorm(p["ln1"], x, eps=cfg.norm_eps)
        y = attention.self_attention(
            p["attn"], h, positions, n_kv_heads=cfg.n_kv_heads, causal=False,
            rope_theta=cfg.rope_theta, quant=cfg.quant_format,
            block_size=cfg.attn_block_size)
        x = x + y
        h = layers.layernorm(p["ln2"], x, eps=cfg.norm_eps)
        x = x + layers.mlp(p["mlp"], h, act="gelu", quant=cfg.quant_format)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.layernorm(params["enc_norm"], x, eps=cfg.norm_eps)


def _dec_layer(cfg: ModelConfig, p: dict, x, positions, enc,
               cache: Optional[dict] = None,
               pos_scalar: Optional[jax.Array] = None):
    h = layers.layernorm(p["ln1"], x, eps=cfg.norm_eps)
    if cache is None:
        y = attention.self_attention(
            p["self_attn"], h, positions, n_kv_heads=cfg.n_kv_heads,
            causal=True, rope_theta=cfg.rope_theta, quant=cfg.quant_format,
            block_size=cfg.attn_block_size)
        new_cache = None
    else:
        y, new_cache = attention.decode_attention(
            p["self_attn"], h, cache, pos_scalar,
            n_kv_heads=cfg.n_kv_heads, rope_theta=cfg.rope_theta,
            quant=cfg.quant_format)
    x = x + y
    h = layers.layernorm(p["ln_cross"], x, eps=cfg.norm_eps)
    x = x + attention.cross_attention(p["cross_attn"], h, enc,
                                      n_kv_heads=cfg.n_kv_heads,
                                      quant=cfg.quant_format)
    h = layers.layernorm(p["ln2"], x, eps=cfg.norm_eps)
    x = x + layers.mlp(p["mlp"], h, act="gelu", quant=cfg.quant_format)
    return x, new_cache


def decode_forward(cfg: ModelConfig, params, tokens: jax.Array,
                   enc: jax.Array, last_logit_only: bool = False
                   ) -> jax.Array:
    """Teacher-forced decoder forward (training).  Returns logits."""
    dt = jnp.dtype(cfg.activation_dtype)
    x = layers.embed(params["embed"], tokens, dtype=dt)
    b, s = tokens.shape
    pos_tab = params["dec_pos"]["table"].astype(dt)
    x = x + pos_tab[jnp.minimum(jnp.arange(s), pos_tab.shape[0] - 1)]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        x, _ = _dec_layer(cfg, p, x, positions, enc)
        return x, None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layers.layernorm(params["dec_norm"], x, eps=cfg.norm_eps)
    if last_logit_only:
        x = x[:, -1:, :]
    return layers.unembed(params["embed"], x, quant=cfg.quant_format)


def train_loss(cfg: ModelConfig, params, batch: dict):
    """batch: {frames (B,T,d), tokens (B,S), targets (B,S)}."""
    enc = encode(cfg, params, batch["frames"])
    logits = decode_forward(cfg, params, batch["tokens"], enc)
    logp = jax.nn.log_softmax(logits.astype(ACCUM), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                               axis=-1)[..., 0]
    mask = (batch["targets"] >= 0).astype(ACCUM)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dh = cfg.resolved_head_dim
    per = attention.kv_cache_specs(batch, max_len, cfg.n_kv_heads, dh)
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + tuple(s.shape),
                                       s.dtype), per)
    return {"self": stacked,
            "enc": jax.ShapeDtypeStruct(
                (batch, cfg.encoder_len, cfg.d_model),
                jnp.dtype(cfg.activation_dtype))}


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes matching ``cache_specs``."""
    kv = ("layers", "batch", None, "kv_heads", "head_dim")
    return {"self": {"k": kv, "v": kv},
            "enc": ("batch", None, None)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc: Optional[jax.Array] = None) -> dict:
    dh = cfg.resolved_head_dim
    per = [attention.init_kv_cache(batch, max_len, cfg.n_kv_heads, dh)
           for _ in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    if enc is None:
        enc = jnp.zeros((batch, cfg.encoder_len, cfg.d_model),
                        jnp.dtype(cfg.activation_dtype))
    return {"self": stacked, "enc": enc}


def serve_step(cfg: ModelConfig, params, tokens: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step against a fixed encoder output held in the cache."""
    dt = jnp.dtype(cfg.activation_dtype)
    x = layers.embed(params["embed"], tokens, dtype=dt)
    pos_tab = params["dec_pos"]["table"].astype(dt)
    x = x + pos_tab[jnp.minimum(pos[:, None], pos_tab.shape[0] - 1)]
    enc = cache["enc"]

    # cache as loop carry with in-place dynamic updates (no ys double-buffer)
    def body(carry, p):
        x, cache_stack, idx = carry
        c = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False),
            cache_stack)
        x, nc = _dec_layer(cfg, p, x, pos[:, None], enc, cache=c,
                           pos_scalar=pos)
        cache_stack = jax.tree_util.tree_map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(
                a, u.astype(a.dtype), idx, 0), cache_stack, nc)
        return (x, cache_stack, idx + 1), None

    (x, new_self, _), _ = jax.lax.scan(
        body, (x, cache["self"], jnp.zeros((), jnp.int32)),
        params["decoder"])
    x = layers.layernorm(params["dec_norm"], x, eps=cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, quant=cfg.quant_format)
    next_tok = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
    return next_tok, {"self": new_self, "enc": enc}
