"""BraggNN in JAX (paper Listing 5) — the production tensor-level twin of
the scalar loop-nest program in ``repro.core.frontend.braggnn``.

Used three ways:
  * as the oracle the scalar DFG is behaviourally verified against;
  * as the deployable low-latency inference path (fused jit, weights
    quantised to FloPoCo (wE,wF) and resident in VMEM via the Pallas conv /
    matmul kernels);
  * as a trainable model (QAT with ``ste_quantize``) for the precision
    study (paper Fig. 7).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import FORMATS, quantize
from repro.nn import graph as nng

ACCUM = jnp.float32


def build(s: int = 1, img: int = 11, *, params=None,
          taylor_order: int = 8) -> nng.ModuleGraph:
    """BraggNN(s) as a declarative :class:`~repro.nn.graph.ModuleGraph`.

    The single-source model description: ``.specs()`` is the training
    param tree, and ``repro.hls.compile(build(...))`` auto-lowers it to
    the loop-nest DFG via the bridge.  Node names/prefixes/labels pin the
    hand-written ``frontend.braggnn`` memref scheme, so the bridged DFG is
    bit-identical (same ``graph_fingerprint``) to the hand-written one.
    ``params`` optionally binds a trained param tree for serving.
    """
    c1, c2 = 16 * s, 8 * s
    h3 = img - 6
    n_flat = 2 * s * h3 * h3
    dims = [n_flat, 16 * s, 8 * s, 4 * s, 2]
    nodes = [
        nng.Conv2d("conv1", in_channels=1, out_channels=c1, kernel=3,
                   out_name_="feat", label_="cnn_layers_1"),
        nng.NonLocalBlock("nlb", channels=c1, mid_channels=c2,
                          taylor_order=taylor_order),
        nng.ReLU(out_name_="cnn2_relu0", label_="cnn_layers_2.relu0"),
        nng.Conv2d("conv2a", in_channels=c1, out_channels=c2, kernel=3,
                   prefix_="cnn2.conv1", out_name_="cnn2_conv1",
                   label_="cnn_layers_2.conv1"),
        nng.ReLU(out_name_="cnn2_relu1", label_="cnn_layers_2.relu1"),
        nng.Conv2d("conv2b", in_channels=c2, out_channels=2 * s, kernel=3,
                   prefix_="cnn2.conv2", out_name_="cnn2_conv2",
                   label_="cnn_layers_2.conv2"),
        nng.ReLU(out_name_="cnn2_relu2", label_="cnn_layers_2.relu2"),
        nng.Flatten(out_name_="flat"),
    ]
    for li in range(4):
        nodes.append(nng.Linear(
            f"dense{li}", in_features=dims[li], out_features=dims[li + 1],
            prefix_=f"dense.{li}", out_name_=f"dense_{li}_out",
            label_=f"dense.{li}"))
        if li < 3:
            nodes.append(nng.ReLU(out_name_=f"dense_{li}_relu",
                                  label_=f"dense.{li}.relu"))
    nodes.append(nng.OutputReLU(label_="dense.final_relu"))
    # functools.partial (not a lambda) keeps the module picklable, which is
    # what lets Design.save persist the tensor serving backend
    return nng.ModuleGraph(
        "braggnn", (1, 1, img, img), nodes, params=params,
        forward_fn=functools.partial(forward, s=s),
        meta={"s": s, "img": img})


def specs(s: int = 1, img: int = 11) -> dict:
    """The ParamSpec tree (derived from :func:`build` — one description)."""
    return build(s, img).specs()


def _conv(x: jax.Array, w: jax.Array, b: Optional[jax.Array]) -> jax.Array:
    """Valid-padding NCHW conv (matches the loop-nest semantics)."""
    y = jax.lax.conv_general_dilated(
        x.astype(ACCUM), w.astype(ACCUM), window_strides=(1, 1),
        padding="VALID", dimension_numbers=("NCHW", "OIHW", "NCHW"))
    if b is not None:
        y = y + b.astype(ACCUM)[None, :, None, None]
    return y


def forward(params: dict, x: jax.Array, *, s: int = 1,
            fmt: Optional[str] = None) -> jax.Array:
    """x: (B, 1, img, img) -> (B, 2) peak centre estimates.

    fmt: FloPoCo format key ('5_11' | '5_4' | '5_3') — quantises weights
    *and* inter-layer activations, modelling the paper's reduced-precision
    datapath end to end.
    """
    q = (lambda a: quantize(a, FORMATS[fmt])) if fmt else (lambda a: a)
    p = jax.tree_util.tree_map(q, params)

    feat = q(_conv(x, p["conv1"]["w"], p["conv1"]["b"]))       # (B,c1,9,9)
    b, c1, h, w = feat.shape
    n = h * w

    def conv1x1(name):
        return q(_conv(feat, p["nlb"][name]["w"], None))       # (B,c2,9,9)

    theta, phi, g = conv1x1("theta"), conv1x1("phi"), conv1x1("g")
    c2 = theta.shape[1]
    tf = theta.reshape(b, c2, n)
    pf = phi.reshape(b, c2, n)
    gf = g.reshape(b, c2, n)
    scores = q(jnp.einsum("bci,bcj->bij", tf, pf))             # (B,n,n)
    attn = jax.nn.softmax(scores, axis=-1)
    y = q(jnp.einsum("bij,bcj->bci", attn, gf)).reshape(b, c2, h, w)
    z = q(_conv(y, p["nlb"]["out"]["w"], None))
    feat = q(feat + z)

    r = jax.nn.relu(feat)
    r = jax.nn.relu(q(_conv(r, p["conv2a"]["w"], p["conv2a"]["b"])))
    r = jax.nn.relu(q(_conv(r, p["conv2b"]["w"], p["conv2b"]["b"])))
    flat = r.reshape(b, -1)
    for li in range(4):
        d = p[f"dense{li}"]
        flat = q(jnp.einsum("bk,nk->bn", flat, d["w"].astype(ACCUM))
                 + d["b"].astype(ACCUM))
        flat = jax.nn.relu(flat)
    return flat


def params_from_feeds(feeds: dict[str, np.ndarray], s: int = 1) -> dict:
    """Adapt the scalar-DFG feed dict (frontend.braggnn names, batch index 0)
    into this model's param tree — lets the testbench drive both paths with
    identical weights."""
    f = {k: np.asarray(v)[0] for k, v in feeds.items()}
    out = {
        "conv1": {"w": f["conv1.weight"], "b": f["conv1.bias"]},
        "nlb": {
            "theta": {"w": f["nlb.theta.weight"]},
            "phi": {"w": f["nlb.phi.weight"]},
            "g": {"w": f["nlb.g.weight"]},
            "out": {"w": f["nlb.out_cnn.weight"]},
        },
        "conv2a": {"w": f["cnn2.conv1.weight"], "b": f["cnn2.conv1.bias"]},
        "conv2b": {"w": f["cnn2.conv2.weight"], "b": f["cnn2.conv2.bias"]},
    }
    for li in range(4):
        out[f"dense{li}"] = {"w": f[f"dense.{li}.weight"],
                             "b": f[f"dense.{li}.bias"]}
    return jax.tree_util.tree_map(jnp.asarray, out)


def synthetic_peaks(key: jax.Array, n: int, img: int = 11
                    ) -> tuple[jax.Array, jax.Array]:
    """Gaussian-blob Bragg-peak surrogates + centre labels (for training
    demos and the precision/accuracy study)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.uniform(k1, (n, 2), minval=3.0, maxval=img - 3.0)
    sigma = jax.random.uniform(k2, (n, 1, 1), minval=0.8, maxval=1.6)
    yy, xx = jnp.mgrid[0:img, 0:img]
    blob = jnp.exp(-(((yy[None] - centers[:, 0, None, None]) ** 2
                      + (xx[None] - centers[:, 1, None, None]) ** 2)
                     / (2 * sigma ** 2)))
    noise = 0.02 * jax.random.normal(k3, blob.shape)
    imgs = (blob + noise)[:, None, :, :].astype(jnp.float32)
    labels = centers / img                      # normalised to [0,1]
    return imgs, labels.astype(jnp.float32)
