"""Assembled models: causal LM, BraggNN, encoder-decoder, transformer block."""

from repro.models import braggnn, encdec, lm, transformer

__all__ = ["braggnn", "encdec", "lm", "transformer"]
