"""Assembled models: causal LM, BraggNN, encoder-decoder."""

from repro.models import braggnn, encdec, lm

__all__ = ["braggnn", "encdec", "lm"]
