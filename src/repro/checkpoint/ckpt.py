"""Sharded, atomic, async checkpointing with elastic restore.

Layout::

    <dir>/step_000123/           (atomic: written as .tmp_step_000123, renamed)
        manifest.json            tree structure, shapes, dtypes, step
        leaf_00000.npy ...       one file per pytree leaf

Guarantees:
  * **Atomicity** — a checkpoint directory either exists completely (the
    rename happened after fsync of every leaf) or not at all; crash-during-
    save never corrupts the latest complete checkpoint.
  * **Async** — ``save_async`` snapshots device arrays to host, then writes
    on a background thread; the step loop continues.  ``wait()`` joins.
  * **Elastic restore** — leaves are stored unsharded (gathered); restore
    takes target shardings for ANY mesh shape and ``jax.device_put``s
    accordingly, so a job checkpointed on N chips resumes on M chips
    (exercised in tests with different mesh shapes).
  * **Retention** — keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any) -> list[str]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for p, _leaf in paths:
        out.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in p))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Any) -> pathlib.Path:
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host memory synchronously (consistent point), write async
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self._pending = self._pool.submit(self._write, step, host_tree)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> pathlib.Path:
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = jax.tree_util.tree_flatten(host_tree)
        names = _leaf_paths(host_tree)
        manifest = {"step": step, "n_leaves": len(leaves), "names": names,
                    "shapes": [list(np.shape(x)) for x in leaves],
                    "dtypes": [str(np.asarray(x).dtype) for x in leaves],
                    "treedef": str(treedef)}
        for i, leaf in enumerate(leaves):
            with open(tmp / f"leaf_{i:05d}.npy", "wb") as f:
                np.save(f, np.asarray(leaf))
                f.flush()
                os.fsync(f.fileno())
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                    # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> tuple[Any, int]:
        """Restore into the structure of ``like``; optionally place each
        leaf with ``shardings`` (elastic: any mesh works)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:09d}"
        manifest = json.loads((path / "manifest.json").read_text())
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), (
            "checkpoint/tree structure mismatch")
        loaded = []
        for i, ref in enumerate(leaves_like):
            arr = np.load(path / f"leaf_{i:05d}.npy")
            assert list(arr.shape) == list(np.shape(ref)), (
                f"leaf {i} ({manifest['names'][i]}): shape "
                f"{arr.shape} != {np.shape(ref)}")
            loaded.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        return tree, step
