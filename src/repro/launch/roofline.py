"""Three-term roofline analysis from the dry-run artifacts (§Roofline).

Terms per (arch x shape) cell on the single-pod mesh (v5e constants):

  compute term    = HLO dot FLOPs per device / 197 TFLOP/s
  memory term     = HBM bytes per device    / 819 GB/s
  collective term = wire bytes per device   / 50 GB/s (per-link ICI)

Sources and honesty notes (full methodology in EXPERIMENTS.md):
  * dot FLOPs and collective bytes come from ``compiled.as_text()`` via
    ``hlo_parse.analyze`` — *trip-count corrected* (XLA's cost_analysis
    visits while bodies once; scan trip counts are recovered from the loop
    condition constants and multiplied through, validated exact on no-scan
    programs).
  * HBM bytes use an explicit analytic traffic model (parameters, optimizer
    state, saved activations under remat, KV cache) because fusion decisions
    make byte-level traffic unrecoverable from HLO text; the model is
    validated against cost_analysis on scan-free configs.
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the ratio
    MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/attention overheads.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12        # bf16 per chip (v5e)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
CHIPS_SINGLE = 256


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float            # global, analytic
    hlo_flops: float              # global = per-device x chips
    params_bytes_per_device: float
    notes: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip FLOP roof achieved at the modelled bound:
        (useful model FLOPs / chips / bound_time) / peak."""
        if self.bound_time <= 0:
            return 0.0
        per_chip = self.model_flops / CHIPS_SINGLE
        return (per_chip / self.bound_time) / PEAK_FLOPS


# ---------------------------------------------------------------------------
# analytic models
# ---------------------------------------------------------------------------

def model_flops_cell(arch: str, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of the cell (6ND train, 2N_active
    per generated token for decode, 2ND prefill)."""
    from repro.configs import registry
    from repro.models import lm as lm_lib
    cfg = registry.get_config(arch)
    shape = registry.get_shape(shape_name)
    per_token_train = lm_lib.model_flops_per_token(cfg)   # 6N
    n_active_2x = per_token_train / 3.0                   # 2N
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return per_token_train * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return n_active_2x * tokens
    # decode: one token per sequence
    return n_active_2x * shape.global_batch


def memory_bytes_cell(arch: str, shape_name: str, rec: dict) -> float:
    """Per-device HBM traffic model for one step (documented in
    EXPERIMENTS.md §Roofline-methodology)."""
    from repro.configs import registry
    cfg = registry.get_config(arch)
    shape = registry.get_shape(shape_name)
    p_bytes = float(rec.get("params_bytes_per_device", 0.0))
    p_elems = p_bytes / 4.0
    d = cfg.d_model
    L = cfg.n_layers
    n_micro = max(1, getattr(cfg, "microbatches", 1))
    # tokens per device = global tokens / data-parallel ways (batch shards
    # over the 16-wide data axis when divisible, else replicates)
    dp = 16 if shape.global_batch % 16 == 0 else 1
    tokens_local = shape.global_batch * shape.seq_len / dp

    if shape.kind == "train":
        # weights f32: fwd+bwd reads per microbatch, grad write, AdamW rw
        w_traffic = p_elems * 4.0 * (2 * n_micro + 5)
        act_traffic = 8.0 * L * tokens_local * d * 2.0  # bf16, remat=full
        return w_traffic + act_traffic
    if shape.kind == "prefill":
        w_traffic = p_bytes
        act_traffic = 4.0 * L * tokens_local * d * 2.0
        return w_traffic + act_traffic
    # decode: all weights once + read the whole cache shard + write slot
    mem = rec.get("memory", {})
    cache_bytes = float(mem.get("alias_bytes", 0.0))  # donated cache shard
    return p_bytes + cache_bytes + 2.0 * tokens_local / shape.seq_len * d * 2


def load_cells(dryrun_dir: str = "experiments/dryrun",
               mesh: str = "single") -> list[RooflineRow]:
    rows = []
    for path in sorted(pathlib.Path(dryrun_dir).glob(f"*__{mesh}.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok":
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        dot_dev = float(rec["hlo"]["dot_flops_per_device"])
        coll_dev = float(rec["hlo"]["collective_bytes_per_device"])
        mem_dev = memory_bytes_cell(arch, shape_name, rec)
        rows.append(RooflineRow(
            arch=arch, shape=shape_name,
            compute_s=dot_dev / PEAK_FLOPS,
            memory_s=mem_dev / HBM_BW,
            collective_s=coll_dev / LINK_BW,
            model_flops=model_flops_cell(arch, shape_name),
            hlo_flops=dot_dev * CHIPS_SINGLE,
            params_bytes_per_device=rec.get("params_bytes_per_device", 0),
        ))
    return rows


_MOVE_HINTS = {
    "compute": ("increase arithmetic intensity per chip (larger per-device "
                "batch, fuse quantisation into the matmul kernel)"),
    "memory": ("cut HBM traffic: bf16/(wE,wF) weights, fewer remat "
               "recomputes, keep KV in-place (donation)"),
    "collective": ("rebind the dominant sharding axis: fewer TP "
                   "activation all-reduces (SP/FSDP), bf16 reductions, "
                   "overlap collectives with compute"),
}


def to_markdown(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
           "what moves the bound |\n|" + "---|" * 10)
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | **{r.dominant}** | "
            f"{r.model_flops:.3e} | {r.useful_ratio:.2f} | "
            f"{r.roofline_fraction:.3f} | {_MOVE_HINTS[r.dominant]} |")
    return "\n".join(lines)


def main() -> None:  # pragma: no cover
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load_cells(args.dir)
    print(to_markdown(rows))


if __name__ == "__main__":  # pragma: no cover
    main()
