"""Serving launcher: continuous-batching engine on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --tiny \
        --requests 12
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.nn import module as module_lib, transformer
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_tiny(args.arch) if args.tiny \
        else registry.get_config(args.arch)
    if getattr(cfg, "is_encoder_decoder", False):
        raise SystemExit("serve.py targets decoder-only archs")
    specs = transformer.model_specs(cfg)
    params = module_lib.init_tree(specs, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_len=args.max_len)

    rng = jax.random.key(1)
    for i in range(args.requests):
        k = jax.random.fold_in(rng, i)
        n = 4 + int(jax.random.randint(k, (), 0, 12))
        prompt = jax.random.randint(k, (n,), 1, cfg.vocab_size).tolist()
        engine.submit(prompt, max_new_tokens=args.new_tokens)

    t0 = time.monotonic()
    finished = engine.run_until_drained()
    dt = time.monotonic() - t0
    s = engine.stats()
    print(f"[serve] {s['requests']} requests, {s['generated_tokens']} tokens "
          f"in {dt:.1f}s ({s['generated_tokens']/dt:.1f} tok/s, "
          f"{dt/max(s['ticks'],1)*1e3:.1f} ms/tick), "
          f"ttft={s['mean_ttft_s']*1e3:.0f}ms")
    assert len(finished) == args.requests


if __name__ == "__main__":
    main()
