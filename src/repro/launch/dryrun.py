import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

The first two lines above MUST stay first: jax locks the device count at
first initialisation, and the production meshes need 512 placeholder CPU
devices.  (Smoke tests and benches do NOT import this module; they see one
device.)

For every supported cell this driver:
  1. builds the step function (train_step / prefill / serve_step),
  2. resolves in/out shardings from the logical axes (core.binding K_i rule),
  3. ``.lower().compile()`` on the requested mesh — success is the deliverable,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (XLA's own per-device estimate),
     and the trip-count-corrected HLO inventory (dot FLOPs, collective
     bytes by kind) from ``compiled.as_text()`` — the §Roofline inputs,
  5. writes one JSON per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES, supports_shape
from repro.launch import hlo_parse, shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill, make_serve_step, make_train_step
from repro.nn import module as module_lib, transformer
from repro.models import encdec
from repro.optim import adamw


def _cache_abstract_and_shardings(cfg, shape, mesh, rules):
    if getattr(cfg, "is_encoder_decoder", False):
        abstract = encdec.cache_specs(cfg, shape.global_batch, shape.seq_len)
        axes = encdec.cache_axes(cfg)
    else:
        abstract = transformer.cache_specs(cfg, shape.global_batch,
                                           shape.seq_len)
        axes = transformer.cache_axes(cfg)
    return abstract, sh.tree_shardings(abstract, axes, mesh, rules)


def build_cell(arch: str, shape_name: str, mesh, *,
               cfg=None, opt_overrides=None):
    """Returns (step_fn, abstract_args tuple, in_shardings, out_shardings)."""
    cfg = cfg or registry.get_config(arch)
    shape = registry.get_shape(shape_name)
    rules = sh.rules_for(cfg)

    # pin activation batch sharding (see ModelConfig.batch_mesh_axes);
    # for train, the per-microbatch batch is what must divide the axes
    eff_batch = shape.global_batch
    if shape.kind == "train":
        eff_batch //= max(1, getattr(cfg, "microbatches", 1))
    bspec = sh.prune_spec((eff_batch,),
                          rules.spec(("batch",), mesh), mesh)
    if bspec and bspec[0] is not None:
        entry = bspec[0]
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        cfg = cfg.replace(batch_mesh_axes=axes)
    if getattr(cfg, "seq_shard_train", False) and shape.kind == "train" \
            and shape.seq_len % mesh.shape.get("model", 1) == 0:
        cfg = cfg.replace(seq_mesh_axes=("model",))

    abstract_params, param_sh = sh.model_param_shardings(cfg, mesh)
    if getattr(cfg, "serve_dtype", "") and shape.kind in ("prefill",
                                                          "decode"):
        sd = jnp.dtype(cfg.serve_dtype)
        abstract_params = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, sd)
            if jnp.issubdtype(a.dtype, jnp.floating) else a,
            abstract_params)
    inputs = registry.input_specs(cfg, shape)
    in_axes = registry.input_axes(cfg, shape)
    input_sh = {k: sh.sharding_for(tuple(v.shape), in_axes[k], mesh, rules)
                for k, v in inputs.items()}

    if shape.kind == "train":
        n_micro = max(1, getattr(cfg, "microbatches", 1))
        # mesh-aware: the per-microbatch batch must stay divisible by the
        # data-parallel ways, else pruning drops the batch sharding and
        # every device sees the whole microbatch (measured on gemma2 multi)
        dp_ways = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
        while n_micro > 1 and (shape.global_batch // n_micro) % dp_ways:
            n_micro //= 2
        cfg = cfg.replace(microbatches=n_micro)
        micro_sh = None
        if n_micro > 1:
            micro_sh = {
                k: sh.sharding_for(
                    (n_micro, v.shape[0] // n_micro) + tuple(v.shape[1:]),
                    (None,) + tuple(in_axes[k]), mesh, rules)
                for k, v in inputs.items()}
        if getattr(cfg, "is_encoder_decoder", False):
            specs = encdec.model_specs(cfg)
        else:
            specs = transformer.model_specs(cfg)
        axes = module_lib.axes_tree(specs)
        opt_abs = adamw.abstract_state(abstract_params)
        opt_axes = adamw.state_axes(axes)
        opt_sh = sh.tree_shardings(opt_abs, opt_axes, mesh, rules)
        step = make_train_step(cfg, microbatch_shardings=micro_sh,
                               grad_shardings=opt_sh["mu"])
        args = (abstract_params, opt_abs, inputs)
        in_shardings = (param_sh, opt_sh, input_sh)
        out_abs = jax.eval_shape(step, *args)
        metrics_sh = jax.tree_util.tree_map(
            lambda _: sh.replicated(mesh), out_abs[2])
        out_shardings = (param_sh, opt_sh, metrics_sh)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill(cfg)
        args = (abstract_params, inputs)
        in_shardings = (param_sh, input_sh)
        vocab_sh = sh.sharding_for(
            (shape.global_batch, cfg.vocab_size), ("batch", "vocab"),
            mesh, rules)
        out_shardings = vocab_sh
        donate = ()
    else:  # decode
        step = make_serve_step(cfg)
        cache_abs, cache_sh = _cache_abstract_and_shardings(
            cfg, shape, mesh, rules)
        args = (abstract_params, cache_abs, inputs)
        in_shardings = (param_sh, cache_sh, input_sh)
        tok_sh = sh.sharding_for((shape.global_batch,), ("batch",), mesh,
                                 rules)
        out_shardings = (tok_sh, cache_sh)
        donate = (1,)
    return step, args, in_shardings, out_shardings, donate


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: pathlib.Path, *, cfg=None, tag: str = "") -> dict:
    shape = registry.get_shape(shape_name)
    base_cfg = cfg or registry.get_config(arch)
    ok, why = supports_shape(base_cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "supported": ok, "skip_reason": why, "tag": tag}
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.perf_counter()
    try:
        with jax.set_mesh(mesh):  # P-based constraints need a context
            step, args, in_sh, out_sh, donate = build_cell(
                arch, shape_name, mesh, cfg=cfg)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            text = compiled.as_text()
        hlo = hlo_parse.analyze(text)
        params_bytes = sh.bytes_per_device(args[0], in_sh[0])
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "params_bytes_per_device": params_bytes,
            "cost_analysis": {
                "flops_raw": float(cost.get("flops", 0.0)),
                "bytes_accessed_raw": float(cost.get("bytes accessed", 0.0)),
            },
            "hlo": {
                "dot_flops_per_device": hlo.dot_flops,
                "collective_bytes_per_device": hlo.collective_bytes,
                "collectives_by_kind": hlo.by_kind(),
                "n_collective_ops": len(hlo.collectives),
                "n_while": hlo.n_while,
                "trip_counts": hlo.trip_counts,
                "hlo_chars": len(text),
            },
        })
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec.update({"status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:]})
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    out_dir = pathlib.Path(args.out)

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") == "ok" or not prev.get("supported", True):
                        continue
                rec = run_cell(arch, shape_name, mesh_kind, out_dir)
                status = rec.get("status", "skipped")
                if not rec.get("supported", True):
                    n_skip += 1
                    print(f"[skip] {arch} x {shape_name} x {mesh_kind}: "
                          f"{rec['skip_reason']}", flush=True)
                elif status == "ok":
                    n_ok += 1
                    print(f"[ ok ] {arch} x {shape_name} x {mesh_kind}: "
                          f"compile {rec['compile_s']}s, "
                          f"dotTF/dev {rec['hlo']['dot_flops_per_device']/1e12:.3f}, "
                          f"collMB/dev {rec['hlo']['collective_bytes_per_device']/1e6:.1f}, "
                          f"temp {rec['memory']['temp_bytes']/1e9:.2f} GB",
                          flush=True)
                else:
                    n_err += 1
                    print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: "
                          f"{rec['error']}", flush=True)
    print(f"done: {n_ok} ok, {n_err} failed, {n_skip} skipped", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
