"""Step-function factories: the exact callables every dry-run cell lowers.

train_step  — fwd + bwd + AdamW update (train_4k cells)
prefill     — full-sequence forward, last-position logits (prefill_32k)
serve_step  — one cached decode step (decode_32k / long_500k)
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.optim import adamw, compress


def make_train_step(cfg: ModelConfig,
                    opt_cfg: Optional[adamw.AdamWConfig] = None,
                    grad_compression: bool = False,
                    microbatch_shardings: Optional[dict] = None,
                    grad_shardings: Optional[dict] = None) -> Callable:
    """``microbatch_shardings``: NamedShardings for the *split* batch
    (leading microbatch dim unsharded).  Without the constraint, GSPMD
    loses the batch sharding through the reshape and replicates per-device
    activations 16x (measured on the stablelm train_4k cell).

    ``grad_shardings``: shardings for the gradient accumulator — pass the
    ZeRO optimizer-state shardings so the fp32 gradient tree is stored
    (data x model)-sharded instead of model-sharded only (16x smaller;
    GSPMD materialises the implied per-microbatch reduce-scatter)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    is_encdec = getattr(cfg, "is_encoder_decoder", False)
    loss_fn = (encdec.train_loss if is_encdec else lm.train_loss)

    n_micro = max(1, getattr(cfg, "microbatches", 1))

    def grads_of(params: Any, batch: dict):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)

    def train_step(params: Any, opt_state: dict, batch: dict):
        if n_micro == 1:
            (_, metrics), grads = grads_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches, mean grads.
            # Keeps per-step activation memory at 1/n_micro while leaving
            # total collective bytes unchanged (payload/n x n rounds).
            micro = jax.tree_util.tree_map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)
            if microbatch_shardings is not None:
                micro = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, micro,
                    microbatch_shardings)

            def constrain(g):
                if grad_shardings is None:
                    return g
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, g, grad_shardings)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                g_acc = constrain(g_acc)
                m_acc = jax.tree_util.tree_map(lambda x, y: x + y, m_acc, m)
                return (g_acc, m_acc), None

            g0 = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            m0 = jax.eval_shape(lambda b: grads_of(params, b)[0][1],
                                jax.tree_util.tree_map(lambda a: a[0], micro))
            m0 = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / n_micro, metrics)
        if grad_compression:
            err = opt_state["err"]
            grads, new_err = compress.compress_with_feedback(grads, err)
        new_params, new_opt, om = adamw.apply_updates(
            opt_cfg, params, grads, {k: v for k, v in opt_state.items()
                                     if k != "err"})
        if grad_compression:
            new_opt["err"] = new_err
        metrics = {**metrics, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill(cfg: ModelConfig) -> Callable:
    is_encdec = getattr(cfg, "is_encoder_decoder", False)

    if is_encdec:
        def prefill_step(params: Any, batch: dict):
            enc = encdec.encode(cfg, params, batch["frames"])
            logits = encdec.decode_forward(cfg, params, batch["tokens"], enc,
                                           last_logit_only=True)
            return logits[:, -1, :]
        return prefill_step

    def prefill_step(params: Any, batch: dict):
        return lm.prefill(cfg, params, batch["tokens"],
                          patches=batch.get("patches"))
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    is_encdec = getattr(cfg, "is_encoder_decoder", False)
    step = encdec.serve_step if is_encdec else lm.serve_step

    def serve_step(params: Any, cache: dict, batch: dict):
        return step(cfg, params, batch["tokens"], cache, batch["pos"])
    return serve_step


def metrics_structure(train: bool = True) -> dict:
    out = {"loss": 0.0, "grad_norm": 0.0, "lr": 0.0}
    return out
