"""Sharding resolution: logical axes -> NamedShardings on a concrete mesh.

This is where the paper's K_i binding rule meets real shapes: a logical
binding is *pruned* when the tensor dimension doesn't divide the mesh-axis
extent (e.g. batch=1 in long_500k can't shard over 16 data rows; 60 experts
pad to 64 instead).  Pruning is per-tensor and deterministic, so dry-run,
checkpointing and the elastic resharder all agree.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.binding import BindingRules
from repro.configs.base import ModelConfig, ShapeConfig
from repro.nn import module as module_lib


def rules_for(cfg) -> BindingRules:
    overrides = dict(getattr(cfg, "rules_overrides", ()) or ())
    rules = BindingRules()
    if overrides:
        rules = rules.with_overrides(**overrides)
    return rules


def prune_spec(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't evenly divide the tensor dimension."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept = []
        extent = 1
        for a in axes:
            sz = mesh.shape[a]
            if dim % (extent * sz) == 0:
                kept.append(a)
                extent *= sz
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def sharding_for(shape: tuple[int, ...], axes: tuple, mesh: Mesh,
                 rules: BindingRules) -> NamedSharding:
    spec = rules.spec(axes, mesh)
    return NamedSharding(mesh, prune_spec(shape, spec, mesh))


def tree_shardings(abstract_tree: Any, axes_tree: Any, mesh: Mesh,
                   rules: BindingRules) -> Any:
    """Shardings for a pytree of ShapeDtypeStructs + matching axes tree."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)
    flat_a, treedef = jax.tree_util.tree_flatten(abstract_tree)
    flat_x = treedef.flatten_up_to(axes_tree)
    out = [sharding_for(tuple(a.shape), x, mesh, rules)
           for a, x in zip(flat_a, flat_x)]
    del is_axes
    return treedef.unflatten(out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def bytes_per_device(abstract_tree: Any, shardings: Any) -> int:
    """Largest per-device byte footprint of a sharded abstract tree."""
    flat_a = jax.tree_util.tree_leaves(abstract_tree)
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    total = 0
    for a, s in zip(flat_a, flat_s):
        shard_elems = int(np.prod(a.shape))
        spec = s.spec
        for dim, entry in zip(a.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            for ax in axes:
                shard_elems //= s.mesh.shape[ax]
        total += shard_elems * jax.numpy.dtype(a.dtype).itemsize
    return total


def model_param_shardings(cfg: ModelConfig, mesh: Mesh):
    """(abstract_params, shardings) for an LM config."""
    from repro.models import encdec
    from repro.nn import transformer
    rules = rules_for(cfg)
    if getattr(cfg, "is_encoder_decoder", False):
        specs = encdec.model_specs(cfg)
    else:
        specs = transformer.model_specs(cfg)
    abstract = module_lib.abstract_tree(specs)
    axes = module_lib.axes_tree(specs)
    return abstract, tree_shardings(abstract, axes, mesh, rules)
