"""Training launcher: end-to-end fault-tolerant training on the local mesh.

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --tiny \
        --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.steps import make_train_step
from repro.nn import module as module_lib, transformer
from repro.optim import adamw
from repro.runtime.fault import DriverConfig, FailureInjector, TrainingDriver


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (restart demo)")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = registry.get_tiny(args.arch) if args.tiny \
        else registry.get_config(args.arch)
    if getattr(cfg, "is_encoder_decoder", False):
        raise SystemExit("use examples/whisper_train.py for enc-dec")

    specs = transformer.model_specs(cfg)
    print(f"[train] arch={cfg.name} params={module_lib.param_count(specs):,}")
    params = module_lib.init_tree(specs, jax.random.key(0))
    opt_state = adamw.init_state(params)

    opt_cfg = adamw.AdamWConfig(total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, grad_compression=args.grad_compression),
        donate_argnums=(0, 1))
    if args.grad_compression:
        from repro.optim import compress
        opt_state["err"] = compress.init_error_state(params)

    pipe = SyntheticTokenPipeline(DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    driver = TrainingDriver(
        DriverConfig(total_steps=args.steps,
                     checkpoint_every=args.ckpt_every),
        train_step=step_fn, pipeline=pipe, ckpt=ckpt,
        injector=FailureInjector(tuple(args.fail_at)))

    t0 = time.monotonic()
    report = driver.run(params, opt_state)
    dt = time.monotonic() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train] done: {args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s), restarts={report.restarts}, "
          f"stragglers={len(report.straggler_steps)}")
    print(f"[train] loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
