"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
initialisation, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed in jax 0.5.x; older jax defaults every axis to Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (two pods, 512 chips).

    The "pod" axis is outermost: only data-parallel gradient reduction (or,
    opt-in, pipeline activations) crosses the slow inter-pod links — the
    paper's SLR-crossing discipline applied to pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CI (requires >= prod(shape) visible devices)."""
    return _mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return _mesh((1, 1), ("data", "model"))
