"""Launch layer: mesh construction, shardings, step factories, dry-run,
roofline analysis, training/serving drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS (512 host devices) at import —
only import it in dedicated dry-run processes.
"""

from repro.launch import mesh, shardings, steps  # noqa: F401
