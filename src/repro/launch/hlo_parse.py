"""Optimized-HLO text analysis: collective inventory and dot-FLOP counting
with while-loop trip-count correction.

XLA's ``cost_analysis()`` visits each ``while`` body exactly once, so any
cost inside a scanned layer stack or a blockwise-attention loop is
undercounted by its trip count.  scan lowers to a while whose *condition*
compares the induction variable against a compile-time constant, so the
trip count is recoverable from the condition computation's ``constant(N)``.
We build the computation call-graph, propagate multipliers through nested
whiles, and weight every collective (and every dot) by the product of
enclosing trip counts.

Cost model per collective (per-device bytes on the wire, ring algorithms,
(k-1)/k ~ 1):
    all-reduce        2 x operand bytes
    all-gather        1 x result bytes
    reduce-scatter    1 x operand bytes
    all-to-all        1 x operand bytes
    collective-permute 1 x operand bytes
Shapes in partitioned HLO are already per-device.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_COST = {"all-reduce": ("operand", 2.0), "all-gather": ("result", 1.0),
              "reduce-scatter": ("operand", 1.0),
              "all-to-all": ("operand", 1.0),
              "collective-permute": ("operand", 1.0)}


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    computation: str
    result_bytes: int
    operand_bytes: int
    multiplier: float
    replica_group_size: int

    @property
    def wire_bytes(self) -> float:
        which, factor = _COLL_COST[self.kind]
        base = self.operand_bytes if which == "operand" else self.result_bytes
        return factor * base * self.multiplier


@dataclasses.dataclass
class HloReport:
    collectives: list
    dot_flops: float              # per-device, trip-count corrected
    collective_bytes: float       # per-device wire bytes, corrected
    n_while: int
    trip_counts: dict

    def by_kind(self) -> dict:
        out: dict[str, float] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.wire_bytes
        return out


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = [line]        # keep header: parameter types
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", text, re.M)
    return m.group(1) if m else None


_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:fusion|call|custom-call)\(.*?(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(comps: dict, cond: str) -> float:
    """Largest integer constant in the condition computation (the bound)."""
    best = 1
    for line in comps.get(cond, []):
        for m in _CONST_RE.finditer(line):
            v = int(m.group(1))
            if v > best:
                best = v
    return float(best)


def analyze(text: str) -> HloReport:
    comps = _split_computations(text)
    entry = _entry_name(text)
    multipliers: dict[str, float] = {}
    trip_counts: dict[str, float] = {}
    n_while = 0

    # propagate multipliers from entry through calls and whiles (BFS)
    from collections import deque
    start = entry if entry in comps else (next(iter(comps)) if comps else None)
    if start is None:
        return HloReport([], 0.0, 0.0, 0, {})
    multipliers[start] = 1.0
    queue = deque([start])
    seen = set()
    while queue:
        name = queue.popleft()
        if name in seen:
            continue
        seen.add(name)
        mult = multipliers.get(name, 1.0)
        for line in comps.get(name, []):
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                n_while += 1
                tc = _trip_count(comps, cond)
                trip_counts[body] = tc
                for target, m in ((body, mult * tc), (cond, mult * tc)):
                    if m > multipliers.get(target, 0.0):
                        multipliers[target] = m
                        seen.discard(target)
                        queue.append(target)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                target = cm.group(1)
                if mult > multipliers.get(target, 0.0):
                    multipliers[target] = mult
                    seen.discard(target)
                    queue.append(target)
        # also catch reducers etc: to_apply=%name anywhere
        for line in comps.get(name, []):
            for m2 in re.finditer(r"to_apply=%?([\w.\-]+)", line):
                target = m2.group(1)
                if mult > multipliers.get(target, 0.0):
                    multipliers[target] = mult
                    seen.discard(target)
                    queue.append(target)

    collectives: list[CollectiveOp] = []
    dot_flops = 0.0
    for name, lines in comps.items():
        mult = multipliers.get(name, 1.0)
        # symbol table: %instr name -> result type (incl. computation params)
        types: dict[str, str] = {}
        for line in lines:
            dm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                          r"((?:\([^)]*\))|(?:\w+\[[\d,]*\]))", line)
            if dm:
                types[dm.group(1)] = dm.group(2)
            for pm in re.finditer(r"%?([\w.\-]+):\s*(\w+\[[\d,]*\])", line):
                types.setdefault(pm.group(1), pm.group(2))
        for line in lines:
            s = line.strip()
            # collectives ------------------------------------------------
            for kind in _COLLECTIVES:
                token = f" {kind}("
                if token in f" {s}" or s.startswith(f"{kind}("):
                    mm = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
                                  + kind.replace("-", r"\-") + r"\((.*)",
                                  s)
                    if not mm:
                        continue
                    res_t, rest = mm.groups()
                    res_b = sum(_shape_bytes(t) for t in
                                re.findall(r"\w+\[[\d,]*\]", res_t))
                    op_b = 0
                    depth = 1
                    args = ""
                    for ch in rest:
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                break
                        args += ch
                    op_b = sum(_shape_bytes(t) for t in
                               re.findall(r"\w+\[[\d,]*\]", args))
                    if op_b == 0:
                        op_b = res_b
                    gs = 0
                    gm = re.search(r"replica_groups=\{\{([\d,]+)\}", s)
                    if gm:
                        gs = len(gm.group(1).split(","))
                    else:
                        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", s)
                        if gm:
                            gs = int(gm.group(2))
                    collectives.append(CollectiveOp(
                        kind, name, res_b, op_b, mult, gs))
                    break
            # dots -------------------------------------------------------
            dm = re.match(
                r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\w+\[[\d,]*\])\S*\s*"
                r"dot\(\s*%?([\w.\-]+)", s)
            if dm:
                res_t, lhs_name = dm.groups()
                res_elems = 1
                m3 = _SHAPE_RE.match(res_t)
                if m3 and m3.group(2):
                    for d in m3.group(2).split(","):
                        if d:
                            res_elems *= int(d)
                lhs_t = types.get(lhs_name, "")
                m4 = _SHAPE_RE.match(lhs_t)
                lhs_dims = []
                if m4 and m4.group(2):
                    lhs_dims = [int(d) for d in m4.group(2).split(",") if d]
                cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
                contract = 1
                if cm2 and cm2.group(1) and lhs_dims:
                    for ci in cm2.group(1).split(","):
                        if ci:
                            contract *= lhs_dims[int(ci)]
                dot_flops += 2.0 * res_elems * contract * mult
    coll_bytes = sum(c.wire_bytes for c in collectives)
    return HloReport(collectives, dot_flops, coll_bytes, n_while,
                     trip_counts)
