import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (§Perf): run tagged config variants of the three
chosen cells through the dry-run, so every hypothesis -> change -> measure
cycle leaves a JSON artifact next to its baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell stablelm_train
    PYTHONPATH=src python -m repro.launch.hillclimb --cell all

The sweep loop itself (ordered tagged variants, skip-if-artifact-exists)
lives in ``repro.tune.strategies.sweep_variants``; the automated version of
the manual rounds below is ``repro.tune``'s ``HillClimb`` strategy.
"""

import argparse
import json
import pathlib

from repro.configs import registry


def _variants_stablelm_train():
    """Most collective-bound cell: stablelm-3b train_4k (TP activation
    all-reduces dominate)."""
    base = registry.get_config("stablelm-3b")
    return "stablelm-3b", "train_4k", [
        ("bf16reduce", base.replace(bf16_reduce=True)),
        ("dotsremat", base.replace(remat="dots")),
        ("sp", base.replace(seq_shard_train=True)),
        ("bf16reduce_dots", base.replace(bf16_reduce=True, remat="dots")),
        ("bf16reduce_sp", base.replace(bf16_reduce=True,
                                       seq_shard_train=True)),
        ("bf16reduce_sp_dots", base.replace(
            bf16_reduce=True, seq_shard_train=True, remat="dots")),
        # round 2: keep the dots win, pay for it with more microbatches
        ("dots_mb8", base.replace(remat="dots", microbatches=8)),
        ("dots_mb8_sp", base.replace(remat="dots", microbatches=8,
                                     seq_shard_train=True)),
    ]


def _variants_rg_long():
    """Paper-representative cell: recurrentgemma-9b long_500k — low-latency
    inference bound by weight streaming; the paper's reduced-precision
    insight is exactly the lever."""
    base = registry.get_config("recurrentgemma-9b")
    return "recurrentgemma-9b", "long_500k", [
        ("bf16serve", base.replace(serve_dtype="bfloat16")),
        ("bf16serve_q54", base.replace(serve_dtype="bfloat16",
                                       quant_format="5_4")),
    ]


def _variants_moe_train():
    """Worst useful-FLOPs ratio among train cells: qwen2-moe-a2.7b train_4k
    (dispatch + shared-expert overhead on top of a small active core)."""
    base = registry.get_config("qwen2-moe-a2.7b")
    return "qwen2-moe-a2.7b", "train_4k", [
        ("bf16reduce", base.replace(bf16_reduce=True)),
        ("cap10", base.replace(capacity_factor=1.0)),
        ("chunk8", base.replace(moe_token_chunks=8)),
        ("bf16reduce_cap10", base.replace(bf16_reduce=True,
                                          capacity_factor=1.0)),
        # round 2: dots remat on top of the capacity win
        ("cap10_dots", base.replace(capacity_factor=1.0, remat="dots")),
        ("cap10_mb8", base.replace(capacity_factor=1.0, microbatches=8)),
        # round 3: combine the two confirmed wins, paying dots' memory
        # with more microbatches
        ("cap10_dots_mb8", base.replace(capacity_factor=1.0, remat="dots",
                                        microbatches=8)),
    ]


CELLS = {
    "stablelm_train": _variants_stablelm_train,
    "rg_long": _variants_rg_long,
    "moe_train": _variants_moe_train,
}


def summarize(out_dir: pathlib.Path, arch: str, shape: str) -> None:
    from repro.launch import roofline as rl
    base_p = out_dir / f"{arch}__{shape}__single.json"
    rows = []
    for p in sorted(out_dir.glob(f"{arch}__{shape}__single*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            rows.append((d.get("tag") or "baseline", None, None, None))
            continue
        tag = d.get("tag") or "baseline"
        coll = d["hlo"]["collective_bytes_per_device"]
        dot = d["hlo"]["dot_flops_per_device"]
        mem = d["memory"]
        peak = (mem["argument_bytes"] + mem["temp_bytes"]
                + mem["output_bytes"] - mem["alias_bytes"]) / 1e9
        rows.append((tag, dot / rl.PEAK_FLOPS, coll / rl.LINK_BW, peak))
    print(f"\n== {arch} x {shape} ==")
    print(f"{'variant':24s} {'compute_s':>10s} {'coll_s':>10s} {'peakGB':>8s}")
    for tag, c, l, p in rows:
        if c is None:
            print(f"{tag:24s}  FAILED")
        else:
            print(f"{tag:24s} {c:10.4f} {l:10.4f} {p:8.2f}")
    del base_p


def main() -> None:
    from repro.launch.dryrun import run_cell
    from repro.tune.strategies import sweep_variants
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=list(CELLS) + ["all"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--summarize-only", action="store_true")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for name in names:
        arch, shape, variants = CELLS[name]()
        if not args.summarize_only:
            def already_ok(tag, cfg):
                path = out_dir / f"{arch}__{shape}__single__{tag}.json"
                return path.exists() and \
                    json.loads(path.read_text()).get("status") == "ok"

            def run_one(tag, cfg):
                rec = run_cell(arch, shape, "single", out_dir, cfg=cfg,
                               tag=tag)
                print(f"[{rec.get('status')}] {arch} x {shape} [{tag}]",
                      flush=True)
                return rec

            sweep_variants(variants, run_one, skip=already_ok)
        summarize(out_dir, arch, shape)


if __name__ == "__main__":
    main()
