"""Model substrate: layers, attention, MoE, recurrent blocks, assembly."""

from repro.nn import attention, layers, module, moe, rglru, rope, transformer, xlstm

__all__ = ["attention", "layers", "module", "moe", "rglru", "rope",
           "transformer", "xlstm"]
