"""Declarative nn module graphs — the single-source model description.

A ``ModuleGraph`` is an ordered list of layer nodes (the BraggNN vocabulary:
conv2d, linear, batch-norm, relu, max-pool, softmax, the non-local attention
block — plus the sequence-model vocabulary: rms-norm, multi-head attention,
position-wise MLP) and the model's input memref shape.  One description
serves every consumer:

  * ``repro.hls.bridge`` walks it and emits the corresponding
    ``repro.core.frontend`` loop nests — the nn -> loop-nest auto-lowering
    that feeds ``repro.hls.compile``;
  * ``specs()`` yields the ``ParamSpec`` tree for training
    (``repro.nn.module.init_tree``);
  * ``weight_feeds()`` binds a trained param tree to the loop-nest memref
    names, so the compiled design runs with the trained weights.

Nodes are pure data (frozen dataclasses): no interp/compiler imports here —
emission lives in the bridge, keeping this importable from training code.

Naming: ``name`` keys the node's subtree in the param tree; ``prefix``
(default: ``name``) prefixes its weight memrefs (``{prefix}.weight`` ...);
``out_name``/``label`` name the node's result memref and loop-nest label.
``repro.models.braggnn.build`` pins these to the hand-written
``frontend.braggnn`` names, which is what makes the bridged DFG
bit-identical (fingerprint-equal) to the hand-written one.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.nn.module import ParamSpec


def _valid_out(n: int, k: int, stride: int, padding: int) -> int:
    return (n + 2 * padding - k) // stride + 1


@dataclasses.dataclass(frozen=True)
class Node:
    """Base layer node: naming common to the whole vocabulary."""

    name: str = ""

    @property
    def prefix(self) -> str:
        return self.name

    @property
    def label(self) -> str:
        return self.name

    @property
    def out_name(self) -> str:
        return f"{self.name}_out"

    def param_specs(self) -> Optional[dict]:
        """ParamSpec subtree for this node (``None`` = parameter-free)."""
        return None

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        """memref name -> path of the param leaf inside ``param_specs()``."""
        return {}

    def out_shape(self, in_shape: tuple[int, ...]) -> tuple[int, ...]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Conv2d(Node):
    """Valid/zero-padded 2D convolution (``frontend.conv2d``)."""

    in_channels: int = 0
    out_channels: int = 0
    kernel: int = 3
    stride: int = 1
    padding: int = 0
    bias: bool = True
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    def param_specs(self) -> dict:
        d = {"w": ParamSpec((self.out_channels, self.in_channels,
                             self.kernel, self.kernel), (None,) * 4)}
        if self.bias:
            d["b"] = ParamSpec((self.out_channels,), (None,), init="zeros")
        return d

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        d = {f"{self.prefix}.weight": ("w",)}
        if self.bias:
            d[f"{self.prefix}.bias"] = ("b",)
        return d

    def out_shape(self, in_shape):
        b, c, h, w = in_shape
        assert c == self.in_channels, (in_shape, self)
        return (b, self.out_channels,
                _valid_out(h, self.kernel, self.stride, self.padding),
                _valid_out(w, self.kernel, self.stride, self.padding))


@dataclasses.dataclass(frozen=True)
class Linear(Node):
    """Dense layer ``x @ W.T + b`` (``frontend.linear``)."""

    in_features: int = 0
    out_features: int = 0
    bias: bool = True
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    def param_specs(self) -> dict:
        d = {"w": ParamSpec((self.out_features, self.in_features),
                            (None, None))}
        if self.bias:
            d["b"] = ParamSpec((self.out_features,), (None,), init="zeros")
        return d

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        d = {f"{self.prefix}.weight": ("w",)}
        if self.bias:
            d[f"{self.prefix}.bias"] = ("b",)
        return d

    def out_shape(self, in_shape):
        b, k = in_shape
        assert k == self.in_features, (in_shape, self)
        return (b, self.out_features)


@dataclasses.dataclass(frozen=True)
class BatchNorm2d(Node):
    """Inference-mode batch norm (``frontend.batch_norm_2d``)."""

    channels: int = 0
    eps: float = 1e-5
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    def param_specs(self) -> dict:
        c = (self.channels,)
        return {"gamma": ParamSpec(c, (None,), init="ones"),
                "beta": ParamSpec(c, (None,), init="zeros"),
                "mean": ParamSpec(c, (None,), init="zeros"),
                "var": ParamSpec(c, (None,), init="ones")}

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        return {f"{self.prefix}.{leaf}": (leaf,)
                for leaf in ("gamma", "beta", "mean", "var")}

    def out_shape(self, in_shape):
        assert in_shape[1] == self.channels, (in_shape, self)
        return in_shape


@dataclasses.dataclass(frozen=True)
class ReLU(Node):
    """Elementwise ReLU (``frontend.relu_layer``)."""

    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def label(self) -> str:
        return self.label_ or self.name or "relu"

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name or 'relu'}_out"

    def out_shape(self, in_shape):
        return in_shape


@dataclasses.dataclass(frozen=True)
class OutputReLU(Node):
    """In-place ReLU on the *output* memref written by the previous node.

    The bridged form of ``frontend.braggnn``'s final ReLU, which rewrites
    the output symbol table under per-element sequential nests instead of
    allocating a new memref.  Must be the last node of a ``ModuleGraph``.
    """

    label_: Optional[str] = None

    @property
    def label(self) -> str:
        return self.label_ or self.name or "final_relu"

    def out_shape(self, in_shape):
        return in_shape


@dataclasses.dataclass(frozen=True)
class MaxPool2d(Node):
    """k x k max pooling (``frontend.max_pool_2d``)."""

    kernel: int = 2
    stride: int = 2
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def label(self) -> str:
        return self.label_ or self.name or "max_pool"

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name or 'max_pool'}_out"

    def out_shape(self, in_shape):
        b, c, h, w = in_shape
        # floor mode; frontend.max_pool_2d bounds-checks its taps, so any
        # smaller output window is also legal — this is the torch default
        ho = _valid_out(h, self.kernel, self.stride, 0)
        wo = _valid_out(w, self.kernel, self.stride, 0)
        return (b, c, ho, wo)


@dataclasses.dataclass(frozen=True)
class Softmax(Node):
    """Softmax over the last axis (``frontend.soft_max``)."""

    taylor_order: int = 8
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def label(self) -> str:
        return self.label_ or self.name or "soft_max"

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name or 'soft_max'}_out"

    def out_shape(self, in_shape):
        return in_shape


@dataclasses.dataclass(frozen=True)
class NonLocalBlock(Node):
    """BraggNN's attention block (``frontend.non_local_block``).

    theta/phi/g 1x1 convs to ``mid_channels``, softmax attention over the
    spatial positions, out-projection back to ``channels``, residual add.
    """

    channels: int = 0
    mid_channels: int = 0
    taylor_order: int = 8
    prefix_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    def param_specs(self) -> dict:
        c1, c2 = self.channels, self.mid_channels
        return {
            "theta": {"w": ParamSpec((c2, c1, 1, 1), (None,) * 4)},
            "phi": {"w": ParamSpec((c2, c1, 1, 1), (None,) * 4)},
            "g": {"w": ParamSpec((c2, c1, 1, 1), (None,) * 4)},
            "out": {"w": ParamSpec((c1, c2, 1, 1), (None,) * 4)},
        }

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        return {
            f"{self.prefix}.theta.weight": ("theta", "w"),
            f"{self.prefix}.phi.weight": ("phi", "w"),
            f"{self.prefix}.g.weight": ("g", "w"),
            f"{self.prefix}.out_cnn.weight": ("out", "w"),
        }

    def out_shape(self, in_shape):
        b, c, h, w = in_shape
        assert c == self.channels and h == w, (in_shape, self)
        return in_shape


@dataclasses.dataclass(frozen=True)
class Flatten(Node):
    """Zero-cost reshape to (batch, -1) (``frontend.copy_reshape``)."""

    out_name_: Optional[str] = None

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name or 'flatten'}_out"

    def out_shape(self, in_shape):
        n = 1
        for d in in_shape[1:]:
            n *= d
        return (in_shape[0], n)


@dataclasses.dataclass(frozen=True)
class RMSNorm(Node):
    """RMS normalisation over the last axis (``frontend.rms_norm``)."""

    dim: int = 0
    eps: float = 1e-5
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    def param_specs(self) -> dict:
        return {"gamma": ParamSpec((self.dim,), (None,), init="ones")}

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        return {f"{self.prefix}.gamma": ("gamma",)}

    def out_shape(self, in_shape):
        l, d = in_shape
        assert d == self.dim, (in_shape, self)
        return in_shape


@dataclasses.dataclass(frozen=True)
class Attention(Node):
    """Pre-norm residual multi-head self-attention (``frontend.attention``).

    Operates on (L, d_model) sequence memrefs.  With ``pre_norm`` the node
    applies an RMS norm before the attention body; with ``residual`` the
    input is added back after the out-projection — so the default node is
    the whole ``x + Attn(RMS(x))`` sub-block and a sequential node chain
    stays linear.  Weights follow the ``repro.nn.attention.attn_specs``
    layout (q/k/v kernels (D, H, dh), o kernel (H, dh, D)).
    """

    d_model: int = 0
    n_heads: int = 0
    taylor_order: int = 8
    eps: float = 1e-5
    pre_norm: bool = True
    residual: bool = True
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_specs(self) -> dict:
        from repro.nn.attention import attn_specs
        s = attn_specs(self.d_model, self.n_heads, self.n_heads,
                       self.head_dim)
        if self.pre_norm:
            s["norm"] = {"gamma": ParamSpec((self.d_model,), (None,),
                                            init="ones")}
        return s

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        d = {f"{self.prefix}.{nm}.kernel": (nm, "kernel")
             for nm in ("q", "k", "v", "o")}
        if self.pre_norm:
            d[f"{self.prefix}.norm.gamma"] = ("norm", "gamma")
        return d

    def out_shape(self, in_shape):
        l, d = in_shape
        assert d == self.d_model, (in_shape, self)
        assert self.d_model % self.n_heads == 0, self
        return in_shape


@dataclasses.dataclass(frozen=True)
class MLP(Node):
    """Pre-norm residual position-wise feed-forward (``frontend.mlp``).

    relu(x @ w1.T + b1) @ w2.T + b2 on (L, d_model) sequence memrefs, with
    the same pre-norm/residual sub-block structure as :class:`Attention`.
    """

    d_model: int = 0
    hidden: int = 0
    eps: float = 1e-5
    pre_norm: bool = True
    residual: bool = True
    prefix_: Optional[str] = None
    label_: Optional[str] = None
    out_name_: Optional[str] = None

    @property
    def prefix(self) -> str:
        return self.prefix_ or self.name

    @property
    def label(self) -> str:
        return self.label_ or self.name

    @property
    def out_name(self) -> str:
        return self.out_name_ or f"{self.name}_out"

    def param_specs(self) -> dict:
        s = {
            "fc1": {"w": ParamSpec((self.hidden, self.d_model),
                                   (None, None)),
                    "b": ParamSpec((self.hidden,), (None,), init="zeros")},
            "fc2": {"w": ParamSpec((self.d_model, self.hidden),
                                   (None, None)),
                    "b": ParamSpec((self.d_model,), (None,), init="zeros")},
        }
        if self.pre_norm:
            s["norm"] = {"gamma": ParamSpec((self.d_model,), (None,),
                                            init="ones")}
        return s

    def weight_memrefs(self) -> dict[str, tuple[str, ...]]:
        d = {
            f"{self.prefix}.fc1.weight": ("fc1", "w"),
            f"{self.prefix}.fc1.bias": ("fc1", "b"),
            f"{self.prefix}.fc2.weight": ("fc2", "w"),
            f"{self.prefix}.fc2.bias": ("fc2", "b"),
        }
        if self.pre_norm:
            d[f"{self.prefix}.norm.gamma"] = ("norm", "gamma")
        return d

    def out_shape(self, in_shape):
        l, d = in_shape
        assert d == self.d_model, (in_shape, self)
        return in_shape


#: The supported layer vocabulary, in one place for error messages.
NODE_TYPES = (Conv2d, Linear, BatchNorm2d, ReLU, OutputReLU, MaxPool2d,
              Softmax, NonLocalBlock, Flatten, RMSNorm, Attention, MLP)


class ModuleGraph:
    """An ordered nn module graph plus its interface metadata.

    ``input_shape`` is the *memref* shape of one sample (e.g.
    ``(1, 1, img, img)`` for BraggNN — the leading singleton is the
    per-sample batch axis of the loop-nest program).  ``params`` optionally
    binds a trained param tree (structure of :meth:`specs`); bound modules
    compile to designs that :meth:`~repro.hls.Design.run` with the trained
    weights without the caller passing weight feeds.  ``forward_fn`` is the
    optional fused tensor-level twin ``(params, x, fmt=None) -> y`` used by
    ``Design.serve``'s tensor backend.
    """

    def __init__(self, name: str, input_shape: Sequence[int],
                 nodes: Sequence[Node], *, input_name: str = "input",
                 params: Any = None,
                 forward_fn: Optional[Callable] = None,
                 meta: Optional[dict] = None):
        if not nodes:
            raise ValueError("ModuleGraph needs at least one node")
        for n in nodes:
            if not isinstance(n, NODE_TYPES):
                raise TypeError(
                    f"unsupported node {type(n).__name__}; vocabulary: "
                    f"{[t.__name__ for t in NODE_TYPES]}")
        if any(isinstance(n, OutputReLU) for n in nodes[:-1]):
            raise ValueError("OutputReLU must be the last node")
        self.name = name
        self.input_shape = tuple(input_shape)
        self.input_name = input_name
        self.nodes = tuple(nodes)
        self.params = params
        self.forward_fn = forward_fn
        self.meta = dict(meta or {})

    # -- shapes & parameters -------------------------------------------------

    def shapes(self) -> list[tuple[int, ...]]:
        """Per-node output shapes (index-aligned with ``nodes``)."""
        out, cur = [], self.input_shape
        for n in self.nodes:
            cur = n.out_shape(cur)
            out.append(cur)
        return out

    @property
    def output_shape(self) -> tuple[int, ...]:
        return self.shapes()[-1]

    def specs(self) -> dict:
        """The ``ParamSpec`` tree: ``{node.name: node subtree}``."""
        d = {}
        for n in self.nodes:
            sub = n.param_specs()
            if sub is None:
                continue
            if not n.name:
                raise ValueError(f"parameterised node {n} needs a name")
            if n.name in d:
                raise ValueError(f"duplicate node name {n.name!r}")
            d[n.name] = sub
        return d

    def init_params(self, key) -> Any:
        from repro.nn.module import init_tree
        return init_tree(self.specs(), key)

    def bind(self, params) -> "ModuleGraph":
        """A copy of this module with ``params`` bound as the weights."""
        return ModuleGraph(self.name, self.input_shape, self.nodes,
                           input_name=self.input_name, params=params,
                           forward_fn=self.forward_fn, meta=self.meta)

    # -- feeds ---------------------------------------------------------------

    def weight_feeds(self, params: Any = None) -> dict[str, np.ndarray]:
        """memref-name feed dict for the bound (or given) param tree.

        Feeds are unbatched — ``emit.evaluate`` / ``to_jax_fn`` broadcast
        weight feeds across the batch axis.
        """
        params = self.params if params is None else params
        if params is None:
            return {}
        feeds: dict[str, np.ndarray] = {}
        for n in self.nodes:
            if n.param_specs() is None:
                continue
            sub = params[n.name]
            for memref, path in n.weight_memrefs().items():
                leaf = sub
                for k in path:
                    leaf = leaf[k]
                feeds[memref] = np.asarray(leaf, dtype=np.float32)
        return feeds

    def describe(self) -> str:
        lines = [f"module {self.name!r}: input {self.input_shape}"]
        for n, shp in zip(self.nodes, self.shapes()):
            lines.append(f"  {type(n).__name__:14s} {n.name or n.label:20s} "
                         f"-> {shp}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"ModuleGraph({self.name!r}, {len(self.nodes)} nodes, "
                f"params={'bound' if self.params is not None else 'unbound'})")
