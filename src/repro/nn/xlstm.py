"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential) — arXiv:2405.04517.

mLSTM is a linear-attention-style cell with exponential gating and a
max-stabiliser.  Training/prefill uses the *chunkwise* form: quadratic
within a chunk, recurrent (C, n, m) state across chunks via ``lax.scan`` —
memory O(S x chunk) and exact w.r.t. the recurrent semantics.  Decode is a
single fused state update.  This is the TPU-native rendering of the paper's
static-scheduling insight for recurrences: the chunk grid is the schedule.

sLSTM has genuine state-dependent gating (recurrent R matrices, shared
max-stabiliser) and cannot be parallelised over time; it lowers to
``lax.scan`` over steps (compile time is length-independent).

Block structure follows the official xLSTM backbone: mLSTM block with
projection factor 2 and causal conv4; sLSTM block with a gated FFN of
factor 4/3.  The assigned xlstm-1.3b config has d_ff = 0: all FFN compute
lives inside these blocks.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import maybe_quantize, rmsnorm
from repro.nn.module import ParamSpec

ACCUM = jnp.float32


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_block_specs(d: int, n_heads: int, *, proj_factor: int = 2,
                      conv_width: int = 4) -> dict:
    d_in = proj_factor * d
    dh = d_in // n_heads
    return {
        "up_main": {"kernel": ParamSpec((d, d_in), ("embed", "mlp"))},
        "up_gate": {"kernel": ParamSpec((d, d_in), ("embed", "mlp"))},
        "conv": {"kernel": ParamSpec((conv_width, d_in), (None, "mlp")),
                 "bias": ParamSpec((d_in,), ("mlp",), init="zeros")},
        "q": {"kernel": ParamSpec((d_in, n_heads, dh),
                                  ("mlp", "heads", "head_dim"))},
        "k": {"kernel": ParamSpec((d_in, n_heads, dh),
                                  ("mlp", "heads", "head_dim"))},
        "v": {"kernel": ParamSpec((d_in, n_heads, dh),
                                  ("mlp", "heads", "head_dim"))},
        "igate": {"kernel": ParamSpec((d_in, n_heads), ("mlp", "heads"),
                                      scale=0.02),
                  "bias": ParamSpec((n_heads,), ("heads",), init="zeros")},
        "fgate": {"kernel": ParamSpec((d_in, n_heads), ("mlp", "heads"),
                                      scale=0.02),
                  "bias": ParamSpec((n_heads,), ("heads",), init="ones")},
        "head_norm": {"scale": ParamSpec((n_heads, dh),
                                         ("heads", "head_dim"),
                                         init="ones")},
        "down": {"kernel": ParamSpec((d_in, d), ("mlp", "embed"))},
    }


def _conv4(p: dict, x: jax.Array, state: Optional[jax.Array]
           ) -> tuple[jax.Array, jax.Array]:
    cw = p["kernel"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    ctx = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x, dtype=ACCUM)
    for j in range(cw):
        y = y + ctx[:, j:j + x.shape[1], :].astype(ACCUM) * \
            p["kernel"][cw - 1 - j].astype(ACCUM)
    y = y + p["bias"].astype(ACCUM)
    return y.astype(x.dtype), ctx[:, -(cw - 1):, :]


def _mlstm_chunk(q, k, v, log_f, log_i, state):
    """One chunk of the stabilised chunkwise mLSTM.

    q,k,v: (B, L, H, D); log_f, log_i: (B, L, H)
    state: (C (B,H,D,D), n (B,H,D), m (B,H)) — all fp32.
    Returns (h (B,L,H,D), new_state).
    """
    C_prev, n_prev, m_prev = state
    b, l, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, ACCUM))
    F = jnp.cumsum(log_f, axis=1)                       # inclusive (B,L,H)
    # intra-chunk log decay matrix:  D[t,s] = F_t - F_s + log_i_s  (s <= t)
    Dmat = (F[:, :, None, :] - F[:, None, :, :]
            + log_i[:, None, :, :])                     # (B,T,S,H)
    causal = jnp.tril(jnp.ones((l, l), bool))
    Dmat = jnp.where(causal[None, :, :, None], Dmat, -jnp.inf)
    # stabiliser per (b, t, h): max over intra decays and inter decay
    b_inter = F + m_prev[:, None, :]                    # (B,L,H)
    m_intra = jnp.max(Dmat, axis=2)                     # (B,T,H)
    m_t = jnp.maximum(m_intra, b_inter)
    m_t = jnp.maximum(m_t, -1e30)
    w_intra = jnp.exp(Dmat - m_t[:, :, None, :])        # (B,T,S,H)
    w_inter = jnp.exp(b_inter - m_t)                    # (B,T,H)

    scores = jnp.einsum("bthd,bshd->btsh", q.astype(ACCUM),
                        k.astype(ACCUM)) * scale * w_intra
    num = jnp.einsum("btsh,bshd->bthd", scores, v.astype(ACCUM))
    num = num + w_inter[..., None] * jnp.einsum(
        "bthd,bhde->bthe", q.astype(ACCUM) * scale, C_prev)
    den_vec = jnp.einsum("btsh,bshd->bthd", w_intra, k.astype(ACCUM))
    den = jnp.einsum("bthd,bthd->bth", q.astype(ACCUM) * scale, den_vec)
    den = den + w_inter * jnp.einsum("bthd,bhd->bth",
                                     q.astype(ACCUM) * scale, n_prev)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_out = num / den[..., None]

    # end-of-chunk state update
    F_L = F[:, -1, :]                                   # (B,H)
    m_new = jnp.maximum(F_L + m_prev, jnp.max(
        F_L[:, None, :] - F + log_i, axis=1))
    decay_state = jnp.exp(F_L + m_prev - m_new)         # (B,H)
    w_kv = jnp.exp(F_L[:, None, :] - F + log_i - m_new[:, None, :])
    C_new = (decay_state[..., None, None] * C_prev
             + jnp.einsum("bsh,bshd,bshe->bhde", w_kv, k.astype(ACCUM),
                          v.astype(ACCUM)))
    n_new = (decay_state[..., None] * n_prev
             + jnp.einsum("bsh,bshd->bhd", w_kv, k.astype(ACCUM)))
    return h_out, (C_new, n_new, m_new)


def mlstm_cell(q, k, v, log_f, log_i, *, chunk: int = 256,
               state: Optional[tuple] = None):
    """Chunkwise mLSTM over a full sequence.  Shapes as in _mlstm_chunk."""
    b, s, h, d = q.shape
    if state is None:
        state = (jnp.zeros((b, h, d, d), ACCUM),
                 jnp.zeros((b, h, d), ACCUM),
                 jnp.full((b, h), -1e30, ACCUM))
    if s <= chunk:
        return _mlstm_chunk(q, k, v, log_f, log_i, state)
    if s % chunk:
        # pad to a chunk multiple; padded steps carry zero input gates
        # (log_i = -inf) so they contribute nothing, and their outputs are
        # sliced off below (causality protects the real positions).
        pad = chunk - s % chunk
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zpad) for a in (q, k, v))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        h_out, st = mlstm_cell(q, k, v, log_f, log_i, chunk=chunk,
                               state=state)
        return h_out[:, :s], st
    nc = s // chunk

    def resh(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(resh, (q, k, v, log_f, log_i)))

    def step(carry, xt):
        qt, kt, vt, ft, it = xt
        h_out, new = _mlstm_chunk(qt, kt, vt, ft, it, carry)
        return new, h_out

    state, hs = jax.lax.scan(step, state, xs)
    h_out = hs.swapaxes(0, 1).reshape(b, s, h, d)
    return h_out, state


def mlstm_block(p: dict, x: jax.Array, *, n_heads: int, chunk: int = 256,
                cache: Optional[dict] = None, quant: Optional[str] = None
                ) -> tuple[jax.Array, Optional[dict]]:
    """Full mLSTM block.  cache (decode): {C, n, m, conv}."""
    dt = x.dtype
    w_main = maybe_quantize(p["up_main"]["kernel"], quant).astype(dt)
    w_gate = maybe_quantize(p["up_gate"]["kernel"], quant).astype(dt)
    main = jnp.einsum("bsd,dk->bsk", x, w_main,
                      preferred_element_type=ACCUM).astype(dt)
    gate = jnp.einsum("bsd,dk->bsk", x, w_gate,
                      preferred_element_type=ACCUM)
    conv_state = cache.get("conv") if cache else None
    conv_out, new_conv = _conv4(p["conv"], main, conv_state)
    conv_act = jax.nn.silu(conv_out.astype(ACCUM)).astype(dt)

    def proj(name, src):
        w = maybe_quantize(p[name]["kernel"], quant).astype(dt)
        return jnp.einsum("bsk,khd->bshd", src, w,
                          preferred_element_type=ACCUM).astype(dt)

    q = proj("q", conv_act)
    k = proj("k", conv_act)
    v = proj("v", main)
    log_i = (jnp.einsum("bsk,kh->bsh", conv_act.astype(ACCUM),
                        p["igate"]["kernel"].astype(ACCUM))
             + p["igate"]["bias"].astype(ACCUM))
    f_pre = (jnp.einsum("bsk,kh->bsh", conv_act.astype(ACCUM),
                        p["fgate"]["kernel"].astype(ACCUM))
             + p["fgate"]["bias"].astype(ACCUM))
    log_f = jax.nn.log_sigmoid(f_pre)

    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
        h, new_state = _mlstm_chunk(q, k, v, log_f, log_i, state)
        new_cache = {"C": new_state[0], "n": new_state[1],
                     "m": new_state[2], "conv": new_conv}
    else:
        h, _ = mlstm_cell(q, k, v, log_f, log_i, chunk=chunk)
        new_cache = None

    # per-head norm, flatten, gate, project down
    h = rmsnorm({"scale": p["head_norm"]["scale"].reshape(-1)},
                h.reshape(*h.shape[:2], -1))
    h = h * jax.nn.silu(gate).astype(dt)
    w_down = maybe_quantize(p["down"]["kernel"], quant).astype(dt)
    out = jnp.einsum("bsk,kd->bsd", h, w_down,
                     preferred_element_type=ACCUM).astype(dt)
    return out, new_cache


def mlstm_cache_specs(batch: int, d: int, n_heads: int, *,
                      proj_factor: int = 2, conv_width: int = 4) -> dict:
    d_in = proj_factor * d
    dh = d_in // n_heads
    return {
        "C": jax.ShapeDtypeStruct((batch, n_heads, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, n_heads, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, n_heads), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, conv_width - 1, d_in),
                                     jnp.bfloat16),
    }


def init_mlstm_cache(batch: int, d: int, n_heads: int, *,
                     proj_factor: int = 2, conv_width: int = 4) -> dict:
    d_in = proj_factor * d
    dh = d_in // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_in), jnp.bfloat16),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block_specs(d: int, n_heads: int, *, conv_width: int = 4,
                      ffn_factor: float = 4.0 / 3.0) -> dict:
    w = d // n_heads
    ffn = int(d * ffn_factor)
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[g] = {
            "kernel": ParamSpec((d, n_heads, w),
                                ("embed", "heads", "head_dim"), scale=0.02),
            "rec": ParamSpec((n_heads, w, w), ("heads", "head_dim", None),
                             scale=0.02),
            "bias": ParamSpec((n_heads, w), ("heads", "head_dim"),
                              init="zeros"),
        }
    return {
        "conv": {"kernel": ParamSpec((conv_width, d), (None, "embed")),
                 "bias": ParamSpec((d,), ("embed",), init="zeros")},
        "gates": gates,
        "head_norm": {"scale": ParamSpec((n_heads, w),
                                         ("heads", "head_dim"),
                                         init="ones")},
        "ffn_up": {"kernel": ParamSpec((d, 2 * ffn), ("embed", "mlp"))},
        "ffn_down": {"kernel": ParamSpec((ffn, d), ("mlp", "embed"))},
    }


def _slstm_scan(p: dict, x_pre: dict, h0, c0, n0, m0):
    """Sequential sLSTM over time.  x_pre[g]: (B, S, H, W) preactivations."""
    def step(carry, xt):
        h, c, n, m = carry                       # (B,H,W) each, fp32
        pg = {}
        for g in ("i", "f", "z", "o"):
            rec = jnp.einsum("bhw,hwv->bhv", h, p["gates"][g]["rec"]
                             .astype(ACCUM))
            pg[g] = xt[g] + rec
        log_f = jax.nn.log_sigmoid(pg["f"])
        m_new = jnp.maximum(log_f + m, pg["i"])
        i_p = jnp.exp(pg["i"] - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(pg["z"])
        o = jax.nn.sigmoid(pg["o"])
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    xs = {g: x_pre[g].swapaxes(0, 1) for g in x_pre}   # (S,B,H,W)
    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    return hs.swapaxes(0, 1), (h, c, n, m)


def slstm_block(p: dict, x: jax.Array, *, n_heads: int,
                cache: Optional[dict] = None, quant: Optional[str] = None
                ) -> tuple[jax.Array, Optional[dict]]:
    """sLSTM block with causal conv and gated FFN.

    cache (decode): {h, c, n, m, conv} — all (B, H, W) fp32 but conv.
    """
    dt = x.dtype
    b, s, d = x.shape
    w = d // n_heads
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = _conv4(p["conv"], x, conv_state)
    xc = jax.nn.silu(xc.astype(ACCUM))

    x_pre = {}
    for g in ("i", "f", "z", "o"):
        src = xc if g in ("i", "f") else x.astype(ACCUM)
        x_pre[g] = (jnp.einsum("bsd,dhw->bshw", src,
                               p["gates"][g]["kernel"].astype(ACCUM))
                    + p["gates"][g]["bias"].astype(ACCUM))

    if cache is not None:
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
        hs, (h, c, n, m) = _slstm_scan(
            p, {g: x_pre[g] for g in x_pre}, *carry)
        new_cache = {"h": h, "c": c, "n": n, "m": m, "conv": new_conv}
    else:
        zeros = jnp.zeros((b, n_heads, w), ACCUM)
        m0 = jnp.full((b, n_heads, w), -1e30, ACCUM)
        hs, _ = _slstm_scan(p, x_pre, zeros, zeros, zeros, m0)
        new_cache = None

    y = rmsnorm({"scale": p["head_norm"]["scale"].reshape(-1)},
                hs.reshape(b, s, d).astype(dt))
    # gated FFN (factor 4/3)
    w_up = maybe_quantize(p["ffn_up"]["kernel"], quant).astype(dt)
    u = jnp.einsum("bsd,dk->bsk", y, w_up, preferred_element_type=ACCUM)
    u1, u2 = jnp.split(u, 2, axis=-1)
    u = (jax.nn.gelu(u1, approximate=True) * u2).astype(dt)
    w_dn = maybe_quantize(p["ffn_down"]["kernel"], quant).astype(dt)
    out = jnp.einsum("bsk,kd->bsd", u, w_dn,
                     preferred_element_type=ACCUM).astype(dt)
    return out, new_cache


def slstm_cache_specs(batch: int, d: int, n_heads: int, *,
                      conv_width: int = 4) -> dict:
    w = d // n_heads
    f32 = jnp.float32
    return {
        "h": jax.ShapeDtypeStruct((batch, n_heads, w), f32),
        "c": jax.ShapeDtypeStruct((batch, n_heads, w), f32),
        "n": jax.ShapeDtypeStruct((batch, n_heads, w), f32),
        "m": jax.ShapeDtypeStruct((batch, n_heads, w), f32),
        "conv": jax.ShapeDtypeStruct((batch, conv_width - 1, d),
                                     jnp.bfloat16),
    }


def init_slstm_cache(batch: int, d: int, n_heads: int, *,
                     conv_width: int = 4) -> dict:
    w = d // n_heads
    z = jnp.zeros((batch, n_heads, w), jnp.float32)
    return {
        "h": z, "c": z, "n": z,
        "m": jnp.full((batch, n_heads, w), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d), jnp.bfloat16),
    }
