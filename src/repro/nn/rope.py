"""Rotary position embeddings: standard, partial (StableLM) and M-RoPE
(Qwen2-VL multimodal 3-section rotary, arXiv:2409.12191).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def _freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for pairs (head_dim must be even)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0,
         fraction: float = 1.0) -> jax.Array:
    """Apply RoPE.

    x:         (..., S, H, D)
    positions: (..., S)  integer positions
    fraction:  rotate only the first ``fraction`` of D (StableLM partial rope)
    """
    d = x.shape[-1]
    rot_d = int(d * fraction)
    rot_d -= rot_d % 2
    if rot_d == 0:
        return x
    x_rot, x_pass = x[..., :rot_d], x[..., rot_d:]
    inv = _freqs(rot_d, theta)                             # (rot_d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, rot_d/2)
    ang = ang[..., None, :]                                # (..., S, 1, rot_d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def mrope(x: jax.Array, positions_3d: jax.Array, *,
          sections: Sequence[int], theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): frequency bands split into (t, h, w)
    sections, each rotated by its own position stream.

    x:            (B, S, H, D)
    positions_3d: (B, 3, S) — temporal, height, width position ids
    sections:     per-section sizes in *pair* units; sum == D/2
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = _freqs(d, theta)                                  # (half,)
    # build the interleaved position stream per frequency band
    band_pos = []
    off = 0
    for s_idx, sec in enumerate(sections):
        p = positions_3d[:, s_idx, :]                       # (B, S)
        band_pos.append(jnp.broadcast_to(p[..., None], p.shape + (sec,)))
        off += sec
    pos = jnp.concatenate(band_pos, axis=-1).astype(jnp.float32)  # (B,S,half)
    ang = pos * inv                                          # (B, S, half)
    ang = ang[..., None, :]                                  # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_positions_3d(positions: jax.Array) -> jax.Array:
    """M-RoPE position stream for text-only input: t == h == w."""
    return jnp.stack([positions, positions, positions], axis=1)


def apply_rope(q: jax.Array, k: jax.Array, positions: jax.Array, *,
               theta: float, fraction: float = 1.0,
               mrope_sections: Optional[Sequence[int]] = None
               ) -> tuple[jax.Array, jax.Array]:
    """Rotate q and k with the configured scheme."""
    if mrope_sections:
        if positions.ndim == 2:  # (B, S) text-only fallback
            positions = text_positions_3d(positions)
        return (mrope(q, positions, sections=mrope_sections, theta=theta),
                mrope(k, positions, sections=mrope_sections, theta=theta))
    return (rope(q, positions, theta=theta, fraction=fraction),
            rope(k, positions, theta=theta, fraction=fraction))
