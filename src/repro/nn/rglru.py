"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)                      (recurrence gate)
    i_t = sigmoid(W_x x_t)                      (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

A *diagonal linear* recurrence — lowered to ``jax.lax.associative_scan``
(parallel over sequence, the paper's reduction-tree insight applied to
time), so prefill is O(S log S) depth and decode is a single fused update
with O(1) state.  Gate matrices are block-diagonal over heads, as in
RecurrentGemma.  Preceded by a short causal depthwise conv (width 4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import maybe_quantize
from repro.nn.module import ParamSpec

ACCUM = jnp.float32
C_RGLRU = 8.0


def rglru_block_specs(d: int, lru_width: int, n_heads: int,
                      conv_width: int = 4) -> dict:
    w = lru_width // n_heads
    return {
        "in_x": {"kernel": ParamSpec((d, lru_width), ("embed", "mlp"))},
        "in_gate": {"kernel": ParamSpec((d, lru_width), ("embed", "mlp"))},
        "conv": {"kernel": ParamSpec((conv_width, lru_width),
                                     (None, "mlp")),
                 "bias": ParamSpec((lru_width,), ("mlp",), init="zeros")},
        "gate_a": {"kernel": ParamSpec((n_heads, w, w),
                                       ("heads", None, None), scale=0.02),
                   "bias": ParamSpec((lru_width,), ("mlp",), init="zeros")},
        "gate_x": {"kernel": ParamSpec((n_heads, w, w),
                                       ("heads", None, None), scale=0.02),
                   "bias": ParamSpec((lru_width,), ("mlp",), init="zeros")},
        "lamb": ParamSpec((lru_width,), ("mlp",), init="ones"),
        "out": {"kernel": ParamSpec((lru_width, d), ("mlp", "embed"))},
    }


def _blockdiag(p: dict, x: jax.Array, n_heads: int) -> jax.Array:
    """x: (..., W) through block-diagonal (H, w, w) + bias."""
    *lead, W = x.shape
    w = W // n_heads
    xh = x.reshape(*lead, n_heads, w)
    y = jnp.einsum("...hw,hwv->...hv", xh.astype(ACCUM),
                   p["kernel"].astype(ACCUM))
    return y.reshape(*lead, W) + p["bias"].astype(ACCUM)


def _causal_conv(p: dict, x: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via shifted adds (width is small).

    x: (B, S, W).  state: (B, cw-1, W) trailing context for decode.
    Returns (y, new_state).
    """
    cw = p["kernel"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    ctx = jnp.concatenate([state, x], axis=1)        # (B, S+cw-1, W)
    y = jnp.zeros_like(x, dtype=ACCUM)
    for j in range(cw):
        tap = ctx[:, j:j + x.shape[1], :].astype(ACCUM)
        y = y + tap * p["kernel"][cw - 1 - j].astype(ACCUM)
    y = y + p["bias"].astype(ACCUM)
    new_state = ctx[:, -(cw - 1):, :] if cw > 1 else state
    return y.astype(x.dtype), new_state


def _gates(p: dict, x: jax.Array, n_heads: int
           ) -> tuple[jax.Array, jax.Array]:
    """Returns (log_a, gated_input) both (B, S, W) in fp32."""
    r = jax.nn.sigmoid(_blockdiag(p["gate_a"], x, n_heads))
    i = jax.nn.sigmoid(_blockdiag(p["gate_x"], x, n_heads))
    log_a = -C_RGLRU * jax.nn.softplus(p["lamb"].astype(ACCUM)) * r
    a2 = jnp.exp(2.0 * log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x.astype(ACCUM))
    return log_a, gx


def rglru_scan(p: dict, x: jax.Array, *, n_heads: int,
               h0: Optional[jax.Array] = None
               ) -> tuple[jax.Array, jax.Array]:
    """Parallel RG-LRU over a sequence.  x: (B, S, W) -> (y, h_last)."""
    log_a, gx = _gates(p, x, n_heads)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_seq, b_seq = jax.lax.associative_scan(combine, (a, gx), axis=1)
    h = b_seq
    if h0 is not None:
        h = h + a_seq * h0[:, None, :].astype(ACCUM)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p: dict, x: jax.Array, h: jax.Array, *, n_heads: int
               ) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  x: (B, 1, W), h: (B, W) fp32 state."""
    log_a, gx = _gates(p, x, n_heads)
    a = jnp.exp(log_a[:, 0, :])
    h_new = a * h + gx[:, 0, :]
    return h_new.astype(x.dtype)[:, None, :], h_new


def rglru_block(p: dict, x: jax.Array, *, n_heads: int,
                cache: Optional[dict] = None,
                quant: Optional[str] = None
                ) -> tuple[jax.Array, Optional[dict]]:
    """The Griffin recurrent temporal-mixing block (drop-in for attention).

    y = W_out( gelu(W_gate x) * RGLRU(conv4(W_x x)) )

    cache (decode): {"h": (B, W) fp32, "conv": (B, cw-1, W)}.
    """
    w_x = maybe_quantize(p["in_x"]["kernel"], quant).astype(x.dtype)
    w_g = maybe_quantize(p["in_gate"]["kernel"], quant).astype(x.dtype)
    xb = jnp.einsum("bsd,dw->bsw", x, w_x, preferred_element_type=ACCUM
                    ).astype(x.dtype)
    gb = jnp.einsum("bsd,dw->bsw", x, w_g, preferred_element_type=ACCUM)
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = _causal_conv(p["conv"], xb, conv_state)
    if cache is not None:
        y_rec, h = rglru_step(p, xc, cache["h"], n_heads=n_heads)
        new_cache = {"h": h, "conv": new_conv}
    else:
        y_rec, h_last = rglru_scan(p, xc, n_heads=n_heads)
        new_cache = None
    y = jax.nn.gelu(gb, approximate=True).astype(x.dtype) * y_rec
    w_o = maybe_quantize(p["out"]["kernel"], quant).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, w_o, preferred_element_type=ACCUM
                     ).astype(x.dtype)
    return out, new_cache


def rglru_cache_specs(batch: int, lru_width: int, conv_width: int = 4
                      ) -> dict:
    return {
        "h": jax.ShapeDtypeStruct((batch, lru_width), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, conv_width - 1, lru_width),
                                     jnp.bfloat16),
    }


def init_rglru_cache(batch: int, lru_width: int, conv_width: int = 4,
                     dtype=jnp.bfloat16) -> dict:
    return {
        "h": jnp.zeros((batch, lru_width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, lru_width), dtype),
    }
