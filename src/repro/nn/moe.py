"""Mixture-of-Experts with expert parallelism (EP).

Routing follows Mixtral/Qwen2-MoE: softmax router, top-k selection with
renormalised gates, plus (Qwen2-MoE) shared experts with a sigmoid gate.

Dispatch is *sort-based* (dropless-up-to-capacity): tokens are sorted by
assigned expert and scattered into per-expert capacity buffers, avoiding the
GShard one-hot dispatch einsum whose FLOPs would be ~600x the useful expert
compute at our shapes (and would poison the roofline's useful-FLOPs ratio).
Experts bind to the ``model`` mesh axis through the ``experts`` logical axis
— the paper's K_i resource-binding rule with experts as the parallel
iteration space.  Expert counts are padded to the mesh axis size when
needed (padding experts are masked out of routing).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import activation, maybe_quantize
from repro.nn.module import ParamSpec

ACCUM = jnp.float32


def moe_specs(d: int, n_experts: int, expert_d_ff: int, *,
              n_experts_padded: Optional[int] = None,
              n_shared: int = 0, shared_d_ff: int = 0) -> dict:
    e = n_experts_padded or n_experts
    s = {
        "router": {"kernel": ParamSpec((d, e), ("embed", None), scale=0.02)},
        "experts": {
            "wi": ParamSpec((e, d, expert_d_ff),
                            ("experts", "expert_embed", "expert_mlp")),
            "wg": ParamSpec((e, d, expert_d_ff),
                            ("experts", "expert_embed", "expert_mlp")),
            "wo": ParamSpec((e, expert_d_ff, d),
                            ("experts", "expert_mlp", "expert_embed")),
        },
    }
    if n_shared:
        ff = shared_d_ff or n_shared * expert_d_ff
        s["shared"] = {
            "wi": ParamSpec((d, ff), ("embed", "mlp")),
            "wg": ParamSpec((d, ff), ("embed", "mlp")),
            "wo": ParamSpec((ff, d), ("mlp", "embed")),
            "gate": ParamSpec((d, 1), ("embed", None), scale=0.02),
        }
    return s


def moe(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
        capacity_factor: float = 1.25, act: str = "silu",
        quant: Optional[str] = None, token_chunks: int = 1
        ) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE layer.  x: (B, S, d).  Returns (y, aux_loss).

    ``n_experts`` is the number of *real* experts; the router masks any
    padding experts (rows n_experts..E-1 of the router kernel).

    ``token_chunks`` > 1 processes tokens in sequential chunks (lax.scan):
    the dispatch buffers (E x C x d) and sorting scratch scale with the
    chunk, bounding transient HBM — at 32k prefill an unchunked dispatch
    buffer alone is >10 GB/device (measured on mixtral-8x7b).
    """
    b, s, d = x.shape
    n = b * s
    if token_chunks > 1 and n % token_chunks == 0:
        xc = x.reshape(token_chunks, (b * s) // token_chunks, 1, d)

        @jax.checkpoint
        def chunk_fn(xch):
            return moe(p, xch, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor, act=act,
                       quant=quant, token_chunks=1)

        def body(_, xch):
            # rematerialised per chunk: without the checkpoint, bwd saves
            # every chunk's (E, C, f) expert activations simultaneously
            return None, chunk_fn(xch)

        _, (ys, auxs) = jax.lax.scan(body, None, xc)
        return ys.reshape(b, s, d), jnp.mean(auxs)
    xt = x.reshape(n, d)
    f = activation(act)

    w_r = maybe_quantize(p["router"]["kernel"], quant)
    logits = jnp.einsum("nd,de->ne", xt.astype(ACCUM), w_r.astype(ACCUM))
    e_pad = logits.shape[-1]
    if e_pad > n_experts:                      # mask padding experts
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                   # (N, E)
    gate, eidx = jax.lax.top_k(probs, top_k)                  # (N, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # ---- sort-based capacity dispatch --------------------------------------
    nk = n * top_k
    capacity = max(1, int(n * top_k / n_experts * capacity_factor))
    flat_e = eidx.reshape(nk)                                  # (NK,)
    flat_t = jnp.arange(nk, dtype=jnp.int32) // top_k          # token ids
    flat_g = gate.reshape(nk)

    order = jnp.argsort(flat_e)                                # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    one_hot = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.int32)   # (NK, E)
    counts = jnp.sum(one_hot, axis=0)                          # (E,)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    rank = jnp.arange(nk, dtype=jnp.int32) - starts[se]        # pos in expert
    keep = (rank < capacity).astype(ACCUM)
    slot = se * capacity + jnp.minimum(rank, capacity - 1)     # (NK,)

    buf = jnp.zeros((e_pad * capacity, d), x.dtype)
    buf = buf.at[slot].add(xt[st] * keep[:, None].astype(x.dtype))
    buf = buf.reshape(e_pad, capacity, d)

    wi = maybe_quantize(p["experts"]["wi"], quant).astype(x.dtype)
    wg = maybe_quantize(p["experts"]["wg"], quant).astype(x.dtype)
    wo = maybe_quantize(p["experts"]["wo"], quant).astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, wi, preferred_element_type=ACCUM)
    g = jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=ACCUM)
    h = (f(g) * h).astype(x.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, wo,
                     preferred_element_type=ACCUM).astype(x.dtype)

    tok_out = out.reshape(e_pad * capacity, d)[slot]           # (NK, d)
    tok_out = tok_out * (sg * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[st].add(tok_out)

    # ---- shared experts (Qwen2-MoE) ----------------------------------------
    if "shared" in p:
        sh = p["shared"]
        wi_s = maybe_quantize(sh["wi"], quant).astype(x.dtype)
        wg_s = maybe_quantize(sh["wg"], quant).astype(x.dtype)
        wo_s = maybe_quantize(sh["wo"], quant).astype(x.dtype)
        hh = jnp.einsum("nd,df->nf", xt, wi_s, preferred_element_type=ACCUM)
        gg = jnp.einsum("nd,df->nf", xt, wg_s, preferred_element_type=ACCUM)
        hh = (f(gg) * hh).astype(x.dtype)
        sh_out = jnp.einsum("nf,fd->nd", hh, wo_s,
                            preferred_element_type=ACCUM)
        sh_gate = jax.nn.sigmoid(
            jnp.einsum("nd,dk->nk", xt.astype(ACCUM),
                       sh["gate"].astype(ACCUM)))
        y = y + (sh_out * sh_gate).astype(x.dtype)

    # ---- load-balancing auxiliary loss (Switch-style) ------------------------
    frac_tokens = counts.astype(ACCUM) / jnp.maximum(nk, 1)    # f_e
    mean_prob = jnp.mean(probs, axis=0)                        # P_e
    aux = n_experts * jnp.sum(frac_tokens * mean_prob)
    return y.reshape(b, s, d), aux
