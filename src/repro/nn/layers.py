"""Basic layers: norms, dense projections, embeddings, MLPs.

Convention: params are plain dicts produced from the matching ``*_specs``
function; apply functions are pure.  Matmuls run in the activation dtype
(bf16 by default) with fp32 accumulation (``preferred_element_type``), the
TPU-native discipline.  When a ``quant`` format is supplied, weights pass
through the paper's (wE,wF) quantiser first — reduced precision as a
first-class feature (paper §4.2) across every architecture.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import FORMATS, FloatFormat, quantize
from repro.nn.module import ParamSpec

ACCUM = jnp.float32


def maybe_quantize(w: jax.Array, quant: Optional[str]) -> jax.Array:
    if quant is None:
        return w
    fmt: FloatFormat = FORMATS[quant]
    return quantize(w, fmt)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]


# -- norms -------------------------------------------------------------------

def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p: dict, x: jax.Array, *, eps: float = 1e-6,
            zero_centered: bool = False) -> jax.Array:
    xf = x.astype(ACCUM)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(ACCUM)
    if zero_centered:           # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(x.dtype)


def layernorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def layernorm(p: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(ACCUM)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(ACCUM) + p["bias"].astype(ACCUM)
            ).astype(x.dtype)


# -- dense -------------------------------------------------------------------

def dense_specs(d_in: int, d_out: int, *, axes: tuple = ("embed", "mlp"),
                bias: bool = False, bias_axis: Optional[str] = None) -> dict:
    out = {"kernel": ParamSpec((d_in, d_out), axes)}
    if bias:
        out["bias"] = ParamSpec((d_out,), (bias_axis,), init="zeros")
    return out


def dense(p: dict, x: jax.Array, *, quant: Optional[str] = None) -> jax.Array:
    w = maybe_quantize(p["kernel"], quant).astype(x.dtype)
    y = jnp.einsum("...k,kn->...n", x, w,
                   preferred_element_type=ACCUM)
    if "bias" in p:
        y = y + p["bias"].astype(ACCUM)
    return y.astype(x.dtype)


# -- embedding ----------------------------------------------------------------

def embedding_specs(vocab: int, d: int) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(p: dict, ids: jax.Array, *, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[ids]


def unembed(p: dict, x: jax.Array, *, quant: Optional[str] = None
            ) -> jax.Array:
    """Project to vocabulary logits with the (possibly tied) table."""
    w = maybe_quantize(p["table"], quant).astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, w, preferred_element_type=ACCUM)


# -- MLPs ---------------------------------------------------------------------

def mlp_specs(d: int, d_ff: int, *, gated: bool = True) -> dict:
    out = {
        "wi": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d), ("mlp", "embed")),
    }
    if gated:
        out["wg"] = ParamSpec((d, d_ff), ("embed", "mlp"))
    return out


def mlp(p: dict, x: jax.Array, *, act: str = "silu",
        quant: Optional[str] = None,
        reduce_dtype=None) -> jax.Array:
    """``reduce_dtype``: dtype of the row-parallel output projection whose
    partial sums cross devices (bf16 halves the TP all-reduce bytes)."""
    f = activation(act)
    wi = maybe_quantize(p["wi"], quant).astype(x.dtype)
    wo = maybe_quantize(p["wo"], quant).astype(x.dtype)
    h = jnp.einsum("...d,df->...f", x, wi, preferred_element_type=ACCUM)
    if "wg" in p:
        wg = maybe_quantize(p["wg"], quant).astype(x.dtype)
        g = jnp.einsum("...d,df->...f", x, wg, preferred_element_type=ACCUM)
        h = f(g) * h
    else:
        h = f(h)
    h = h.astype(x.dtype)
    out_dt = reduce_dtype or ACCUM
    return jnp.einsum("...f,fd->...d", h, wo,
                      preferred_element_type=out_dt).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
