"""Attention: GQA/MQA/MHA with causal, bidirectional, local-window (SWA) and
logit-softcapped variants; blockwise (flash-style) streaming for long
prefill; full and rolling-window KV caches for decode.

All score/softmax math is fp32; projections run in the activation dtype with
fp32 accumulation.  The blockwise path is a pure-JAX ``lax.scan`` over KV
blocks with running (max, denominator, accumulator) — the memory-bounded
form the dry-run relies on for 32k prefill — and is numerically identical to
the reference full-matrix path (tested).  A Pallas flash kernel with the
same contract lives in ``repro.kernels.flash_attention`` for the TPU target.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import maybe_quantize, softcap
from repro.nn.module import ParamSpec
from repro.nn.rope import apply_rope

ACCUM = jnp.float32
NEG_INF = -2.3819763e38  # large negative, safe in bf16/f32


# -- specs --------------------------------------------------------------------

def attn_specs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
               *, qkv_bias: bool = False) -> dict:
    s = {
        "q": {"kernel": ParamSpec((d_model, n_heads, head_dim),
                                  ("embed", "heads", "head_dim"))},
        "k": {"kernel": ParamSpec((d_model, n_kv_heads, head_dim),
                                  ("embed", "kv_heads", "head_dim"))},
        "v": {"kernel": ParamSpec((d_model, n_kv_heads, head_dim),
                                  ("embed", "kv_heads", "head_dim"))},
        "o": {"kernel": ParamSpec((n_heads, head_dim, d_model),
                                  ("heads", "head_dim", "embed"))},
    }
    if qkv_bias:
        s["q"]["bias"] = ParamSpec((n_heads, head_dim),
                                   ("heads", "head_dim"), init="zeros")
        s["k"]["bias"] = ParamSpec((n_kv_heads, head_dim),
                                   ("kv_heads", "head_dim"), init="zeros")
        s["v"]["bias"] = ParamSpec((n_kv_heads, head_dim),
                                   ("kv_heads", "head_dim"), init="zeros")
    return s


def qkv_project(p: dict, x: jax.Array, *, quant: Optional[str] = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    def proj(sub):
        w = maybe_quantize(sub["kernel"], quant).astype(x.dtype)
        y = jnp.einsum("bsd,dhk->bshk", x, w, preferred_element_type=ACCUM)
        if "bias" in sub:
            y = y + sub["bias"].astype(ACCUM)
        return y.astype(x.dtype)
    return proj(p["q"]), proj(p["k"]), proj(p["v"])


def out_project(p: dict, y: jax.Array, *, quant: Optional[str] = None,
                reduce_dtype=None) -> jax.Array:
    w = maybe_quantize(p["o"]["kernel"], quant).astype(y.dtype)
    return jnp.einsum("bshk,hkd->bsd", y, w,
                      preferred_element_type=reduce_dtype or ACCUM
                      ).astype(y.dtype)


# -- masks --------------------------------------------------------------------

def mask_bias(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window: Optional[int]) -> jax.Array:
    """Additive mask bias of shape broadcastable to (..., Q, K).

    Negative key positions are the universal "invalid" sentinel (empty or
    padded cache slots, block padding) and are masked regardless of the
    causal/window flags — a bare causal test would *pass* for a negative
    sentinel since it looks like the distant past.
    """
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window is not None and window > 0:
        ok &= (qp - kp) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(ACCUM)


# -- reference full-matrix attention -------------------------------------------

def _gqa_heads(q: jax.Array, n_kv: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   q_pos: jax.Array, k_pos: jax.Array, causal: bool = True,
                   window: Optional[int] = None,
                   logit_cap: float = 0.0) -> jax.Array:
    """Materialised-scores attention (reference / short-sequence path).

    q: (B,S,H,D); k,v: (B,T,K,D); q_pos: (B,S); k_pos: (B,T).
    """
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    qr = _gqa_heads(q, n_kv)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr, k,
                        preferred_element_type=ACCUM) / jnp.sqrt(
                            jnp.asarray(d, ACCUM))
    scores = softcap(scores, logit_cap)
    bias = mask_bias(q_pos, k_pos, causal=causal, window=window)
    scores = scores + bias[:, None, None, :, :]
    w = jax.nn.softmax(scores.astype(ACCUM), axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v,
                     preferred_element_type=ACCUM)
    return out.reshape(b, s, h, d).astype(v.dtype)


# -- blockwise streaming attention ---------------------------------------------
#
# Flash-style: lax.scan over KV blocks with a running (max, denom, acc).
# Memory O(S x block) instead of O(S x T); numerically exact.  A custom VJP
# recomputes per-block scores in the backward pass (the flash-attention
# backward) — without it, jax would save every block's score matrix for
# bwd, i.e. O(S^2) per layer, defeating the whole point (measured: ~23 GB
# per device on the stablelm train_4k cell before this VJP existed).

def _blk_parts(k, v, k_pos, block_size):
    b, t = k.shape[0], k.shape[1]
    n_kv, d = k.shape[2], k.shape[3]
    if t % block_size:
        pad = block_size - t % block_size
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1_000_000)
        t += pad
    nblk = t // block_size
    kb = k.reshape(b, nblk, block_size, n_kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_size, n_kv, d).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(b, nblk, block_size).transpose(1, 0, 2)
    return kb, vb, pb, nblk


def _block_scores(qr, kc, pc, q_pos, scale, causal, window, logit_cap):
    sc = jnp.einsum("bskgd,btkd->bkgst", qr, kc,
                    preferred_element_type=ACCUM) * scale
    sc = softcap(sc, logit_cap)
    bias = mask_bias(q_pos, pc, causal=causal, window=window)
    return sc + bias[:, None, None, :, :]


def _blockwise_fwd_core(q, k, v, q_pos, k_pos, causal, window, logit_cap,
                        block_size):
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qr = _gqa_heads(q, n_kv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, ACCUM))
    kb, vb, pb, _ = _blk_parts(k, v, k_pos, block_size)

    m0 = jnp.full((b, n_kv, g, s), NEG_INF, ACCUM)
    l0 = jnp.zeros((b, n_kv, g, s), ACCUM)
    acc0 = jnp.zeros((b, s, n_kv, g, d), ACCUM)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        sc = _block_scores(qr, kc, pc, q_pos, scale, causal, window,
                           logit_cap)
        m_blk = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        p = jnp.exp(sc - m_safe[..., None])
        p = jnp.where(sc == NEG_INF, 0.0, p)
        corr = jnp.exp(jnp.where(m == NEG_INF, NEG_INF, m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(vc.dtype), vc,
                        preferred_element_type=ACCUM)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-37)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]      # (B,S,K,G,D) fp32
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _blockwise_attention(q, k, v, q_pos, k_pos, causal, window, logit_cap,
                         block_size):
    out, _, _ = _blockwise_fwd_core(q, k, v, q_pos, k_pos, causal, window,
                                    logit_cap, block_size)
    b, s, h, d = q.shape
    return out.reshape(b, s, h, d).astype(v.dtype)


def _blockwise_vjp_fwd(q, k, v, q_pos, k_pos, causal, window, logit_cap,
                       block_size):
    out, m, l = _blockwise_fwd_core(q, k, v, q_pos, k_pos, causal, window,
                                    logit_cap, block_size)
    b, s, h, d = q.shape
    o = out.reshape(b, s, h, d).astype(v.dtype)
    return o, (q, k, v, q_pos, k_pos, out, m, l)


def _blockwise_vjp_bwd(causal, window, logit_cap, block_size, res, do):
    q, k, v, q_pos, k_pos, out, m, l = res
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    t = k.shape[1]
    qr = _gqa_heads(q, n_kv).astype(ACCUM)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, ACCUM))
    do_r = do.reshape(b, s, n_kv, g, d).astype(ACCUM)
    # D_i = rowsum(dO * O)   (B,S,K,G)
    delta = jnp.sum(do_r * out, axis=-1).transpose(0, 2, 3, 1)  # (B,K,G,S)
    m_safe = jnp.where(m == NEG_INF, 0.0, m)

    kb, vb, pb, nblk = _blk_parts(k, v, k_pos, block_size)
    t_pad = nblk * block_size

    dq0 = jnp.zeros((b, s, n_kv, g, d), ACCUM)

    def step(dq, blk):
        kc, vc, pc = blk
        sc = _block_scores(qr, kc, pc, q_pos, scale, causal, window,
                           logit_cap)
        p = jnp.exp(sc - m_safe[..., None])
        p = jnp.where(sc == NEG_INF, 0.0, p)
        p = p / l[..., None]                                  # (B,K,G,S,T)
        dp = jnp.einsum("bskgd,btkd->bkgst", do_r, vc.astype(ACCUM))
        # softcap derivative: d tanh path
        if logit_cap:
            raw = jnp.einsum("bskgd,btkd->bkgst", qr, kc.astype(ACCUM)
                             ) * scale
            dcap = 1.0 - jnp.tanh(raw / logit_cap) ** 2
        else:
            dcap = 1.0
        ds = p * (dp - delta[..., None]) * dcap               # (B,K,G,S,T)
        dv = jnp.einsum("bkgst,bskgd->btkd", p, do_r)
        dk = jnp.einsum("bkgst,bskgd->btkd", ds, qr) * scale
        dq = dq + jnp.einsum("bkgst,btkd->bskgd", ds,
                             kc.astype(ACCUM)) * scale
        return dq, (dk, dv)

    dq, (dks, dvs) = jax.lax.scan(step, dq0, (kb, vb, pb))
    # (nblk, B, blk, K, D) -> (B, T, K, D), drop padding
    dk_full = dks.transpose(1, 0, 2, 3, 4).reshape(b, t_pad, n_kv, d)[:, :t]
    dv_full = dvs.transpose(1, 0, 2, 3, 4).reshape(b, t_pad, n_kv, d)[:, :t]
    dq_out = dq.reshape(b, s, h, d).astype(q.dtype)
    return (dq_out, dk_full.astype(k.dtype), dv_full.astype(v.dtype),
            None, None)


_blockwise_attention.defvjp(_blockwise_vjp_fwd, _blockwise_vjp_bwd)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_pos: jax.Array, k_pos: jax.Array,
                        causal: bool = True, window: Optional[int] = None,
                        logit_cap: float = 0.0,
                        block_size: int = 512) -> jax.Array:
    """Exact streaming attention with flash-style forward AND backward."""
    return _blockwise_attention(q, k, v, q_pos, k_pos, causal, window,
                                logit_cap, block_size)


# -- top-level self-attention ---------------------------------------------------

def self_attention(p: dict, x: jax.Array, positions: jax.Array, *,
                   n_kv_heads: int, causal: bool = True,
                   window: Optional[int] = None, logit_cap: float = 0.0,
                   rope_theta: float = 10000.0, rope_fraction: float = 1.0,
                   mrope_sections=None, quant: Optional[str] = None,
                   block_size: Optional[int] = None,
                   reduce_dtype=None) -> jax.Array:
    """Self-attention for training / prefill (no cache)."""
    q, k, v = qkv_project(p, x, quant=quant)
    pos_1d = positions if positions.ndim == 2 else positions[:, 0, :]
    q, k = apply_rope(q, k, positions, theta=rope_theta,
                      fraction=rope_fraction, mrope_sections=mrope_sections)
    kwargs = dict(q_pos=pos_1d, k_pos=pos_1d, causal=causal, window=window,
                  logit_cap=logit_cap)
    s = x.shape[1]
    if block_size is not None and s > block_size:
        y = blockwise_attention(q, k, v, block_size=block_size, **kwargs)
    else:
        y = full_attention(q, k, v, **kwargs)
    return out_project(p, y, quant=quant, reduce_dtype=reduce_dtype)


# -- KV caches -------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  *, window: Optional[int] = None,
                  dtype=jnp.bfloat16) -> dict:
    """Cache entry for one attention layer.

    Full cache:   k/v (B, max_len, K, D)
    Rolling SWA:  k/v (B, window, K, D) + kpos (B, window) actual positions
                  (-1 = empty), written at pos % window.
    """
    size = min(window, max_len) if window else max_len
    cache = {
        "k": jnp.zeros((batch, size, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, size, n_kv_heads, head_dim), dtype),
    }
    if window:
        cache["kpos"] = jnp.full((batch, size), -1, jnp.int32)
    return cache


def kv_cache_specs(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                   *, window: Optional[int] = None, dtype=jnp.bfloat16
                   ) -> dict:
    size = min(window, max_len) if window else max_len
    c = {"k": jax.ShapeDtypeStruct((batch, size, n_kv_heads, head_dim), dtype),
         "v": jax.ShapeDtypeStruct((batch, size, n_kv_heads, head_dim), dtype)}
    if window:
        c["kpos"] = jax.ShapeDtypeStruct((batch, size), jnp.int32)
    return c


def _write_at(cache_arr: jax.Array, val: jax.Array, slot: jax.Array
              ) -> jax.Array:
    """Scatter one step (B,1,...) into the cache at per-batch slot (B,).

    vmapped dynamic_update_slice lowers to a scatter along the (unsharded)
    time axis — O(1) work per step, unlike a one-hot matmul which would
    dominate the decode roofline.
    """
    def upd(c, v, s):
        start = (s,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, v.astype(c.dtype), start)
    return jax.vmap(upd)(cache_arr, val, slot)


def decode_attention(p: dict, x: jax.Array, cache: dict, pos: jax.Array, *,
                     n_kv_heads: int, window: Optional[int] = None,
                     logit_cap: float = 0.0, rope_theta: float = 10000.0,
                     rope_fraction: float = 1.0, mrope_sections=None,
                     quant: Optional[str] = None
                     ) -> tuple[jax.Array, dict]:
    """One decode step: x (B,1,d), per-sequence positions pos (B,)."""
    q, k, v = qkv_project(p, x, quant=quant)
    positions = pos[:, None]                                  # (B,1)
    if mrope_sections:
        positions3 = jnp.stack([positions] * 3, axis=1)       # (B,3,1)
        q, k = apply_rope(q, k, positions3, theta=rope_theta,
                          fraction=rope_fraction,
                          mrope_sections=mrope_sections)
    else:
        q, k = apply_rope(q, k, positions, theta=rope_theta,
                          fraction=rope_fraction)
    size = cache["k"].shape[1]
    slot = pos % size if window else jnp.minimum(pos, size - 1)
    new_k = _write_at(cache["k"], k, slot)
    new_v = _write_at(cache["v"], v, slot)
    new_cache = {"k": new_k, "v": new_v}
    if window:
        kpos = _write_at(cache["kpos"].astype(jnp.int32), pos[:, None], slot)
        new_cache["kpos"] = kpos.astype(jnp.int32)
        k_pos = new_cache["kpos"]
        # valid = written and within window of the current position
        valid = (k_pos >= 0) & (pos[:, None] - k_pos < window) & (
            k_pos <= pos[:, None])
        k_pos = jnp.where(valid, k_pos, -1_000_000)
    else:
        k_pos = jnp.broadcast_to(jnp.arange(size)[None, :],
                                 (x.shape[0], size))
        k_pos = jnp.where(k_pos <= pos[:, None], k_pos, -1_000_000)
    y = full_attention(q, new_k, new_v, q_pos=positions, k_pos=k_pos,
                       causal=True, window=None, logit_cap=logit_cap)
    return out_project(p, y, quant=quant), new_cache


# -- cross-attention (encoder-decoder) --------------------------------------------

def cross_attention(p: dict, x: jax.Array, enc: jax.Array, *,
                    n_kv_heads: int, quant: Optional[str] = None
                    ) -> jax.Array:
    """Decoder-to-encoder attention (no positional rotation, no mask)."""
    def proj(sub, inp):
        w = maybe_quantize(sub["kernel"], quant).astype(inp.dtype)
        y = jnp.einsum("bsd,dhk->bshk", inp, w, preferred_element_type=ACCUM)
        if "bias" in sub:
            y = y + sub["bias"].astype(ACCUM)
        return y.astype(inp.dtype)
    q = proj(p["q"], x)
    k = proj(p["k"], enc)
    v = proj(p["v"], enc)
    b, s = x.shape[:2]
    t = enc.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    k_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    y = full_attention(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=False)
    return out_project(p, y, quant=quant)
