"""Parameter/module system: param trees with logical sharding axes.

Every parameter is declared by a ``ParamSpec`` carrying its shape and a
tuple of *logical axis names*.  Logical names resolve to mesh axes through
``repro.core.binding.BindingRules`` — the paper's K_i resource-binding rule
operating at pod scale.  Declaring axes at parameter-creation time (rather
than annotating call sites) keeps a single source of truth for the dry-run's
in_shardings, the checkpointing layouts and the elastic resharder.

Specs compose as plain nested dicts; ``stack`` prepends a ``layers`` axis so
homogeneous blocks can be scanned with ``jax.lax.scan`` (small HLO, fast
compile — essential for lowering 40 architecture x shape cells on one CPU).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: Axes
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev; None -> 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def fan_in(self) -> int:
        if len(self.shape) <= 1:
            return max(self.shape[0] if self.shape else 1, 1)
        return int(np.prod(self.shape[:-1]))


SpecTree = Any  # nested dict of ParamSpec


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def map_specs(fn: Callable[[ParamSpec], Any], specs: SpecTree) -> Any:
    return jax.tree_util.tree_map(fn, specs, is_leaf=_is_spec)


def init_tree(specs: SpecTree, key: jax.Array) -> Any:
    """Materialise parameters (fold keys deterministically over the tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    out = []
    for i, spec in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        else:
            std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(
                spec.fan_in())
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * std
                   ).astype(spec.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(specs: SpecTree) -> Any:
    """ShapeDtypeStruct stand-ins — no allocation (dry-run path)."""
    return map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def axes_tree(specs: SpecTree) -> Any:
    """The logical-axes tree matching the param tree's structure."""
    return map_specs(lambda s: s.axes, specs)


def stack(specs: SpecTree, n: int) -> SpecTree:
    """Prepend a ``layers`` dimension to every spec (scan-over-layers)."""
    return map_specs(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, axes=("layers",) + s.axes), specs)


def param_count(specs: SpecTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs: SpecTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
                   for s in leaves))
