"""Decoder assembly: blocks, superblock scan, train forward, decode step.

Layers are grouped into *superblocks* of ``len(cfg.attn_pattern)`` layers so
heterogeneous patterns (gemma2 local/global alternation, recurrentgemma's
rec/rec/attn, xLSTM's m/m/.../s) scan with ``jax.lax.scan`` over stacked
parameters — small HLO, compile time independent of depth.  Remainder
layers (n_layers mod period) run unrolled with their own parameters.

KV/recurrent caches mirror the parameter layout (stacked per superblock
position), so the decode step scans layers and caches together.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import attention, layers, moe as moe_lib, module, rglru, xlstm

Params = Any


def _pin_batch(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Pin activation sharding to the launcher-chosen axes: batch on dim 0
    (cfg.batch_mesh_axes) and, when sequence parallelism is enabled
    (cfg.seq_mesh_axes), seq on dim 1.  No-op when unset (smoke tests)."""
    b_axes = getattr(cfg, "batch_mesh_axes", ())
    s_axes = getattr(cfg, "seq_mesh_axes", ())
    if not b_axes and not s_axes:
        return x
    from jax.sharding import PartitionSpec as P
    def entry(axes):
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else tuple(axes)
    spec = [entry(b_axes)] + [None] * (x.ndim - 1)
    if s_axes and x.ndim >= 3:
        spec[1] = entry(s_axes)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _norm_specs(cfg: ModelConfig) -> dict:
    if cfg.norm == "layernorm":
        return layers.layernorm_specs(cfg.d_model)
    return layers.rmsnorm_specs(cfg.d_model)


def _apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layers.layernorm(p, x, eps=cfg.norm_eps)
    return layers.rmsnorm(p, x, eps=cfg.norm_eps,
                          zero_centered=cfg.zero_centered_norm)


# -- one block ---------------------------------------------------------------

def mixer_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("global", "local"):
        return attention.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.resolved_head_dim,
                                    qkv_bias=cfg.qkv_bias)
    if kind == "rglru":
        return rglru.rglru_block_specs(cfg.d_model,
                                       cfg.lru_width or cfg.d_model,
                                       cfg.n_heads, cfg.conv_width)
    if kind == "mlstm":
        return xlstm.mlstm_block_specs(cfg.d_model, cfg.n_heads,
                                       proj_factor=cfg.mlstm_proj_factor,
                                       conv_width=cfg.conv_width)
    if kind == "slstm":
        return xlstm.slstm_block_specs(cfg.d_model, cfg.n_heads,
                                       conv_width=cfg.conv_width)
    raise ValueError(kind)


def block_specs(cfg: ModelConfig, kind: str) -> dict:
    s: dict = {"ln1": _norm_specs(cfg), "mixer": mixer_specs(cfg, kind)}
    has_ffn = cfg.d_ff > 0 or cfg.n_experts > 0
    if has_ffn:
        s["ln2"] = _norm_specs(cfg)
        if cfg.n_experts > 0:
            s["moe"] = moe_lib.moe_specs(
                cfg.d_model, cfg.n_experts, cfg.expert_d_ff,
                n_experts_padded=cfg.n_experts_padded or cfg.n_experts,
                n_shared=cfg.n_shared_experts, shared_d_ff=cfg.shared_d_ff)
        else:
            s["mlp"] = layers.mlp_specs(cfg.d_model, cfg.d_ff, gated=True)
    if cfg.post_norms:
        s["post1"] = _norm_specs(cfg)
        if has_ffn:
            s["post2"] = _norm_specs(cfg)
    return s


def apply_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                positions: jax.Array, *, cache: Optional[dict] = None,
                pos_scalar: Optional[jax.Array] = None,
                ) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _apply_norm(cfg, p["ln1"], x)
    new_cache = cache
    if kind in ("global", "local"):
        window = cfg.window if kind == "local" else None
        rdt = jnp.bfloat16 if getattr(cfg, "bf16_reduce", False) else None
        if cache is None:
            y = attention.self_attention(
                p["mixer"], h, positions, n_kv_heads=cfg.n_kv_heads,
                causal=True, window=window, logit_cap=cfg.attn_softcap,
                rope_theta=cfg.rope_theta, rope_fraction=cfg.rope_fraction,
                mrope_sections=cfg.mrope_sections or None,
                quant=cfg.quant_format, block_size=cfg.attn_block_size,
                reduce_dtype=rdt)
        else:
            y, new_cache = attention.decode_attention(
                p["mixer"], h, cache, pos_scalar,
                n_kv_heads=cfg.n_kv_heads, window=window or None,
                logit_cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
                rope_fraction=cfg.rope_fraction,
                mrope_sections=cfg.mrope_sections or None,
                quant=cfg.quant_format)
    elif kind == "rglru":
        y, new_cache = rglru.rglru_block(
            p["mixer"], h, n_heads=cfg.n_heads, cache=cache,
            quant=cfg.quant_format)
    elif kind == "mlstm":
        y, new_cache = xlstm.mlstm_block(
            p["mixer"], h, n_heads=cfg.n_heads, chunk=cfg.mlstm_chunk,
            cache=cache, quant=cfg.quant_format)
    elif kind == "slstm":
        y, new_cache = xlstm.slstm_block(
            p["mixer"], h, n_heads=cfg.n_heads, cache=cache,
            quant=cfg.quant_format)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        y = _apply_norm(cfg, p["post1"], y)
    x = x + y

    if "mlp" in p or "moe" in p:
        h = _apply_norm(cfg, p["ln2"], x)
        if "moe" in p:
            y, aux = moe_lib.moe(
                p["moe"], h, n_experts=cfg.n_experts,
                top_k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor, act=cfg.act,
                quant=cfg.quant_format,
                token_chunks=getattr(cfg, "moe_token_chunks", 1))
        else:
            y = layers.mlp(p["mlp"], h, act=cfg.act, quant=cfg.quant_format,
                           reduce_dtype=jnp.bfloat16 if getattr(
                               cfg, "bf16_reduce", False) else None)
        if cfg.post_norms:
            y = _apply_norm(cfg, p["post2"], y)
        x = x + y
    return x, new_cache, aux


# -- cache construction --------------------------------------------------------

def _kind_cache_specs(cfg: ModelConfig, kind: str, batch: int,
                      max_len: int) -> dict:
    dh = cfg.resolved_head_dim
    if kind == "global":
        return attention.kv_cache_specs(batch, max_len, cfg.n_kv_heads, dh)
    if kind == "local":
        return attention.kv_cache_specs(batch, max_len, cfg.n_kv_heads, dh,
                                        window=cfg.window)
    if kind == "rglru":
        return rglru.rglru_cache_specs(batch, cfg.lru_width or cfg.d_model,
                                       cfg.conv_width)
    if kind == "mlstm":
        return xlstm.mlstm_cache_specs(batch, cfg.d_model, cfg.n_heads,
                                       proj_factor=cfg.mlstm_proj_factor,
                                       conv_width=cfg.conv_width)
    if kind == "slstm":
        return xlstm.slstm_cache_specs(batch, cfg.d_model, cfg.n_heads,
                                       conv_width=cfg.conv_width)
    raise ValueError(kind)


def _kind_cache_init(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int) -> dict:
    dh = cfg.resolved_head_dim
    if kind == "global":
        return attention.init_kv_cache(batch, max_len, cfg.n_kv_heads, dh)
    if kind == "local":
        return attention.init_kv_cache(batch, max_len, cfg.n_kv_heads, dh,
                                       window=cfg.window)
    if kind == "rglru":
        return rglru.init_rglru_cache(batch, cfg.lru_width or cfg.d_model,
                                      cfg.conv_width)
    if kind == "mlstm":
        return xlstm.init_mlstm_cache(batch, cfg.d_model, cfg.n_heads,
                                      proj_factor=cfg.mlstm_proj_factor,
                                      conv_width=cfg.conv_width)
    if kind == "slstm":
        return xlstm.init_slstm_cache(batch, cfg.d_model, cfg.n_heads,
                                      conv_width=cfg.conv_width)
    raise ValueError(kind)


def _stack_tree(trees: list) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs_tree(tree: Any, n: int) -> Any:
    def f(s):
        return jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype)
    return jax.tree_util.tree_map(f, tree)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract cache tree (ShapeDtypeStructs) for the dry-run."""
    out: dict = {"blocks": {}, "extra": {}}
    for i, kind in enumerate(cfg.attn_pattern):
        per = _kind_cache_specs(cfg, kind, batch, max_len)
        out["blocks"][str(i)] = _stack_specs_tree(per, cfg.n_superblocks)
    for j in range(cfg.n_remainder_layers):
        kind = cfg.attn_pattern[j]
        out["extra"][str(j)] = _kind_cache_specs(cfg, kind, batch, max_len)
    return out


def _kind_cache_axes(cfg: ModelConfig, kind: str) -> dict:
    """Logical axes for each cache leaf (mirrors _kind_cache_specs)."""
    if kind in ("global", "local"):
        out = {"k": ("batch", None, "kv_heads", "head_dim"),
               "v": ("batch", None, "kv_heads", "head_dim")}
        if kind == "local" and cfg.window:
            out["kpos"] = ("batch", None)
        return out
    if kind == "rglru":
        return {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}
    if kind == "mlstm":
        return {"C": ("batch", "heads", "head_dim", None),
                "n": ("batch", "heads", "head_dim"),
                "m": ("batch", "heads"),
                "conv": ("batch", None, "mlp")}
    if kind == "slstm":
        ax = ("batch", "heads", "head_dim")
        return {"h": ax, "c": ax, "n": ax, "m": ax,
                "conv": ("batch", None, "embed")}
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axes tree matching ``cache_specs`` / ``init_cache``."""
    out: dict = {"blocks": {}, "extra": {}}
    for i, kind in enumerate(cfg.attn_pattern):
        per = _kind_cache_axes(cfg, kind)
        out["blocks"][str(i)] = jax.tree_util.tree_map(
            lambda a: ("layers",) + a, per,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    for j in range(cfg.n_remainder_layers):
        out["extra"][str(j)] = _kind_cache_axes(cfg, cfg.attn_pattern[j])
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    out: dict = {"blocks": {}, "extra": {}}
    for i, kind in enumerate(cfg.attn_pattern):
        per = [_kind_cache_init(cfg, kind, batch, max_len)
               for _ in range(cfg.n_superblocks)]
        out["blocks"][str(i)] = _stack_tree(per)
    for j in range(cfg.n_remainder_layers):
        kind = cfg.attn_pattern[j]
        out["extra"][str(j)] = _kind_cache_init(cfg, kind, batch, max_len)
    return out


# -- model specs ----------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> dict:
    s: dict = {
        "embed": layers.embedding_specs(cfg.vocab_size, cfg.d_model),
        "final_norm": _norm_specs(cfg),
        "blocks": {},
        "extra": {},
    }
    for i, kind in enumerate(cfg.attn_pattern):
        s["blocks"][str(i)] = module.stack(block_specs(cfg, kind),
                                           cfg.n_superblocks)
    for j in range(cfg.n_remainder_layers):
        s["extra"][str(j)] = block_specs(cfg, cfg.attn_pattern[j])
    if not cfg.tie_embeddings:
        s["unembed"] = {"kernel": module.ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))}
    if cfg.learned_positions:
        s["pos_embed"] = {"table": module.ParamSpec(
            (cfg.max_position, cfg.d_model), (None, "embed"), scale=0.02)}
    if cfg.n_patches:
        s["patch_norm"] = _norm_specs(cfg)
    return s


# -- forward (train / prefill) ----------------------------------------------------

def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None,
            last_logit_only: bool = False,
            ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss).

    tokens:  (B, S) int32
    patches: (B, P, d) precomputed frontend embeddings (VLM stub) — they are
             prepended to the token embeddings (total length must equal the
             cell's seq_len; input_specs arranges that).
    """
    dt = jnp.dtype(cfg.activation_dtype)
    x = layers.embed(params["embed"], tokens, dtype=dt)
    x = _pin_batch(cfg, x)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    if patches is not None:
        p = patches.astype(dt)
        if "patch_norm" in params:
            p = _apply_norm(cfg, params["patch_norm"], p)
        x = jnp.concatenate([p, x], axis=1)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    if cfg.learned_positions:
        pos_tab = params["pos_embed"]["table"].astype(dt)
        x = x + pos_tab[jnp.minimum(positions, pos_tab.shape[0] - 1)]

    aux_total = jnp.zeros((), jnp.float32)
    period = cfg.pattern_period

    def superblock(x, block_params):
        aux_sb = jnp.zeros((), jnp.float32)
        x = _pin_batch(cfg, x)
        for i, kind in enumerate(cfg.attn_pattern):
            fn = _maybe_remat(cfg, lambda xx, p=block_params, k=kind, idx=i:
                              apply_block(cfg, k, p[str(idx)], xx, positions))
            x, _, aux = fn(x)
            aux_sb = aux_sb + aux
        return x, aux_sb

    if cfg.scan_layers and cfg.n_superblocks > 0:
        def scan_body(carry, block_params):
            x, aux_acc = carry
            x, aux_sb = superblock(x, block_params)
            return (x, aux_acc + aux_sb), None
        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, aux_total), params["blocks"])
    else:
        for li in range(cfg.n_superblocks):
            bp = jax.tree_util.tree_map(lambda a: a[li], params["blocks"])
            x, aux_sb = superblock(x, bp)
            aux_total = aux_total + aux_sb

    for j in range(cfg.n_remainder_layers):
        kind = cfg.attn_pattern[j]
        x, _, aux = apply_block(cfg, kind, params["extra"][str(j)], x,
                                positions)
        aux_total = aux_total + aux

    x = _apply_norm(cfg, params["final_norm"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x, quant=cfg.quant_format)
    else:
        logits = layers.dense(params["unembed"], x, quant=cfg.quant_format)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux_total


# -- decode step -------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: dict, pos: jax.Array
                ) -> tuple[jax.Array, dict]:
    """One token for every sequence.  tokens (B,1); pos (B,) current index.

    Returns (logits (B, vocab), new_cache).
    """
    dt = jnp.dtype(cfg.activation_dtype)
    x = layers.embed(params["embed"], tokens, dtype=dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    positions = pos[:, None]

    new_cache: dict = {"blocks": {}, "extra": {}}
    if cfg.n_superblocks > 0:
        # The cache is a loop CARRY updated in place with
        # dynamic_update_index — XLA aliases while-loop state, so no stacked
        # ys copy of the (multi-GB) cache is ever materialised.  With scan-ys
        # the decode step would double-buffer the whole KV cache and blow the
        # 16 GB/chip budget (measured: 13.8 GB temp vs ~0.4 GB this way).
        def scan_body(carry, block_params):
            x, cache_stack, idx = carry
            block_cache = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0,
                                                       keepdims=False),
                cache_stack)
            new_bc = {}
            for i, kind in enumerate(cfg.attn_pattern):
                x, nc, _ = apply_block(cfg, kind, block_params[str(i)], x,
                                       positions, cache=block_cache[str(i)],
                                       pos_scalar=pos)
                new_bc[str(i)] = nc
            cache_stack = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0), cache_stack, new_bc)
            return (x, cache_stack, idx + 1), None
        (x, new_blocks, _), _ = jax.lax.scan(
            scan_body, (x, cache["blocks"], jnp.zeros((), jnp.int32)),
            params["blocks"])
        new_cache["blocks"] = new_blocks
    for j in range(cfg.n_remainder_layers):
        kind = cfg.attn_pattern[j]
        x, nc, _ = apply_block(cfg, kind, params["extra"][str(j)], x,
                               positions, cache=cache["extra"][str(j)],
                               pos_scalar=pos)
        new_cache["extra"][str(j)] = nc

    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x, quant=cfg.quant_format)
    else:
        logits = layers.dense(params["unembed"], x, quant=cfg.quant_format)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0, :], new_cache
