"""OpenHLS-JAX core: the paper's compiler as a composable JAX module.

Pipeline (paper Fig. 1):
    frontend (loop nests)  ->  symbolic interpretation (interp)  ->
    SSA DFG (ir)  ->  passes (forwarding/relu/fmac/trees/cse/dce)  ->
    resource-constrained list scheduling (schedule)  ->
    emission (emit: functional sim + SIMD JAX design)  ->
    behavioural verification (verify)

plus the two TPU-scale adaptations:
    precision — FloPoCo (wE,wF) emulation for weights-in-VMEM deployment
    binding   — the K_i resource-binding rule applied to device meshes
"""

from repro.core import (binding, cachedir, emit, frontend, interp, ir, passes,
                        pipeline, precision, schedule, verify)
from repro.core.binding import BindingRules, DEFAULT_RULES
from repro.core.cachedir import CACHE_FORMAT_VERSION, cache_root
from repro.core.interp import Context, MemRef, SymVal
from repro.core.ir import Graph
from repro.core.passes import optimize
from repro.core.pipeline import (CompiledDesign, CompilerConfig,
                                 CompilerDriver, DesignCache, PassManager,
                                 PassReport, register_pass)
from repro.core.precision import FP_5_3, FP_5_4, FP_5_11, FloatFormat, quantize, ste_quantize
from repro.core.schedule import (Schedule, ScheduleParams, list_schedule,
                                 partition_stages)
from repro.core.verify import run_testbench

__all__ = [
    "binding", "cachedir", "emit", "frontend", "interp", "ir", "passes",
    "pipeline", "precision", "schedule", "verify", "BindingRules",
    "DEFAULT_RULES", "CACHE_FORMAT_VERSION", "cache_root",
    "Context", "MemRef", "SymVal", "Graph", "optimize", "CompiledDesign",
    "CompilerConfig", "CompilerDriver", "DesignCache", "PassManager",
    "PassReport", "register_pass", "FP_5_3", "FP_5_4", "FP_5_11",
    "FloatFormat", "quantize", "ste_quantize", "Schedule", "ScheduleParams",
    "list_schedule", "partition_stages", "run_testbench",
]
