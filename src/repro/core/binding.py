"""Resource binding at pod scale: parallel axes -> mesh axes (paper §3.3).

On the FPGA, OpenHLS binds the instances of an scf.parallel iteration space
to K_i functional units.  On a TPU pod the functional units are chips, and
the binding is a sharding: each *named* parallel axis of a tensor operation
(batch, heads, experts, ...) binds to a mesh axis via a rule table, and
K_i = product of bound mesh-axis sizes is the replication factor — exactly
the paper's K_i, computed over devices instead of DSPs.

This module is the single source of truth for shardings across the
framework: model code annotates arrays with *logical* axis names, and the
launcher resolves them against the active mesh through these rules
(MaxText-style logical axis rules, derived here from the paper's binding
discipline).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple[str, ...]]


#: Default rule table for the production mesh (pod, data, model).
#: First matching rule wins.  ``None`` = replicated along that logical axis.
DEFAULT_RULES: tuple[tuple[str, MeshAxes], ...] = (
    ("batch", ("pod", "data")),   # DP across pods and the data axis
    ("seq", None),                # sequence replicated in train (SP opt-in)
    ("seq_shard", "data"),        # context/sequence parallelism (opt-in)
    ("embed", None),              # activations' feature dim replicated
    ("heads", "model"),           # TP over attention heads
    ("kv_heads", "model"),        # TP over KV heads (GQA)
    ("qkv", None),
    ("mlp", "model"),             # TP over FFN hidden (Megatron column)
    ("mlp_in", "model"),
    ("experts", "model"),         # EP: experts bound to the model axis
    ("expert_mlp", None),         # within-expert hidden replicated under EP
    ("expert_embed", None),       # FSDP opt-in for huge replicated experts
    ("vocab", "model"),           # TP over the embedding/vocab dim
    ("kv_batch", ("pod", "data")),  # KV cache batch dim
    ("layers", None),             # stacked-layer leading dim (scan axis)
    ("conv", None),
    ("head_dim", None),           # per-arch overrides bind this to model
    ("opt_embed", "data"),        # ZeRO: optimizer state also shards the
                                  # embed dim over data (see optim.adamw)
)


@dataclasses.dataclass(frozen=True)
class BindingRules:
    rules: tuple[tuple[str, MeshAxes], ...] = DEFAULT_RULES

    def mesh_axes_for(self, logical: Optional[str],
                      mesh: Mesh) -> MeshAxes:
        if logical is None:
            return None
        for name, target in self.rules:
            if name != logical:
                continue
            if target is None:
                return None
            axes = (target,) if isinstance(target, str) else tuple(target)
            present = tuple(a for a in axes if a in mesh.shape)
            if not present:
                return None
            return present if len(present) > 1 else present[0]
        return None

    def spec(self, logical_axes: Sequence[Optional[str]],
             mesh: Mesh) -> P:
        """PartitionSpec for an array annotated with logical axis names."""
        used: set[str] = set()
        out: list[MeshAxes] = []
        for ax in logical_axes:
            target = self.mesh_axes_for(ax, mesh)
            if target is None:
                out.append(None)
                continue
            axes = (target,) if isinstance(target, str) else tuple(target)
            fresh = tuple(a for a in axes if a not in used)
            used.update(fresh)
            if not fresh:
                out.append(None)
            elif len(fresh) == 1:
                out.append(fresh[0])
            else:
                out.append(fresh)
        return P(*out)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes, mesh))

    def K(self, logical_axes: Sequence[Optional[str]], mesh: Mesh) -> int:
        """Replication factor K_i of a binding (paper §3.3): the number of
        devices an op's parallel iteration space is spread across."""
        spec = self.spec(logical_axes, mesh)
        k = 1
        for entry in spec:
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            for a in axes:
                k *= mesh.shape[a]
        return k

    def with_overrides(self, **overrides: MeshAxes) -> "BindingRules":
        """Return new rules with some logical axes re-bound (hillclimbing)."""
        new = tuple((k, v) for k, v in overrides.items())
        rest = tuple((k, v) for k, v in self.rules if k not in overrides)
        return BindingRules(new + rest)


def tree_shardings(axes_tree, mesh: Mesh,
                   rules: Optional[BindingRules] = None):
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings."""
    rules = rules or BindingRules()
    return jax.tree_util.tree_map(
        lambda axes: rules.sharding(axes, mesh), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
