"""Versioned on-disk cache roots shared by every persistent artifact store.

The design cache (``pipeline.DesignCache``) and the tuning database
(``repro.tune.TuningDB``) both persist artifacts whose layout follows the
compiler's own data structures, so a single format-version number governs
both: ``CACHE_FORMAT_VERSION`` is folded into every design hash *and* names
the on-disk directory level (``<root>/v<N>/<kind>/``).  Bumping it turns
every stale entry into a miss — and ``cache_root`` additionally *evicts*
sibling ``v<M>`` directories from older versions, so abandoned entries do
not accumulate forever (the PR-1 disk cache never cleaned these up).
"""

from __future__ import annotations

import os
import re
import shutil
import tempfile
from pathlib import Path
from typing import Optional, Union

#: Folded into every design hash and into the cache directory layout: bump
#: when Graph/Schedule/CompiledDesign layout, pass semantics, or the tuning
#: record schema change, so stale on-disk entries from older code versions
#: become cache misses instead of loading into incompatible objects.
#: v4: struct-of-arrays Graph serialisation (numpy columns replace the Op
#: list) and the column-bytes graph fingerprint.
CACHE_FORMAT_VERSION = 4

_VERSION_DIR = re.compile(r"^v\d+$")


def default_cache_base() -> Path:
    """Per-user base directory for all repro caches.

    ``$REPRO_CACHE_DIR`` overrides; the default lives under the system temp
    dir, suffixed with the uid — cache entries include pickles and must
    never be shared between users.
    """
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env)
    uid = os.getuid() if hasattr(os, "getuid") else "u"
    return Path(tempfile.gettempdir()) / f"repro_cache_{uid}"


def evict_stale_versions(base: Union[str, Path], *,
                         keep_version: int = CACHE_FORMAT_VERSION) -> list[str]:
    """Delete ``v<M>`` cache trees under ``base`` for every ``M != keep``.

    Only directories matching ``v<digits>`` exactly are touched; anything
    else under ``base`` is left alone.  Returns the names removed (eviction
    is best-effort: a tree that cannot be removed is skipped).
    """
    base = Path(base)
    removed: list[str] = []
    if not base.is_dir():
        return removed
    for entry in base.iterdir():
        if (entry.is_dir() and _VERSION_DIR.match(entry.name)
                and entry.name != f"v{keep_version}"):
            try:
                shutil.rmtree(entry)
                removed.append(entry.name)
            except OSError:
                continue
    return removed


def _evict_legacy_roots() -> None:
    """Remove pre-versioning cache trees this layout superseded.

    The PR-1 design cache lived at ``$TMPDIR/repro_design_cache_<uid>``
    with no version level and no eviction; it is unreachable by the new
    code, so clean it up rather than leaving its pickles behind forever.
    """
    uid = os.getuid() if hasattr(os, "getuid") else "u"
    legacy = Path(tempfile.gettempdir()) / f"repro_design_cache_{uid}"
    if legacy.is_dir():
        try:
            shutil.rmtree(legacy)
        except OSError:
            pass


def cache_root(kind: str, *, base: Optional[Union[str, Path]] = None,
               version: int = CACHE_FORMAT_VERSION,
               evict_stale: bool = True) -> Path:
    """The managed cache directory for one artifact kind, e.g. ``designs``.

    Returns ``<base>/v<version>/<kind>`` (created 0700 if missing) and, by
    default, evicts sibling version trees (and the pre-versioning legacy
    design-cache dir) first.
    """
    base = Path(base) if base is not None else default_cache_base()
    base.mkdir(parents=True, exist_ok=True, mode=0o700)
    if evict_stale:
        evict_stale_versions(base, keep_version=version)
        _evict_legacy_roots()
    root = base / f"v{version}" / kind
    root.mkdir(parents=True, exist_ok=True, mode=0o700)
    return root
