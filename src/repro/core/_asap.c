/* The sequential ASAP resource-serialisation core (pool binding).
 *
 * This is a literal port of the Python reference loop in
 * repro/core/schedule.py:_asap_scalar — same earliest-free-unit discipline
 * over the same packed (free_time * cap + unit_id) heaps, so the two are
 * bit-identical by construction (and proven so by the golden suite).  The
 * loop is inherently order-serial: each op's issue slot depends on every
 * earlier allocation in its pool, and measured wave-batching collapses to
 * ~1 op per wave on rank-major traces (each parallel instance's reduction
 * chain is contiguous in program order).  Hence a compiled kernel rather
 * than an array program.
 *
 * Built lazily by repro/core/cext.py with the system C compiler; the
 * Python loop remains the fallback when no compiler is available.
 *
 * Heap invariant (shared with the Python core): every acquire pops at most
 * one entry and pushes exactly one entry for the same unit, so a pool's
 * heap always holds exactly one entry per allocated unit; entries are
 * distinct because unit ids are distinct mod cap.  Pop order is therefore
 * implementation-independent (no ties), and any correct binary heap
 * reproduces heapq's sequence.
 */

#include <stdint.h>
#include <stdlib.h>

typedef int64_t i64;

static void heap_push(i64 *h, i64 *sz, i64 v) {
    i64 i = (*sz)++;
    h[i] = v;
    while (i > 0) {
        i64 p = (i - 1) >> 1;
        if (h[p] <= h[i])
            break;
        i64 tmp = h[p];
        h[p] = h[i];
        h[i] = tmp;
        i = p;
    }
}

static i64 heap_pop(i64 *h, i64 *sz) {
    i64 top = h[0];
    i64 last = h[--(*sz)];
    i64 n = *sz;
    i64 i = 0;
    for (;;) {
        i64 l = 2 * i + 1;
        if (l >= n)
            break;
        i64 r = l + 1;
        i64 m = (r < n && h[r] < h[l]) ? r : l;
        if (h[m] >= last)
            break;
        h[i] = h[m];
        i = m;
    }
    if (n > 0)
        h[i] = last;
    return top;
}

/* Returns 0 on success, 1 on allocation failure.
 *
 * start/key/ready/class_alloc/port_alloc are outputs; key must arrive
 * filled with -1, ready and the alloc arrays zeroed.  n_arrays may be 0
 * when no port-class ops exist (port_alloc then still needs 1 slot).
 */
int asap_pool(i64 n, i64 nv,
              const i64 *a0, const i64 *a1, const i64 *a2,
              const i64 *res, const i64 *dl, const i64 *ol,
              const i64 *cls, const i64 *aid,
              i64 n_classes, i64 cap_k, i64 ports_cap, i64 stride,
              i64 n_arrays, i64 port_class_id,
              i64 *start, i64 *key, i64 *ready,
              i64 *class_alloc, i64 *port_alloc) {
    /* heap entries per pool never exceed min(cap, n) */
    i64 cbuf = cap_k < n ? cap_k : n;
    if (cbuf < 1)
        cbuf = 1;
    i64 pbuf = ports_cap < n ? ports_cap : n;
    if (pbuf < 1)
        pbuf = 1;
    i64 *class_heap = malloc((size_t)(n_classes * cbuf) * sizeof(i64));
    i64 *class_sz = calloc((size_t)n_classes, sizeof(i64));
    i64 *port_heap = NULL;
    i64 *port_sz = NULL;
    if (n_arrays > 0) {
        port_heap = malloc((size_t)(n_arrays * pbuf) * sizeof(i64));
        port_sz = calloc((size_t)n_arrays, sizeof(i64));
    }
    if (!class_heap || !class_sz ||
        (n_arrays > 0 && (!port_heap || !port_sz))) {
        free(class_heap);
        free(class_sz);
        free(port_heap);
        free(port_sz);
        return 1;
    }

    for (i64 i = 0; i < n; i++) {
        i64 t = 0;
        i64 a = a0[i];
        if (a >= 0) {
            i64 ta = ready[a];
            if (ta > t)
                t = ta;
            a = a1[i];
            if (a >= 0) {
                ta = ready[a];
                if (ta > t)
                    t = ta;
                a = a2[i];
                if (a >= 0) {
                    ta = ready[a];
                    if (ta > t)
                        t = ta;
                }
            }
        }
        i64 cl = cls[i];
        if (cl) {
            i64 *h, *sz, *alloc, cap, key_base;
            if (cl == port_class_id) {
                i64 ar = aid[i];
                h = port_heap + ar * pbuf;
                sz = port_sz + ar;
                alloc = port_alloc + ar;
                cap = ports_cap;
                key_base = (n_classes + ar) * stride;
            } else {
                h = class_heap + cl * cbuf;
                sz = class_sz + cl;
                alloc = class_alloc + cl;
                cap = cap_k;
                key_base = cl * stride;
            }
            i64 uid;
            if (*sz > 0 && h[0] <= t * cap + cap - 1) {
                uid = heap_pop(h, sz) % cap;
            } else if (*alloc < cap) {
                uid = (*alloc)++;
            } else {
                i64 packed = heap_pop(h, sz);
                i64 fr = packed / cap;
                uid = packed % cap;
                if (fr > t)
                    t = fr;
            }
            heap_push(h, sz, (t + ol[i]) * cap + uid);
            key[i] = key_base + uid;
        }
        start[i] = t;
        i64 r = res[i];
        if (r >= 0)
            ready[r] = t + dl[i];
    }

    free(class_heap);
    free(class_sz);
    free(port_heap);
    free(port_sz);
    return 0;
}
