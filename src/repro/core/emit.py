"""Design emission + functional simulation (paper §3.1 item 4, §3.2).

Three execution backends for a scheduled DFG:

  * ``evaluate``      — numpy functional simulation in program order.  With a
                        ``FloatFormat`` this becomes the FloPoCo functional
                        model (quantise after every operation), i.e. the
                        reference the paper's testbenches compare RTL against.
  * ``to_jax_fn``     — "RTL emission" for TPU: the DFG is levelised by its
                        schedule and each (cycle-level, opcode) group becomes
                        one vectorised gather/compute/scatter — a SIMD
                        rendering of the fully scheduled design.  The emitted
                        function is jittable and exactly evaluates the DFG.
  * the tensor path   — production inference uses the tensor-level model
                        (``repro.models``) with ``precision.quantize``
                        inserted per the chosen format; the scalar DFG
                        backends above serve as its behavioural oracle.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.ir import Graph
from repro.core.precision import FloatFormat, quantize_np


def _input_arrays(g: Graph, feeds: dict[str, np.ndarray], batch: int
                  ) -> dict[int, np.ndarray]:
    """Scatter feed tensors into per-value (batch,) vectors."""
    vals: dict[int, np.ndarray] = {}
    for name, table in g.inputs.items():
        if name not in feeds:
            raise KeyError(f"missing feed for input memref '{name}'")
        arr = np.asarray(feeds[name], dtype=np.float32)
        for idx, vid in table.items():
            if arr.ndim == len(idx):          # unbatched feed: broadcast
                vals[vid] = np.full((batch,), arr[idx], dtype=np.float32)
            else:                              # leading batch dimension
                vals[vid] = np.ascontiguousarray(
                    arr[(slice(None),) + idx], dtype=np.float32)
    return vals


def evaluate(g: Graph, feeds: dict[str, np.ndarray], *,
             fmt: Optional[FloatFormat] = None,
             batch: Optional[int] = None) -> dict[str, np.ndarray]:
    """Functional simulation of the DFG on a batch of input vectors.

    feeds: memref name -> array of shape ``shape`` or ``(batch,) + shape``.
    fmt:   if given, every input, constant and op result is quantised —
           the FloPoCo functional-model mode (paper §3.1 item 4).
    """
    if batch is None:
        batch = 1
        for name, arr in feeds.items():
            arr = np.asarray(arr)
            want = g.inputs.get(name)
            if want and arr.ndim == len(next(iter(want))) + 1:
                batch = arr.shape[0]
                break
    q = (lambda x: quantize_np(x, fmt)) if fmt is not None else (lambda x: x)

    vals = _input_arrays(g, feeds, batch)
    for vid in list(vals):
        vals[vid] = q(vals[vid])
    for vid, c in g.consts.items():
        vals[vid] = q(np.full((batch,), c, dtype=np.float32))

    for op in g.ops:
        a = op.args
        oc = op.opcode
        if oc == "mulf":
            r = vals[a[0]] * vals[a[1]]
        elif oc == "addf":
            r = vals[a[0]] + vals[a[1]]
        elif oc == "subf":
            r = vals[a[0]] - vals[a[1]]
        elif oc == "divf":
            r = vals[a[0]] / vals[a[1]]
        elif oc == "sqrtf":
            r = np.sqrt(vals[a[0]])
        elif oc == "maxf":
            r = np.maximum(vals[a[0]], vals[a[1]])
        elif oc == "minf":
            r = np.minimum(vals[a[0]], vals[a[1]])
        elif oc == "negf":
            r = -vals[a[0]]
        elif oc == "relu":
            r = np.maximum(vals[a[0]], 0.0)
        elif oc == "fmac":
            # fmac(b, c, a) = b*c + a, rounded once (fused on FPGA)
            r = vals[a[0]] * vals[a[1]] + vals[a[2]]
        elif oc == "cmpugt":
            r = (vals[a[0]] > vals[a[1]]).astype(np.float32)
        elif oc == "select":
            r = np.where(vals[a[0]] > 0.5, vals[a[1]], vals[a[2]])
        elif oc == "load":
            r = vals[a[0]]
        elif oc == "store":
            r = vals[a[0]]
        elif oc == "copy":
            r = vals[a[0]]
        else:  # pragma: no cover
            raise NotImplementedError(oc)
        if oc not in ("cmpugt", "load", "store", "copy"):
            r = q(r)
        if op.result >= 0:
            vals[op.result] = r

    outs: dict[str, np.ndarray] = {}
    for name, table in g.outputs.items():
        shape = tuple(max(i[d] for i in table) + 1
                      for d in range(len(next(iter(table)))))
        out = np.zeros((batch,) + shape, dtype=np.float32)
        for idx, vid in table.items():
            out[(slice(None),) + idx] = vals[vid]
        outs[name] = out
    return outs


# ---------------------------------------------------------------------------
# SIMD emission: the TPU rendering of the fully scheduled design
# ---------------------------------------------------------------------------

def to_jax_fn(g: Graph) -> Callable[[dict[str, "np.ndarray"]], dict[str, "np.ndarray"]]:
    """Emit a jittable function that exactly evaluates the DFG.

    The DFG is levelised (ASAP with unit delays); each (level, opcode) group
    becomes one gather -> vector op -> scatter.  This is the SIMD analogue of
    RTL emission: every op executes at its scheduled level, with no dynamic
    control flow — the XLA program is the FSM.
    """
    import jax
    import jax.numpy as jnp

    # levelise
    level = np.zeros(g.n_values, dtype=np.int64)
    op_level = np.zeros(len(g.ops), dtype=np.int64)
    for op in g.ops:
        lv = 0
        for a in op.args:
            lv = max(lv, int(level[a]) + 1)
        op_level[op.idx] = lv
        if op.result >= 0:
            level[op.result] = lv

    # group ops by (level, opcode)
    groups: dict[tuple[int, str], list] = {}
    for op in g.ops:
        groups.setdefault((int(op_level[op.idx]), op.opcode), []).append(op)
    ordered = sorted(groups.items(), key=lambda kv: kv[0][0])

    # precompute gather/scatter index arrays
    compiled_groups = []
    for (lv, oc), ops in ordered:
        n_args = max(len(o.args) for o in ops)
        arg_idx = [np.array([o.args[i] if i < len(o.args) else 0
                             for o in ops], dtype=np.int32)
                   for i in range(n_args)]
        res_idx = np.array([o.result for o in ops], dtype=np.int32)
        compiled_groups.append((oc, arg_idx, res_idx))

    const_idx = np.array(sorted(g.consts), dtype=np.int32)
    const_val = np.array([g.consts[int(i)] for i in const_idx],
                         dtype=np.float32)
    input_scatter = {
        name: (np.array([vid for _, vid in sorted(table.items())],
                        dtype=np.int32),
               [idx for idx, _ in sorted(table.items())])
        for name, table in g.inputs.items()
    }
    output_gather = {
        name: (np.array([vid for _, vid in sorted(table.items())],
                        dtype=np.int32),
               tuple(max(i[d] for i in table) + 1
                     for d in range(len(next(iter(table))))))
        for name, table in g.outputs.items()
    }
    n_values = g.n_values

    def run(feeds: dict[str, jax.Array]) -> dict[str, jax.Array]:
        example_name = next(iter(input_scatter))
        rank = len(next(iter(g.inputs[example_name])))
        ex_shape = jnp.shape(feeds[example_name])
        batch = ex_shape[0] if len(ex_shape) == rank + 1 else 1
        buf = jnp.zeros((batch, n_values), dtype=jnp.float32)
        buf = buf.at[:, const_idx].set(const_val[None, :])
        for name, (vids, idxs) in input_scatter.items():
            arr = jnp.asarray(feeds[name], dtype=jnp.float32)
            if arr.ndim == len(idxs[0]):
                arr = arr[None]
            flat = jnp.stack([arr[(slice(None),) + i] for i in idxs], axis=1)
            buf = buf.at[:, vids].set(flat)
        for oc, arg_idx, res_idx in compiled_groups:
            a = [buf[:, ai] for ai in arg_idx]
            if oc == "mulf":
                r = a[0] * a[1]
            elif oc == "addf":
                r = a[0] + a[1]
            elif oc == "subf":
                r = a[0] - a[1]
            elif oc == "divf":
                r = a[0] / a[1]
            elif oc == "sqrtf":
                r = jnp.sqrt(a[0])
            elif oc == "maxf":
                r = jnp.maximum(a[0], a[1])
            elif oc == "minf":
                r = jnp.minimum(a[0], a[1])
            elif oc == "negf":
                r = -a[0]
            elif oc == "relu":
                r = jnp.maximum(a[0], 0.0)
            elif oc == "fmac":
                r = a[0] * a[1] + a[2]
            elif oc == "cmpugt":
                r = (a[0] > a[1]).astype(jnp.float32)
            elif oc == "select":
                r = jnp.where(a[0] > 0.5, a[1], a[2])
            elif oc in ("load", "store", "copy"):
                r = a[0]
            else:  # pragma: no cover
                raise NotImplementedError(oc)
            buf = buf.at[:, res_idx].set(r)
        outs = {}
        for name, (vids, shape) in output_gather.items():
            outs[name] = buf[:, vids].reshape((batch,) + shape)
        return outs

    return run
