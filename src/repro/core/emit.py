"""Design emission + functional simulation (paper §3.1 item 4, §3.2).

Three execution backends for a scheduled DFG:

  * ``evaluate``      — numpy functional simulation.  With a ``FloatFormat``
                        this becomes the FloPoCo functional model (quantise
                        after every operation), i.e. the reference the
                        paper's testbenches compare RTL against.  The DFG is
                        levelised and each (level, opcode) group executes as
                        one vectorised gather/compute/scatter over a dense
                        ``(n_values, batch)`` value matrix — bit-identical
                        to the historical per-op program-order loop (which
                        survives in ``repro.core.legacy``; route through it
                        with ``REPRO_LEGACY_IR=1``).
  * ``to_jax_fn``     — "RTL emission" for TPU: the DFG is levelised by its
                        schedule and each (cycle-level, opcode) group becomes
                        one vectorised gather/compute/scatter — a SIMD
                        rendering of the fully scheduled design.  The emitted
                        function is jittable and exactly evaluates the DFG.
  * the tensor path   — production inference uses the tensor-level model
                        (``repro.models``) with ``precision.quantize``
                        inserted per the chosen format; the scalar DFG
                        backends above serve as its behavioural oracle.
"""

from __future__ import annotations

import os
from typing import Callable, Optional

import numpy as np

from repro.core.ir import OPCODES, Graph, GraphCols
from repro.core.precision import FloatFormat, quantize_np


def _input_arrays(g: Graph, feeds: dict[str, np.ndarray], batch: int
                  ) -> dict[int, np.ndarray]:
    """Scatter feed tensors into per-value (batch,) vectors."""
    vals: dict[int, np.ndarray] = {}
    for name, table in g.inputs.items():
        if name not in feeds:
            raise KeyError(f"missing feed for input memref '{name}'")
        arr = np.asarray(feeds[name], dtype=np.float32)
        for idx, vid in table.items():
            if arr.ndim == len(idx):          # unbatched feed: broadcast
                vals[vid] = np.full((batch,), arr[idx], dtype=np.float32)
            else:                              # leading batch dimension
                vals[vid] = np.ascontiguousarray(
                    arr[(slice(None),) + idx], dtype=np.float32)
    return vals


def levelize(c: GraphCols, n_values: int) -> np.ndarray:
    """ASAP levels (unit delays) per op, computed as Kahn waves.

    An op's level is 1 + the max level of its operand values (inputs and
    constants sit at level 0) — the longest-path depth the historical per-op
    loop computed sequentially.  Each wave resolves every op whose operands
    are all known, so total work is linear in edges with one numpy step per
    DAG level.
    """
    n = c.n
    op_level = np.zeros(n, dtype=np.int64)
    if n == 0:
        return op_level
    args = c.args
    am = args >= 0
    pa = np.where(am, c.producer[np.clip(args, 0, None)], -1)
    dep = pa >= 0
    indeg = dep.sum(axis=1)
    # consumer CSR: edges producer-op -> consumer-op
    pe = pa[dep]
    ce = np.broadcast_to(np.arange(n)[:, None], pa.shape)[dep]
    order = np.argsort(pe, kind="stable")
    ce_s = ce[order]
    counts = np.bincount(pe[order], minlength=n)
    offs = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    val_level = np.zeros(max(n_values, 1), dtype=np.int64)
    frontier = np.flatnonzero(indeg == 0)
    remaining = indeg
    while frontier.size:
        fa = args[frontier]
        lv = np.where(fa >= 0, val_level[np.clip(fa, 0, None)] + 1, 0) \
            .max(axis=1)
        op_level[frontier] = lv
        fr = c.result[frontier]
        rmask = fr >= 0
        val_level[fr[rmask]] = lv[rmask]
        lens = counts[frontier]
        tot = int(lens.sum())
        if not tot:
            break
        base = np.repeat(offs[frontier], lens)
        within = np.arange(tot) - np.repeat(np.cumsum(lens) - lens, lens)
        cons = ce_s[base + within]
        remaining = remaining - np.bincount(cons, minlength=n)
        frontier = np.unique(cons[remaining[cons] == 0])
    return op_level


def _level_groups(c: GraphCols, n_values: int):
    """Rows grouped by (level, opcode), levels ascending, rows in program
    order within each group."""
    if c.n == 0:      # passthrough design: outputs wired straight to inputs
        return
    op_level = levelize(c, n_values)
    order = np.lexsort((np.arange(c.n), c.opcode, op_level))
    lv_s = op_level[order]
    oc_s = c.opcode[order]
    brk = np.flatnonzero((np.diff(lv_s) != 0) | (np.diff(oc_s) != 0)) + 1
    for rows in np.split(order, brk):
        yield int(op_level[rows[0]]), OPCODES[c.opcode[rows[0]]], rows


def compile_groups(c: GraphCols, n_values: int
                   ) -> list[tuple[int, str, list[np.ndarray], np.ndarray]]:
    """Precompute the gather/scatter index arrays per (level, opcode) group.

    Returns ``(level, opcode, [arg index arrays], result index array)``
    tuples in level order — the shared unit of emission for the SIMD
    rendering (:func:`to_jax_fn`) and the Pallas backend
    (``repro.core.emit_pallas``), which fuses contiguous runs of them into
    compiled kernels.
    """
    groups = []
    for lv, oc, rows in _level_groups(c, n_values):
        ga = c.args[rows]
        n_args = int((ga >= 0).sum(axis=1).max()) if len(rows) else 0
        arg_idx = [np.where(ga[:, i] >= 0, ga[:, i], 0).astype(np.int32)
                   for i in range(n_args)]
        res_idx = c.result[rows].astype(np.int32)
        groups.append((lv, oc, arg_idx, res_idx))
    return groups


def io_tables(g: Graph):
    """Constant / input-scatter / output-gather index tables of a DFG.

    Shared by every vectorised emitter: ``const_idx``/``const_val`` seed the
    value buffer, ``input_scatter[name] = (vids, idx tuples)`` place feeds,
    ``output_gather[name] = (vids, shape)`` assemble outputs.
    """
    const_idx = np.array(sorted(g.consts), dtype=np.int32)
    const_val = np.array([g.consts[int(i)] for i in const_idx],
                         dtype=np.float32)
    input_scatter = {
        name: (np.array([vid for _, vid in sorted(table.items())],
                        dtype=np.int32),
               [idx for idx, _ in sorted(table.items())])
        for name, table in g.inputs.items()
    }
    output_gather = {
        name: (np.array([vid for _, vid in sorted(table.items())],
                        dtype=np.int32),
               tuple(max(i[d] for i in table) + 1
                     for d in range(len(next(iter(table))))))
        for name, table in g.outputs.items()
    }
    return const_idx, const_val, input_scatter, output_gather


def _assemble_outputs(g: Graph, batch: int, value_of
                      ) -> dict[str, np.ndarray]:
    """Scatter per-value (batch,) vectors into output tensors.

    ``value_of(vid) -> (batch,)`` abstracts over the two simulators' value
    stores (the legacy dict, the vectorised value matrix) so both paths
    share one assembly.
    """
    outs: dict[str, np.ndarray] = {}
    for name, table in g.outputs.items():
        shape = tuple(max(i[d] for i in table) + 1
                      for d in range(len(next(iter(table)))))
        out = np.zeros((batch,) + shape, dtype=np.float32)
        for idx, vid in table.items():
            out[(slice(None),) + idx] = value_of(vid)
        outs[name] = out
    return outs


def evaluate(g: Graph, feeds: dict[str, np.ndarray], *,
             fmt: Optional[FloatFormat] = None,
             batch: Optional[int] = None) -> dict[str, np.ndarray]:
    """Functional simulation of the DFG on a batch of input vectors.

    feeds: memref name -> array of shape ``shape`` or ``(batch,) + shape``.
    fmt:   if given, every input, constant and op result is quantised —
           the FloPoCo functional-model mode (paper §3.1 item 4).
    """
    if batch is None:
        batch = 1
        for name, arr in feeds.items():
            arr = np.asarray(arr)
            want = g.inputs.get(name)
            if want and arr.ndim == len(next(iter(want))) + 1:
                batch = arr.shape[0]
                break
    q = (lambda x: quantize_np(x, fmt)) if fmt is not None else (lambda x: x)

    vals = _input_arrays(g, feeds, batch)
    if os.environ.get("REPRO_LEGACY_IR", "") == "1":
        from repro.core import legacy
        for vid in list(vals):
            vals[vid] = q(vals[vid])
        for vid, cv in g.consts.items():
            vals[vid] = q(np.full((batch,), cv, dtype=np.float32))
        vals = legacy.evaluate(g, vals, batch, q)
        return _assemble_outputs(g, batch, vals.__getitem__)

    c = g.cols()
    M = np.zeros((max(g.n_values, 1), batch), dtype=np.float32)
    if vals:
        ivids = np.fromiter(vals.keys(), dtype=np.int64, count=len(vals))
        M[ivids] = q(np.stack(list(vals.values()), axis=0))
    if g.consts:
        cvids = np.fromiter(g.consts.keys(), dtype=np.int64,
                            count=len(g.consts))
        cvals = np.fromiter(g.consts.values(), dtype=np.float32,
                            count=len(g.consts))
        M[cvids] = q(np.broadcast_to(cvals[:, None],
                                     (len(cvals), batch)).copy())

    args, res = c.args, c.result
    for _lv, oc, rows in _level_groups(c, g.n_values):
        a0 = M[args[rows, 0]]
        if oc == "mulf":
            r = a0 * M[args[rows, 1]]
        elif oc == "addf":
            r = a0 + M[args[rows, 1]]
        elif oc == "subf":
            r = a0 - M[args[rows, 1]]
        elif oc == "divf":
            r = a0 / M[args[rows, 1]]
        elif oc == "sqrtf":
            r = np.sqrt(a0)
        elif oc == "maxf":
            r = np.maximum(a0, M[args[rows, 1]])
        elif oc == "minf":
            r = np.minimum(a0, M[args[rows, 1]])
        elif oc == "negf":
            r = -a0
        elif oc == "relu":
            r = np.maximum(a0, 0.0)
        elif oc == "fmac":
            # fmac(b, c, a) = b*c + a, rounded once (fused on FPGA)
            r = a0 * M[args[rows, 1]] + M[args[rows, 2]]
        elif oc == "cmpugt":
            r = (a0 > M[args[rows, 1]]).astype(np.float32)
        elif oc == "select":
            r = np.where(a0 > 0.5, M[args[rows, 1]], M[args[rows, 2]])
        elif oc in ("load", "store", "copy"):
            r = a0
        else:  # pragma: no cover
            raise NotImplementedError(oc)
        if oc not in ("cmpugt", "load", "store", "copy"):
            r = q(r)
        rmask = res[rows] >= 0
        if rmask.all():
            M[res[rows]] = r
        elif rmask.any():
            M[res[rows][rmask]] = r[rmask]

    return _assemble_outputs(g, batch, M.__getitem__)


# ---------------------------------------------------------------------------
# SIMD emission: the TPU rendering of the fully scheduled design
# ---------------------------------------------------------------------------

#: valid values for the ``backend=`` of :func:`to_jax_fn` (and the emission
#: half of ``Design.serve``): the SIMD interpretation vs the Pallas-native
#: compiled rendering
EMIT_BACKENDS = ("simd", "pallas")


def to_jax_fn(g: Graph, *, backend: str = "simd", **pallas_kw
              ) -> Callable[[dict[str, "np.ndarray"]], dict[str, "np.ndarray"]]:
    """Emit a jittable function that exactly evaluates the DFG.

    ``backend='simd'`` (default): the DFG is levelised (ASAP with unit
    delays); each (level, opcode) group becomes one gather -> vector op ->
    scatter.  This is the SIMD analogue of RTL emission: every op executes
    at its scheduled level, with no dynamic control flow — the XLA program
    is the FSM.

    ``backend='pallas'``: contiguous runs of levelised groups are fused
    into compiled kernels instead of interpreted — see
    :func:`repro.core.emit_pallas.to_pallas_fn`, which also accepts
    ``module=`` for the nest-pattern fast path (extra keywords are
    forwarded).  The returned callable carries its lowering ``.plan``.
    """
    if backend not in EMIT_BACKENDS:
        raise ValueError(f"unknown emission backend {backend!r} "
                         f"(valid: {', '.join(EMIT_BACKENDS)})")
    if backend == "pallas":
        from repro.core.emit_pallas import to_pallas_fn
        return to_pallas_fn(g, **pallas_kw)
    if pallas_kw:
        raise TypeError(f"backend='simd' takes no extra keywords, got "
                        f"{sorted(pallas_kw)}")
    import jax
    import jax.numpy as jnp

    c = g.cols()
    compiled_groups = [(oc, arg_idx, res_idx) for _lv, oc, arg_idx, res_idx
                       in compile_groups(c, g.n_values)]
    const_idx, const_val, input_scatter, output_gather = io_tables(g)
    n_values = g.n_values

    def run(feeds: dict[str, jax.Array]) -> dict[str, jax.Array]:
        # batch = leading axis of the first *batched* feed (mirrors
        # ``evaluate``): unbatched feeds — typically weights — broadcast
        batch = 1
        for name in input_scatter:
            rank = len(next(iter(g.inputs[name])))
            shp = jnp.shape(feeds[name])
            if len(shp) == rank + 1:
                batch = shp[0]
                break
        buf = jnp.zeros((batch, n_values), dtype=jnp.float32)
        buf = buf.at[:, const_idx].set(const_val[None, :])
        for name, (vids, idxs) in input_scatter.items():
            arr = jnp.asarray(feeds[name], dtype=jnp.float32)
            if arr.ndim == len(idxs[0]):
                arr = arr[None]
            flat = jnp.stack([arr[(slice(None),) + i] for i in idxs], axis=1)
            buf = buf.at[:, vids].set(flat)
        for oc, arg_idx, res_idx in compiled_groups:
            a = [buf[:, ai] for ai in arg_idx]
            if oc == "mulf":
                r = a[0] * a[1]
            elif oc == "addf":
                r = a[0] + a[1]
            elif oc == "subf":
                r = a[0] - a[1]
            elif oc == "divf":
                r = a[0] / a[1]
            elif oc == "sqrtf":
                r = jnp.sqrt(a[0])
            elif oc == "maxf":
                r = jnp.maximum(a[0], a[1])
            elif oc == "minf":
                r = jnp.minimum(a[0], a[1])
            elif oc == "negf":
                r = -a[0]
            elif oc == "relu":
                r = jnp.maximum(a[0], 0.0)
            elif oc == "fmac":
                r = a[0] * a[1] + a[2]
            elif oc == "cmpugt":
                r = (a[0] > a[1]).astype(jnp.float32)
            elif oc == "select":
                r = jnp.where(a[0] > 0.5, a[1], a[2])
            elif oc in ("load", "store", "copy"):
                r = a[0]
            else:  # pragma: no cover
                raise NotImplementedError(oc)
            buf = buf.at[:, res_idx].set(r)
        outs = {}
        for name, (vids, shape) in output_gather.items():
            outs[name] = buf[:, vids].reshape((batch,) + shape)
        return outs

    return run
