"""SSA dataflow-graph IR for the OpenHLS compiler.

The unit of representation is the *fully unrolled, scalar* dataflow graph
(DFG) of a DNN, exactly as recovered by symbolic interpretation of the
scf-dialect loop nests (paper §3.1).  Values are dense integer ids; ops are
flat records.  After interpretation with store-load forwarding there are no
load/store ops left — only arithmetic ops, graph inputs (hoisted weights and
activations), and graph outputs (final contents of output memrefs).

A second, optional mode (``forward=False`` in the interpreter) keeps explicit
``load``/``store`` ops with memory-port resource constraints.  That mode
models a conventional HLS tool that cannot forward through memory (the
paper's Vitis HLS baseline, §4.1) and is used by the Fig. 4 benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Sequence

# ---------------------------------------------------------------------------
# Opcodes
# ---------------------------------------------------------------------------

#: Floating-point arithmetic ops (bind to DSP-like units on FPGA; MXU/VPU
#: lanes on TPU).  Delay table below gives FloPoCo-ish pipeline depths in
#: cycles at the paper's 10 ns target clock.
ARITH_OPS = frozenset({
    "mulf", "addf", "subf", "divf", "sqrtf", "maxf", "minf", "negf",
    "relu", "fmac", "expf", "cmpugt", "select", "copy",
})

#: Memory ops — only present when store-load forwarding is disabled.
MEM_OPS = frozenset({"load", "store"})

#: Structural pseudo-ops.
META_OPS = frozenset({"input", "const", "output"})

ALL_OPS = ARITH_OPS | MEM_OPS | META_OPS

#: Pipeline depth (cycles @ 10 ns) per op.  Calibrated against FloPoCo
#: (5,11)/(5,4) core latencies reported in the FloPoCo literature and tuned
#: so that the scheduled BraggNN(s=1) lands in the neighbourhood of the
#: paper's 1238-interval design (EXPERIMENTS.md §Paper-claims).
DEFAULT_DELAYS: dict[str, int] = {
    "mulf": 2,
    "addf": 3,
    "subf": 3,
    "fmac": 4,      # fused multiply-accumulate (paper §3.2 "Remove MACs")
    "divf": 12,
    "sqrtf": 12,
    "maxf": 1,
    "minf": 1,
    "negf": 0,      # sign-flip is free in FloPoCo encoding (paper §3)
    "relu": 0,      # combinational: mux on sign bit
    "expf": 0,      # never scheduled directly: expanded into Taylor series
    "cmpugt": 1,
    "select": 0,
    "copy": 0,
    "load": 1,
    "store": 1,
    "input": 0,
    "const": 0,
    "output": 0,
}

#: Resource class each opcode binds to.  ``None`` means unconstrained
#: (combinational / free).  The paper binds mulf and addf to separate DSP
#: instantiations ("2 K_i DSPs, assuming mulf, addf bind to one DSP each").
RESOURCE_CLASS: dict[str, Optional[str]] = {
    "mulf": "mul",
    "addf": "add",
    "subf": "add",
    "fmac": "mac",
    "divf": "div",
    "sqrtf": "sqrt",
    "maxf": "cmp",
    "minf": "cmp",
    "cmpugt": "cmp",
    "negf": None,
    "relu": None,
    "select": None,
    "copy": None,
    "expf": None,
    "load": "port",   # memory ports are per-array resources
    "store": "port",
    "input": None,
    "const": None,
    "output": None,
}


@dataclasses.dataclass(slots=True)
class Op:
    """One node of the DFG.

    idx:      position in program (interpretation) order — the linear order
              used to serialise same-resource operations (paper §3.3).
    opcode:   one of ALL_OPS.
    args:     operand value ids.
    result:   result value id (-1 for store/output).
    nest:     id of the originating loop nest (one per DNN operation).
    rank:     linear index of this op's parallel-iteration instance within
              its nest's parallel iteration space (the "j" in the paper's
              resource indexing), or -1 when not inside an scf.parallel.
    array:    for load/store: name of the memref accessed (port binding).
    """

    idx: int
    opcode: str
    args: tuple[int, ...]
    result: int
    nest: int = -1
    rank: int = -1
    array: str = ""


class Graph:
    """Flat SSA DFG plus interface metadata."""

    def __init__(self) -> None:
        self.ops: list[Op] = []
        self.n_values: int = 0
        # value id -> producing op index (-1 for inputs/consts)
        self.producer: list[int] = []
        # interface: memref name -> {index tuple -> value id}
        self.inputs: dict[str, dict[tuple[int, ...], int]] = {}
        self.outputs: dict[str, dict[tuple[int, ...], int]] = {}
        # value id -> python float for constants
        self.consts: dict[int, float] = {}
        # nest id -> size of its parallel iteration space (K_i, paper §3.3)
        self.nest_parallel_space: dict[int, int] = {}
        # nest id -> human-readable label (e.g. "conv2d_0")
        self.nest_labels: dict[int, str] = {}
        # subset of input memref names that are weights ("hoisted globals",
        # paper §3.2): exposed at the module interface like any input, but
        # bound to trained constants at deployment time.
        self.weight_names: set[str] = set()

    # -- construction -------------------------------------------------------

    def new_value(self) -> int:
        vid = self.n_values
        self.n_values += 1
        self.producer.append(-1)
        return vid

    def add_op(
        self,
        opcode: str,
        args: Sequence[int],
        *,
        nest: int = -1,
        rank: int = -1,
        array: str = "",
        result: Optional[int] = None,
    ) -> int:
        """Append an op; returns its result value id (or -1)."""
        assert opcode in ALL_OPS, opcode
        if result is None:
            result = -1 if opcode in ("store", "output") else self.new_value()
        op = Op(len(self.ops), opcode, tuple(args), result, nest, rank, array)
        self.ops.append(op)
        if result >= 0:
            self.producer[result] = op.idx
        return result

    def add_const(self, value: float) -> int:
        vid = self.new_value()
        self.consts[vid] = float(value)
        return vid

    # -- queries ------------------------------------------------------------

    def num_arith_ops(self) -> int:
        return sum(1 for op in self.ops if op.opcode in ARITH_OPS)

    def op_histogram(self) -> dict[str, int]:
        hist: dict[str, int] = {}
        for op in self.ops:
            hist[op.opcode] = hist.get(op.opcode, 0) + 1
        return hist

    def use_counts(self) -> list[int]:
        uses = [0] * self.n_values
        for op in self.ops:
            for a in op.args:
                uses[a] += 1
        for table in self.outputs.values():
            for vid in table.values():
                uses[vid] += 1
        return uses

    def K(self) -> int:
        """Peak resource replication: K = max_i K_i (paper §3.3)."""
        if not self.nest_parallel_space:
            return 1
        return max(self.nest_parallel_space.values())

    def output_values(self) -> list[int]:
        out: list[int] = []
        for table in self.outputs.values():
            out.extend(table.values())
        return out

    def input_values(self) -> list[int]:
        out: list[int] = []
        for table in self.inputs.values():
            out.extend(table.values())
        return out

    # -- rewriting ----------------------------------------------------------

    def rewrite(self, live_ops: Iterable[Op]) -> "Graph":
        """Rebuild a graph from a subset/sequence of (possibly new) ops.

        ``live_ops`` must be topologically ordered.  Value ids are preserved
        (the new graph keeps the same value-id space), which keeps interface
        tables valid.  Producer indices are recomputed.
        """
        g = Graph()
        g.n_values = self.n_values
        g.producer = [-1] * self.n_values
        g.inputs = {k: dict(v) for k, v in self.inputs.items()}
        g.outputs = {k: dict(v) for k, v in self.outputs.items()}
        g.consts = dict(self.consts)
        g.nest_parallel_space = dict(self.nest_parallel_space)
        g.nest_labels = dict(self.nest_labels)
        g.weight_names = set(self.weight_names)
        for op in live_ops:
            new = Op(len(g.ops), op.opcode, op.args, op.result, op.nest,
                     op.rank, op.array)
            g.ops.append(new)
            if new.result >= 0:
                g.producer[new.result] = new.idx
        return g

    def topo_check(self) -> None:
        """Assert program order is a valid topological order (SSA def-before-use)."""
        defined = [False] * self.n_values
        for vid in self.consts:
            defined[vid] = True
        for table in self.inputs.values():
            for vid in table.values():
                defined[vid] = True
        for op in self.ops:
            for a in op.args:
                if not defined[a]:
                    raise ValueError(
                        f"op {op.idx} ({op.opcode}) uses undefined value {a}")
            if op.result >= 0:
                defined[op.result] = True
        for name, table in self.outputs.items():
            for vid in table.values():
                if not defined[vid]:
                    raise ValueError(f"output {name} reads undefined value {vid}")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        h = self.op_histogram()
        return (f"Graph(ops={len(self.ops)}, values={self.n_values}, "
                f"K={self.K()}, hist={h})")


def iter_edges(g: Graph) -> Iterator[tuple[int, int]]:
    """Yield (producer_op_idx, consumer_op_idx) data-dependence edges."""
    for op in g.ops:
        for a in op.args:
            p = g.producer[a]
            if p >= 0:
                yield (p, op.idx)
