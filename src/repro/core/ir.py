"""SSA dataflow-graph IR for the OpenHLS compiler — struct-of-arrays layout.

The unit of representation is the *fully unrolled, scalar* dataflow graph
(DFG) of a DNN, exactly as recovered by symbolic interpretation of the
scf-dialect loop nests (paper §3.1).  Values are dense integer ids; ops are
flat records.  After interpretation with store-load forwarding there are no
load/store ops left — only arithmetic ops, graph inputs (hoisted weights and
activations), and graph outputs (final contents of output memrefs).

A second, optional mode (``forward=False`` in the interpreter) keeps explicit
``load``/``store`` ops with memory-port resource constraints.  That mode
models a conventional HLS tool that cannot forward through memory (the
paper's Vitis HLS baseline, §4.1) and is used by the Fig. 4 benchmark.

Storage layout
--------------
Unrolled graphs run to hundreds of thousands of ops, so the hot path —
tracing, the pass pipeline, scheduling, emission — operates on dense
*struct-of-arrays* columns rather than a Python list of ``Op`` objects.
A graph holds its op table in one of two interconvertible forms:

  * build form: one plain-``int`` Python list per column.  ``list.append``
    is the cheapest way to grow from the interpreter — the trace-time fast
    path — and no ``Op`` object is ever constructed.
  * sealed form: contiguous numpy ``int32`` arrays (``Graph.cols()``) that
    every pass/scheduler consumes with vectorised operations.  ``args`` is
    a packed ``(n, 3)`` matrix padded with ``-1`` (no opcode takes more than
    three operands); memref names are interned into ``array_names`` and
    stored as integer ids.  Pass outputs are built directly in this form
    via :meth:`Graph.from_columns` — no per-op rewriting.

``Graph.ops`` remains available as a sequence view that materialises ``Op``
records on demand — the compatibility surface for tests, benchmarks, and the
legacy object-graph implementations (``repro.core.legacy``).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Opcodes
# ---------------------------------------------------------------------------

#: Floating-point arithmetic ops (bind to DSP-like units on FPGA; MXU/VPU
#: lanes on TPU).  Delay table below gives FloPoCo-ish pipeline depths in
#: cycles at the paper's 10 ns target clock.
ARITH_OPS = frozenset({
    "mulf", "addf", "subf", "divf", "sqrtf", "maxf", "minf", "negf",
    "relu", "fmac", "expf", "cmpugt", "select", "copy",
})

#: Memory ops — only present when store-load forwarding is disabled.
MEM_OPS = frozenset({"load", "store"})

#: Structural pseudo-ops.
META_OPS = frozenset({"input", "const", "output"})

ALL_OPS = ARITH_OPS | MEM_OPS | META_OPS

#: Stable opcode numbering for the integer ``opcode`` column.  Appending is
#: fine; reordering is a cache-format change (``CACHE_FORMAT_VERSION``).
OPCODES: tuple[str, ...] = (
    "mulf", "addf", "subf", "divf", "sqrtf", "maxf", "minf", "negf",
    "relu", "fmac", "expf", "cmpugt", "select", "copy",
    "load", "store", "input", "const", "output",
)
OPCODE_ID: dict[str, int] = {name: i for i, name in enumerate(OPCODES)}
N_OPCODES = len(OPCODES)
MAX_ARGS = 3

#: Build-form chunk size: every this-many appended ops, the tail lists are
#: frozen into dense ``int32`` chunk arrays (:meth:`Graph._flush_chunk`), so
#: a multi-million-op trace holds at most one chunk of boxed Python ints at
#: a time (~8 MB of arrays per 64k ops vs ~28 bytes per boxed int per
#: column) and ``cols()`` concatenates arrays instead of converting giant
#: lists.
TRACE_CHUNK = 1 << 16

#: Pipeline depth (cycles @ 10 ns) per op.  Calibrated against FloPoCo
#: (5,11)/(5,4) core latencies reported in the FloPoCo literature and tuned
#: so that the scheduled BraggNN(s=1) lands in the neighbourhood of the
#: paper's 1238-interval design (EXPERIMENTS.md §Paper-claims).
DEFAULT_DELAYS: dict[str, int] = {
    "mulf": 2,
    "addf": 3,
    "subf": 3,
    "fmac": 4,      # fused multiply-accumulate (paper §3.2 "Remove MACs")
    "divf": 12,
    "sqrtf": 12,
    "maxf": 1,
    "minf": 1,
    "negf": 0,      # sign-flip is free in FloPoCo encoding (paper §3)
    "relu": 0,      # combinational: mux on sign bit
    "expf": 0,      # never scheduled directly: expanded into Taylor series
    "cmpugt": 1,
    "select": 0,
    "copy": 0,
    "load": 1,
    "store": 1,
    "input": 0,
    "const": 0,
    "output": 0,
}

#: Resource class each opcode binds to.  ``None`` means unconstrained
#: (combinational / free).  The paper binds mulf and addf to separate DSP
#: instantiations ("2 K_i DSPs, assuming mulf, addf bind to one DSP each").
RESOURCE_CLASS: dict[str, Optional[str]] = {
    "mulf": "mul",
    "addf": "add",
    "subf": "add",
    "fmac": "mac",
    "divf": "div",
    "sqrtf": "sqrt",
    "maxf": "cmp",
    "minf": "cmp",
    "cmpugt": "cmp",
    "negf": None,
    "relu": None,
    "select": None,
    "copy": None,
    "expf": None,
    "load": "port",   # memory ports are per-array resources
    "store": "port",
    "input": None,
    "const": None,
    "output": None,
}

#: Resource-class numbering for the vectorised scheduler.  Class 0 is the
#: "unconstrained" pseudo-class (RESOURCE_CLASS is None).
RESOURCE_CLASSES: tuple[str, ...] = (
    "", "mul", "add", "mac", "div", "sqrt", "cmp", "port")
RESOURCE_CLASS_ID: dict[str, int] = {
    name: i for i, name in enumerate(RESOURCE_CLASSES)}
PORT_CLASS_ID = RESOURCE_CLASS_ID["port"]

# Dense per-opcode-id lookup tables shared by the vectorised passes and
# scheduler (index with an ``opcode`` column).
ARITH_MASK = np.array([name in ARITH_OPS for name in OPCODES], dtype=bool)
DELAY_TABLE = np.array([DEFAULT_DELAYS[name] for name in OPCODES],
                       dtype=np.int64)
CLASS_TABLE = np.array(
    [RESOURCE_CLASS_ID[RESOURCE_CLASS[name] or ""] for name in OPCODES],
    dtype=np.int64)

# Hot opcode ids for the pattern passes.
ID_MULF = OPCODE_ID["mulf"]
ID_ADDF = OPCODE_ID["addf"]
ID_MAXF = OPCODE_ID["maxf"]
ID_MINF = OPCODE_ID["minf"]
ID_RELU = OPCODE_ID["relu"]
ID_FMAC = OPCODE_ID["fmac"]
ID_CMPUGT = OPCODE_ID["cmpugt"]
ID_SELECT = OPCODE_ID["select"]
ID_STORE = OPCODE_ID["store"]


def delay_table(delays: Optional[dict[str, int]]) -> np.ndarray:
    """Per-opcode-id delay lookup array for a (possibly custom) delay map."""
    if delays is None or delays is DEFAULT_DELAYS:
        return DELAY_TABLE
    return np.array([delays.get(name, 0) for name in OPCODES], dtype=np.int64)


@dataclasses.dataclass(slots=True)
class Op:
    """One node of the DFG (the record view of one SoA row).

    idx:      position in program (interpretation) order — the linear order
              used to serialise same-resource operations (paper §3.3).
    opcode:   one of ALL_OPS.
    args:     operand value ids.
    result:   result value id (-1 for store/output).
    nest:     id of the originating loop nest (one per DNN operation).
    rank:     linear index of this op's parallel-iteration instance within
              its nest's parallel iteration space (the "j" in the paper's
              resource indexing), or -1 when not inside an scf.parallel.
    array:    for load/store: name of the memref accessed (port binding).
    """

    idx: int
    opcode: str
    args: tuple[int, ...]
    result: int
    nest: int = -1
    rank: int = -1
    array: str = ""


@dataclasses.dataclass(frozen=True)
class GraphCols:
    """The sealed struct-of-arrays view of a graph's op table.

    All columns are contiguous ``int32`` arrays of length ``n`` (``args`` is
    ``(n, 3)``, padded with -1); ``producer`` has length ``n_values`` and
    maps value id -> producing op row (-1 for inputs/consts).
    """

    opcode: np.ndarray
    args: np.ndarray
    result: np.ndarray
    nest: np.ndarray
    rank: np.ndarray
    array_id: np.ndarray
    producer: np.ndarray

    @property
    def n(self) -> int:
        return len(self.opcode)


def _producer_from(result: np.ndarray, n_values: int) -> np.ndarray:
    producer = np.full(n_values, -1, dtype=np.int32)
    has_res = result >= 0
    producer[result[has_res]] = np.flatnonzero(has_res)
    return producer


class _OpsView(Sequence):
    """Sequence view over the columns, materialising ``Op`` rows on demand.

    The int columns are fetched once per view (and on a sealed graph live
    only as long as the view), so indexed access inside a loop stays O(1)
    without the graph retaining dual storage.
    """

    __slots__ = ("_g", "_cache")

    def __init__(self, g: "Graph"):
        self._g = g
        self._cache: Optional[tuple[list, ...]] = None

    def _lists(self) -> tuple[list, ...]:
        if self._cache is None:
            self._cache = self._g._lists_view()
        return self._cache

    def __len__(self) -> int:
        return self._g.n_ops

    def _make(self, i: int, lists) -> Op:
        g = self._g
        o, a0, a1, a2, r, ne, rk, ai = lists
        if a0[i] < 0:
            args: tuple[int, ...] = ()
        elif a1[i] < 0:
            args = (a0[i],)
        elif a2[i] < 0:
            args = (a0[i], a1[i])
        else:
            args = (a0[i], a1[i], a2[i])
        return Op(i, OPCODES[o[i]], args, r[i], ne[i], rk[i],
                  g.array_names[ai[i]])

    def __getitem__(self, i):
        n = len(self)
        lists = self._lists()
        if isinstance(i, slice):
            return [self._make(j, lists) for j in range(*i.indices(n))]
        i = int(i)
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError(i)
        return self._make(i, lists)

    def __iter__(self) -> Iterator[Op]:
        g = self._g
        names = OPCODES
        arr_names = g.array_names
        o, a0, a1, a2, r, ne, rk, ai = self._lists()
        for i in range(len(o)):
            x0 = a0[i]
            if x0 < 0:
                args: tuple[int, ...] = ()
            else:
                x1 = a1[i]
                if x1 < 0:
                    args = (x0,)
                else:
                    x2 = a2[i]
                    args = (x0, x1) if x2 < 0 else (x0, x1, x2)
            yield Op(i, names[o[i]], args, r[i], ne[i], rk[i],
                     arr_names[ai[i]])


class Graph:
    """Flat SSA DFG plus interface metadata (struct-of-arrays storage)."""

    def __init__(self) -> None:
        # build-form columns: op id, arg0..2 (-1 pad), result, nest, rank,
        # interned array id.  ``None`` when the graph lives in sealed form.
        self._lists: Optional[tuple[list, ...]] = (
            [], [], [], [], [], [], [], [])
        # frozen prefix of the build form: every TRACE_CHUNK appends, the
        # tail lists flush into dense int32 arrays so tracing a multi-
        # million-op graph never holds more than one chunk of boxed ints
        self._chunks: list[tuple[np.ndarray, ...]] = []
        self._cols: Optional[GraphCols] = None
        self._n_ops: int = 0
        # interned memref-name table; id 0 is the empty name
        self.array_names: list[str] = [""]
        self._array_intern: dict[str, int] = {"": 0}
        self.n_values: int = 0
        # interface: memref name -> {index tuple -> value id}
        self.inputs: dict[str, dict[tuple[int, ...], int]] = {}
        self.outputs: dict[str, dict[tuple[int, ...], int]] = {}
        # value id -> python float for constants
        self.consts: dict[int, float] = {}
        # nest id -> size of its parallel iteration space (K_i, paper §3.3)
        self.nest_parallel_space: dict[int, int] = {}
        # nest id -> human-readable label (e.g. "conv2d_0")
        self.nest_labels: dict[int, str] = {}
        # subset of input memref names that are weights ("hoisted globals",
        # paper §3.2): exposed at the module interface like any input, but
        # bound to trained constants at deployment time.
        self.weight_names: set[str] = set()

    # -- storage ------------------------------------------------------------

    @property
    def n_ops(self) -> int:
        return self._n_ops

    @property
    def ops(self) -> _OpsView:
        return _OpsView(self)

    @property
    def producer(self) -> np.ndarray:
        """Value id -> producing op row (-1 for inputs/consts)."""
        return self.cols().producer

    def _thaw(self) -> tuple[list, ...]:
        c = self._cols
        return (c.opcode.tolist(), c.args[:, 0].tolist(),
                c.args[:, 1].tolist(), c.args[:, 2].tolist(),
                c.result.tolist(), c.nest.tolist(), c.rank.tolist(),
                c.array_id.tolist())

    def _flush_chunk(self) -> None:
        """Freeze the current build-list tail into int32 chunk arrays.

        The lists are cleared *in place* — ``Context._emit`` holds direct
        references to the list objects within a call.
        """
        lists = self._lists
        self._chunks.append(tuple(np.asarray(col, dtype=np.int32)
                                  for col in lists))
        for col in lists:
            col.clear()

    def _merge_chunks(self) -> None:
        """Fold frozen chunks back into the build lists (rare: list-form
        mutation of a mid-trace graph)."""
        if not self._chunks:
            return
        for k, col in enumerate(self._lists):
            head = np.concatenate(
                [ch[k] for ch in self._chunks]).tolist()
            head.extend(col)
            col[:] = head  # in place: _emit may hold references
        self._chunks = []

    def _mutable_lists(self) -> tuple[list, ...]:
        """The build-form columns, thawing from sealed form if needed.

        For *mutation* only: the thawed lists are installed as the graph's
        storage (the caller invalidates ``_cols`` after appending).
        """
        if self._lists is None:
            self._lists = self._thaw()
        else:
            self._merge_chunks()
        return self._lists

    def _lists_view(self) -> tuple[list, ...]:
        """Indexable int columns for the ``Op`` view.

        Read-only: a sealed graph thaws a *transient* copy that the view
        caches for its own lifetime — the graph keeps single (array)
        storage, so big cached designs don't retain boxed-int columns after
        someone iterates ``g.ops`` once.  A mid-trace chunked graph likewise
        merges into a transient copy, leaving the chunk storage intact.
        """
        if self._lists is None:
            return self._thaw()
        if self._chunks:
            merged = []
            for k, col in enumerate(self._lists):
                head = np.concatenate(
                    [ch[k] for ch in self._chunks]).tolist()
                head.extend(col)
                merged.append(head)
            return tuple(merged)
        return self._lists

    def cols(self) -> GraphCols:
        """Seal and return the dense column arrays (cached until mutation)."""
        if self._cols is None:
            if self._chunks:
                tail = tuple(np.asarray(col, dtype=np.int32)
                             for col in self._lists)
                o, a0, a1, a2, r, ne, rk, ai = (
                    np.concatenate([ch[k] for ch in self._chunks]
                                   + [tail[k]])
                    for k in range(len(tail)))
                self._chunks = []
            else:
                o, a0, a1, a2, r, ne, rk, ai = (
                    np.asarray(col, dtype=np.int32) for col in self._lists)
            opcode = o
            args = np.empty((len(opcode), MAX_ARGS), dtype=np.int32)
            args[:, 0] = a0
            args[:, 1] = a1
            args[:, 2] = a2
            result = r
            self._cols = GraphCols(
                opcode=opcode, args=args, result=result,
                nest=ne, rank=rk, array_id=ai,
                producer=_producer_from(result, self.n_values))
            # sealed graphs drop the build lists (thawed back on demand by
            # the Op view or a later add_op) — no dual storage for the big
            # raw/optimised graphs that live inside CompiledDesign
            self._lists = None
        return self._cols

    def intern_array(self, name: str) -> int:
        aid = self._array_intern.get(name)
        if aid is None:
            aid = len(self.array_names)
            self.array_names.append(name)
            self._array_intern[name] = aid
        return aid

    def _copy_meta(self, src: "Graph") -> None:
        """Deep-copy interface metadata from ``src`` (value-id space shared)."""
        self.n_values = src.n_values
        self.inputs = {k: dict(v) for k, v in src.inputs.items()}
        self.outputs = {k: dict(v) for k, v in src.outputs.items()}
        self.consts = dict(src.consts)
        self.nest_parallel_space = dict(src.nest_parallel_space)
        self.nest_labels = dict(src.nest_labels)
        self.weight_names = set(src.weight_names)
        self.array_names = list(src.array_names)
        self._array_intern = dict(src._array_intern)

    @classmethod
    def from_columns(cls, src: "Graph", opcode: np.ndarray, args: np.ndarray,
                     result: np.ndarray, nest: np.ndarray, rank: np.ndarray,
                     array_id: np.ndarray, *,
                     n_values: Optional[int] = None) -> "Graph":
        """Build a rewritten graph directly from column arrays.

        Interface metadata is copied from ``src``; the value-id space is
        preserved (``n_values`` may extend it, e.g. for reduction trees).
        This is the bulk constructor every vectorised pass uses in place of
        per-op ``Rewriter`` churn — the graph is born in sealed form and
        never materialises ``Op`` objects unless a consumer asks.
        """
        g = cls()
        g._copy_meta(src)
        if n_values is not None:
            g.n_values = n_values
        opcode = np.ascontiguousarray(opcode, dtype=np.int32)
        args = np.ascontiguousarray(args, dtype=np.int32)
        result = np.ascontiguousarray(result, dtype=np.int32)
        g._lists = None
        g._n_ops = len(opcode)
        g._cols = GraphCols(
            opcode=opcode, args=args, result=result,
            nest=np.ascontiguousarray(nest, dtype=np.int32),
            rank=np.ascontiguousarray(rank, dtype=np.int32),
            array_id=np.ascontiguousarray(array_id, dtype=np.int32),
            producer=_producer_from(result, g.n_values))
        return g

    # -- construction -------------------------------------------------------

    def new_value(self) -> int:
        vid = self.n_values
        self.n_values += 1
        if self._cols is not None:
            self._mutable_lists()   # keep the op table before invalidating
            self._cols = None       # producer array length depends on n_values
        return vid

    def add_op(
        self,
        opcode: str,
        args: Sequence[int],
        *,
        nest: int = -1,
        rank: int = -1,
        array: str = "",
        result: Optional[int] = None,
    ) -> int:
        """Append an op; returns its result value id (or -1).

        This is the trace-time hot path: eight plain-list appends into the
        preallocated column buffers, no ``Op`` object construction.
        """
        try:
            opid = OPCODE_ID[opcode]
        except KeyError:
            raise AssertionError(opcode) from None
        if result is None:
            result = -1 if opcode in ("store", "output") else self.new_value()
        o, a0, a1, a2, r, ne, rk, ai = (self._lists if self._lists is not None
                                        else self._mutable_lists())
        n = len(args)
        o.append(opid)
        a0.append(args[0] if n > 0 else -1)
        a1.append(args[1] if n > 1 else -1)
        a2.append(args[2] if n > 2 else -1)
        r.append(result)
        ne.append(nest)
        rk.append(rank)
        ai.append(self.intern_array(array) if array else 0)
        self._n_ops += 1
        self._cols = None
        if len(o) >= TRACE_CHUNK:
            self._flush_chunk()
        return result

    def add_const(self, value: float) -> int:
        vid = self.new_value()
        self.consts[vid] = float(value)
        return vid

    # -- queries ------------------------------------------------------------

    def num_arith_ops(self) -> int:
        if not self._n_ops:
            return 0
        return int(ARITH_MASK[self.cols().opcode].sum())

    def op_histogram(self) -> dict[str, int]:
        if not self._n_ops:
            return {}
        counts = np.bincount(self.cols().opcode, minlength=N_OPCODES)
        return {OPCODES[i]: int(c) for i, c in enumerate(counts) if c}

    def use_counts(self) -> np.ndarray:
        """Per-value use count (args plus interface outputs), int64[n_values]."""
        c = self.cols()
        flat = c.args[c.args >= 0]
        uses = np.bincount(flat, minlength=self.n_values)
        out_vals = self.output_values()
        if out_vals:
            uses = uses + np.bincount(np.asarray(out_vals, dtype=np.int64),
                                      minlength=self.n_values)
        return uses

    def K(self) -> int:
        """Peak resource replication: K = max_i K_i (paper §3.3)."""
        if not self.nest_parallel_space:
            return 1
        return max(self.nest_parallel_space.values())

    def output_values(self) -> list[int]:
        out: list[int] = []
        for table in self.outputs.values():
            out.extend(table.values())
        return out

    def input_values(self) -> list[int]:
        out: list[int] = []
        for table in self.inputs.values():
            out.extend(table.values())
        return out

    # -- rewriting ----------------------------------------------------------

    def rewrite(self, live_ops: Iterable[Op]) -> "Graph":
        """Rebuild a graph from a subset/sequence of (possibly new) ops.

        ``live_ops`` must be topologically ordered.  Value ids are preserved
        (the new graph keeps the same value-id space), which keeps interface
        tables valid.  Producer indices are recomputed.
        """
        g = Graph()
        g._copy_meta(self)
        for op in live_ops:
            g.add_op(op.opcode, op.args, nest=op.nest, rank=op.rank,
                     array=op.array, result=op.result)
        return g

    def topo_check(self) -> None:
        """Assert program order is a valid topological order (SSA def-before-use)."""
        c = self.cols()
        n = c.n
        BIG = n + 1
        defined_at = np.full(max(self.n_values, 1), BIG, dtype=np.int32)
        iface = list(self.consts)
        for table in self.inputs.values():
            iface.extend(table.values())
        if iface:
            defined_at[np.asarray(iface, dtype=np.int64)] = -1
        has_res = c.result >= 0
        ridx = np.flatnonzero(has_res).astype(np.int32)
        # reversed scatter: the earliest definition position wins
        # (redefinition is tolerated, as in the historical per-op check)
        defined_at[c.result[has_res][::-1]] = ridx[::-1]
        # take(mode="clip") maps the -1 arg padding onto slot 0; the `am`
        # mask discards those lanes
        arg_def = defined_at.take(c.args, mode="clip")
        bad = arg_def >= np.arange(n, dtype=np.int32)[:, None]
        bad &= c.args >= 0
        if bad.any():
            i, j = np.argwhere(bad)[0]
            a = int(c.args[i, j])
            raise ValueError(
                f"op {int(i)} ({OPCODES[c.opcode[i]]}) uses undefined "
                f"value {a}")
        for name, table in self.outputs.items():
            for vid in table.values():
                if defined_at[vid] >= BIG:
                    raise ValueError(
                        f"output {name} reads undefined value {vid}")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        h = self.op_histogram()
        return (f"Graph(ops={self.n_ops}, values={self.n_values}, "
                f"K={self.K()}, hist={h})")


def iter_edges(g: Graph) -> Iterator[tuple[int, int]]:
    """Yield (producer_op_idx, consumer_op_idx) data-dependence edges."""
    c = g.cols()
    prod = c.producer
    for i in range(c.n):
        for j in range(MAX_ARGS):
            a = c.args[i, j]
            if a < 0:
                continue
            p = prod[a]
            if p >= 0:
                yield (int(p), i)
