"""Pallas-native emission: compile the scheduled design, don't interpret it.

``emit.to_jax_fn`` renders the levelised DFG as one gather/compute/scatter
per (level, opcode) group — faithful, but *interpretive*: every value
round-trips through a ``(batch, n_values)`` buffer, and on CPU the result
is ~69x slower than the hand-written tensor path (BENCH_2026-07-28.json).
This module is the compiled rendering, with two tiers:

**Nest-pattern tier** (``mode='nests'``) — when the design carries the
``ModuleGraph`` it was bridged from, each node lowers through the kernel
registry (:mod:`repro.kernels.registry`): ``Conv2d`` -> the
weights-in-VMEM conv exemplar, ``Linear`` -> the smallfloat matmul,
``Softmax`` and the NLB attention softmax -> the fused Taylor softmax,
the NLB attention core optionally -> flash attention.  ReLU nodes fuse
into the preceding conv/matmul kernel.  Nodes without a registered kernel
(batch norm, pooling, strided/padded conv) run on the plain tensor path
and are recorded as fallbacks in the :class:`PallasPlan`.

**Generic DFG tier** (``mode='dfg'``) — works for *any* traced design:
the Kahn-wave levelisation and per-(level, opcode) grouping of
``core/emit.py`` (the right unit of fusion since the struct-of-arrays IR)
is partitioned into contiguous runs of kernel-supported groups, and each
run becomes ONE fused kernel: gather indices baked in as static arrays,
compute vectorised per group, and a group's scatter elided entirely when
its result set is consumed exactly through an aligned gather later in the
same segment (the value is forwarded in-register instead).  Groups whose
opcode has no entry in ``registry.OPCODE_KERNELS`` fall back per-group to
the tensor path and are recorded.  With ``fmt`` every group result is
re-quantised — the per-op FloPoCo functional model, bit-matching
``emit.evaluate``.

``use_pallas`` routes segment bodies / registry kernels through real
``pl.pallas_call`` lowerings (interpret mode off-TPU — the CI
``pallas-smoke`` path); the default off-accelerator is the kernels' own
oracle discipline: same lowering, executed as plain XLA.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro import obs
from repro.core import emit
from repro.core.ir import Graph
from repro.core.precision import FORMATS, FloatFormat
from repro.kernels import registry as kreg

#: per-sample flop-free node types the nest tier implements inline without
#: counting them as kernel fallbacks
_TRIVIAL_NODES = ("ReLU", "OutputReLU", "Flatten")


def _on_accelerator() -> bool:
    import jax
    try:
        return jax.devices()[0].platform in ("tpu", "gpu")
    except Exception:  # pragma: no cover - no backend at all
        return False


def _norm_fmt(fmt) -> tuple[Optional[FloatFormat], Optional[str]]:
    """-> (FloatFormat or None, format key or None)."""
    if fmt is None or fmt == "fp32":
        return None, None
    if isinstance(fmt, str):
        return FORMATS[fmt], fmt
    if isinstance(fmt, FloatFormat):
        key = next((k for k, v in FORMATS.items() if v == fmt), None)
        return fmt, key or f"{fmt.exp_bits}_{fmt.man_bits}"
    raise TypeError(f"fmt must be None, a FORMATS key or a FloatFormat, "
                    f"got {type(fmt).__name__}")


@dataclasses.dataclass
class PallasPlan:
    """What the lowering actually did — serving telemetry + test surface."""

    mode: str                                  #: 'nests' | 'dfg'
    use_pallas: bool                           #: real pl.pallas_call bodies?
    interpret: bool                            #: interpret=True off-TPU
    fmt: Optional[str] = None                  #: FloPoCo key, None = fp32
    n_groups: int = 0                          #: levelised groups (dfg tier)
    n_segments: int = 0                        #: fused kernels (dfg tier)
    fused_scatters: int = 0                    #: scatter->gather pairs elided
    kernels: dict = dataclasses.field(default_factory=dict)
    fallbacks: list = dataclasses.field(default_factory=list)
    notes: list = dataclasses.field(default_factory=list)

    def record_kernel(self, name: str) -> None:
        self.kernels[name] = self.kernels.get(name, 0) + 1

    def summary(self) -> str:
        kern = ", ".join(f"{k}x{v}" for k, v in sorted(self.kernels.items()))
        parts = [f"pallas[{self.mode}]"]
        if self.mode == "dfg":
            parts.append(f"{self.n_segments} fused kernels over "
                         f"{self.n_groups} groups "
                         f"({self.fused_scatters} scatters elided)")
        if kern:
            parts.append(kern)
        parts.append(f"{len(self.fallbacks)} fallbacks")
        if not self.use_pallas:
            parts.append("oracle bodies (no accelerator)")
        return "; ".join(parts)


# ---------------------------------------------------------------------------
# Generic tier: fuse levelised op groups into compiled kernels
# ---------------------------------------------------------------------------

def _fallback_compute(oc: str, a: list):
    """The tensor-path rendering of one unkernelled group (mirrors
    ``emit.to_jax_fn``'s op table for the opcodes outside the registry)."""
    import jax.numpy as jnp
    if oc == "cmpugt":
        return (a[0] > a[1]).astype(jnp.float32)
    if oc == "select":
        return jnp.where(a[0] > 0.5, a[1], a[2])
    table = kreg.OPCODE_KERNELS
    if oc in table:
        return table[oc][1](a)
    raise NotImplementedError(oc)  # pragma: no cover


def _plan_segments(groups, output_vids: np.ndarray, opcode_table,
                   plan: PallasPlan):
    """Partition the level-ordered groups into fused segments + fallbacks.

    Returns ``steps``: a list of ``('segment', [(oc, arg_idx, res_idx,
    forward_keys, skip_scatter), ...])`` and ``('fallback', (oc, arg_idx,
    res_idx))`` entries, plus per-group scatter-elision already resolved.
    """
    # consumer bookkeeping: how often each value id is read by later groups,
    # and through which (group, arg-position) gathers
    n_groups = len(groups)
    refs: dict[int, int] = {}
    for _lv, _oc, arg_idx, _res in groups:
        for ai in arg_idx:
            for v in ai:
                refs[int(v)] = refs.get(int(v), 0) + 1
    out_set = set(int(v) for v in output_vids)

    raw_steps: list[tuple[str, Any]] = []
    cur: list[int] = []          # group indices of the open segment
    for gi, (lv, oc, arg_idx, res_idx) in enumerate(groups):
        if oc in opcode_table:
            cur.append(gi)
        else:
            if cur:
                raw_steps.append(("segment", cur))
                cur = []
            raw_steps.append(("fallback", gi))
            plan.fallbacks.append(f"L{lv}:{oc} ({len(res_idx)} ops)")
    if cur:
        raw_steps.append(("segment", cur))

    # scatter elision: a group's scatter is dropped iff its results are not
    # design outputs and every read of them happens through a later gather
    # *in the same segment* whose index array matches bit-for-bit (those
    # gathers are then served from the forwarded register value).
    steps = []
    for kind, payload in raw_steps:
        if kind == "fallback":
            lv, oc, arg_idx, res_idx = groups[payload]
            steps.append(("fallback", (oc, arg_idx, res_idx)))
            continue
        seg_groups = payload
        produced: dict[bytes, int] = {}      # res bytes -> group position
        matched_reads: dict[int, int] = {}   # producer pos -> forwarded reads
        gathers = []                         # per group: arg keys
        for pos, gi in enumerate(seg_groups):
            _lv, oc, arg_idx, res_idx = groups[gi]
            keys = []
            for ai in arg_idx:
                k = ai.tobytes()
                keys.append(k if k in produced else None)
                if k in produced:
                    matched_reads[produced[k]] = \
                        matched_reads.get(produced[k], 0) + len(ai)
            gathers.append(keys)
            produced[res_idx.tobytes()] = pos
        seg = []
        for pos, gi in enumerate(seg_groups):
            _lv, oc, arg_idx, res_idx = groups[gi]
            valid = res_idx >= 0
            total_reads = sum(refs.get(int(v), 0) for v in res_idx[valid])
            is_output = any(int(v) in out_set for v in res_idx[valid])
            skip = (valid.all() and not is_output
                    and matched_reads.get(pos, 0) == total_reads
                    and total_reads > 0)
            if skip:
                plan.fused_scatters += 1
            seg.append((oc, arg_idx, res_idx, gathers[pos], skip))
        steps.append(("segment", seg))
    plan.n_segments = sum(1 for k, _ in steps if k == "segment")
    return steps


def _segment_body(seg, opcode_table, q, n_values: int):
    """One fused segment -> ``(body(buf, idx) -> buf, idx_flat)``.

    The body is shared verbatim between the ``pl.pallas_call`` kernel and
    the oracle (plain XLA) execution — the lowering is identical, only the
    launch differs.  All gather/scatter index arrays of the segment are
    concatenated into ONE static int32 vector (``idx_flat``) addressed by
    compile-time offsets, because a Pallas kernel cannot capture array
    constants — the index vector rides along as a kernel input instead.
    Result slots of ops without a destination are redirected one past the
    buffer and dropped by the scatter.
    """
    layout = []
    chunks: list[np.ndarray] = []
    off = 0
    for (oc, arg_idx, res_idx, keys, skip) in seg:
        spans = []
        for ai in arg_idx:
            spans.append((off, len(ai)))
            chunks.append(ai.astype(np.int32))
            off += len(ai)
        res_full = np.where(res_idx >= 0, res_idx, n_values)
        rspan = (off, len(res_full))
        chunks.append(res_full.astype(np.int32))
        off += len(res_full)
        layout.append((oc, keys, skip, spans, rspan, res_idx.tobytes()))
    idx_flat = (np.concatenate(chunks) if chunks
                else np.zeros(1, np.int32))

    def body(buf, idx):
        fwd: dict[bytes, Any] = {}
        for oc, keys, skip, spans, (ro, rl), rkey in layout:
            a = [fwd[k] if k is not None and k in fwd
                 else buf[:, idx[o:o + l]]
                 for k, (o, l) in zip(keys, spans)]
            r = opcode_table[oc][1](a)
            if q is not None and oc not in kreg.NO_QUANT_OPCODES:
                r = q(r)
            fwd[rkey] = r
            if not skip:
                buf = buf.at[:, idx[ro:ro + rl]].set(r, mode="drop")
        return buf

    return body, idx_flat


def _segment_fn(body, idx_flat: np.ndarray, use_pallas: bool,
                interpret: bool):
    """Launch one fused segment: real ``pl.pallas_call`` or oracle body."""
    import jax.numpy as jnp

    jidx = jnp.asarray(idx_flat)
    if not use_pallas:
        return lambda buf: body(buf, jidx)
    import jax
    from jax.experimental import pallas as pl

    ni = len(idx_flat)

    def kernel(b_ref, i_ref, o_ref):
        o_ref[...] = body(b_ref[...], i_ref[...])

    def launch(buf):
        batch, nv = buf.shape
        bb = 8 if batch % 8 == 0 else 1
        # one grid step owns a block of samples; the whole value buffer is
        # VMEM-resident for the segment's lifetime (the no-BRAM discipline)
        return pl.pallas_call(
            kernel,
            grid=(batch // bb,),
            in_specs=[pl.BlockSpec((bb, nv), lambda i: (i, 0)),
                      pl.BlockSpec((ni,), lambda i: (0,))],
            out_specs=pl.BlockSpec((bb, nv), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((batch, nv), jnp.float32),
            interpret=interpret,
        )(buf, jidx)

    return launch


def _lower_dfg(g: Graph, *, fmt_obj, use_pallas: bool, interpret: bool,
               opcode_table, plan: PallasPlan):
    import jax
    import jax.numpy as jnp
    from repro.core.precision import quantize

    c = g.cols()
    groups = emit.compile_groups(c, g.n_values)
    plan.n_groups = len(groups)
    const_idx, const_val, input_scatter, output_gather = emit.io_tables(g)
    all_out_vids = (np.concatenate([v for v, _ in output_gather.values()])
                    if output_gather else np.zeros(0, np.int32))
    q = (lambda x: quantize(x, fmt_obj)) if fmt_obj is not None else None
    steps = _plan_segments(groups, all_out_vids, opcode_table, plan)

    n_values = g.n_values
    compiled = []
    step_labels = []      # one label per compiled step, for profiling spans
    for kind, payload in steps:
        if kind == "segment":
            body, idx_flat = _segment_body(payload, opcode_table, q,
                                           n_values)
            compiled.append(_segment_fn(body, idx_flat, use_pallas,
                                        interpret))
            step_labels.append(
                f"segment{sum(1 for s in step_labels if 'segment' in s)}"
                f"[{len(payload)} groups]")
        else:
            oc, arg_idx, res_idx = payload
            jargs = [jnp.asarray(ai) for ai in arg_idx]
            jres = jnp.asarray(np.where(res_idx >= 0, res_idx,
                                        n_values).astype(np.int32))

            def fb(buf, oc=oc, jargs=jargs, jres=jres):
                r = _fallback_compute(oc, [buf[:, ja] for ja in jargs])
                if q is not None and oc not in kreg.NO_QUANT_OPCODES:
                    r = q(r)
                return buf.at[:, jres].set(r, mode="drop")

            compiled.append(fb)
            step_labels.append(f"fallback[{oc}]")
    input_rank = {name: len(next(iter(g.inputs[name])))
                  for name in input_scatter}
    cval = q(jnp.asarray(const_val)) if q is not None \
        else jnp.asarray(const_val)

    def _prologue(feeds):
        batch = 1
        for name in input_scatter:
            shp = jnp.shape(feeds[name])
            if len(shp) == input_rank[name] + 1:
                batch = shp[0]
                break
        buf = jnp.zeros((batch, n_values), dtype=jnp.float32)
        buf = buf.at[:, const_idx].set(cval[None, :])
        for name, (vids, idxs) in input_scatter.items():
            arr = jnp.asarray(feeds[name], dtype=jnp.float32)
            if arr.ndim == len(idxs[0]):
                arr = arr[None]
            flat = jnp.stack([arr[(slice(None),) + i] for i in idxs], axis=1)
            if q is not None:
                flat = q(flat)
            buf = buf.at[:, vids].set(flat)
        return buf, batch

    def _epilogue(buf, batch):
        return {name: buf[:, vids].reshape((batch,) + shape)
                for name, (vids, shape) in output_gather.items()}

    def run(feeds):
        buf, batch = _prologue(feeds)
        for step in compiled:
            buf = step(buf)
        return _epilogue(buf, batch)

    def profile(feeds):
        # unjitted twin of ``run``: one span + device sync per fused
        # segment / fallback step, so the per-kernel cost is observable
        buf, batch = _prologue(feeds)
        for label, step in zip(step_labels, compiled):
            with obs.span(f"pallas.{label}", cat="pallas"):
                buf = jax.block_until_ready(step(buf))
        return _epilogue(buf, batch)

    run.profile = profile
    return run


# ---------------------------------------------------------------------------
# Nest-pattern tier: registry kernels per bridged module node
# ---------------------------------------------------------------------------

def _lower_module(module, *, fmt_obj, fmt_tuple, use_pallas: bool,
                  interpret: bool, nlb_flash: bool, plan: PallasPlan):
    import jax.numpy as jnp
    from jax import lax
    from repro.core.precision import quantize
    from repro.nn import graph as nng

    if module.input_shape[0] != 1 and len(module.input_shape) != 2:
        raise ValueError(
            f"nest tier expects a per-sample memref input shape with a "
            f"leading 1 (image models) or a 2-D (L, D) sequence shape, "
            f"got {module.input_shape}; use mode='dfg'")

    conv_e = kreg.for_pattern("Conv2d")
    mm_e = kreg.for_pattern("Linear")
    sm_e = kreg.for_pattern("Softmax")
    fa_e = kreg.for_pattern("NonLocalBlock.attention")
    kw = {"use_pallas": use_pallas, "interpret": interpret}
    q = (lambda x: quantize(x, fmt_obj)) if fmt_obj is not None \
        else (lambda x: x)

    nodes = list(module.nodes)
    weight_names: list[str] = []
    for n in nodes:
        weight_names.extend(n.weight_memrefs())

    steps: list[Callable] = []   # each: (x, w: dict) -> x
    step_labels: list[str] = []  # one per step, for profiling spans
    i = 0
    while i < len(nodes):
        node = nodes[i]
        fuse_relu = (i + 1 < len(nodes)
                     and isinstance(nodes[i + 1],
                                    (nng.ReLU, nng.OutputReLU)))
        if isinstance(node, nng.Conv2d):
            wn, bn = f"{node.prefix}.weight", f"{node.prefix}.bias"
            has_b = node.bias
            if node.stride == 1 and node.padding == 0:
                plan.record_kernel(conv_e.name + (":relu" if fuse_relu
                                                 else ""))

                def step(x, w, wn=wn, bn=bn, has_b=has_b, fr=fuse_relu):
                    return q(conv_e.fn(x, w[wn], w[bn] if has_b else None,
                                       fmt=fmt_tuple, fuse_relu=fr, **kw))
            else:
                plan.fallbacks.append(
                    f"{node.name}: Conv2d(stride={node.stride}, "
                    f"padding={node.padding}) via jnp")

                def step(x, w, wn=wn, bn=bn, has_b=has_b, fr=fuse_relu,
                         node=node):
                    xq, wq = x, w[wn]
                    if fmt_obj is not None:
                        xq, wq = q(xq), q(wq)
                    p = node.padding
                    y = lax.conv_general_dilated(
                        xq, wq, window_strides=(node.stride,) * 2,
                        padding=[(p, p), (p, p)],
                        dimension_numbers=("NCHW", "OIHW", "NCHW"))
                    if has_b:
                        y = y + w[bn][None, :, None, None]
                    if fr:
                        y = jnp.maximum(y, 0.0)
                    return q(y)
        elif isinstance(node, nng.Linear):
            wn, bn = f"{node.prefix}.weight", f"{node.prefix}.bias"
            has_b = node.bias
            eb = fmt_obj.exp_bits if fmt_obj is not None else None
            mb = fmt_obj.man_bits if fmt_obj is not None else None
            plan.record_kernel(mm_e.name + (":relu" if fuse_relu else ""))

            def step(x, w, wn=wn, bn=bn, has_b=has_b, fr=fuse_relu,
                     eb=eb, mb=mb):
                # loop-nest semantics: out = x @ W.T + b
                return q(mm_e.fn(x, w[wn].T, w[bn] if has_b else None,
                                 exp_bits=eb, man_bits=mb, fuse_relu=fr,
                                 **kw))
        elif isinstance(node, nng.Softmax):
            plan.record_kernel(sm_e.name)

            def step(x, w, node=node, fr=fuse_relu):
                y = sm_e.fn(x, taylor_order=node.taylor_order, **kw)
                return jnp.maximum(y, 0.0) if fr else y
        elif isinstance(node, nng.NonLocalBlock):
            steps.append(_nlb_step(node, conv_e, sm_e, fa_e, q, fmt_tuple,
                                   kw, nlb_flash, plan))
            step_labels.append(_node_label(node))
            fuse_relu = False
            i += 1
            continue
        elif isinstance(node, nng.BatchNorm2d):
            plan.fallbacks.append(f"{node.name}: BatchNorm2d via jnp")
            pre = node.prefix

            def step(x, w, pre=pre, node=node, fr=fuse_relu):
                ga, be = w[f"{pre}.gamma"], w[f"{pre}.beta"]
                mu, va = w[f"{pre}.mean"], w[f"{pre}.var"]
                if fmt_obj is not None:
                    x, ga, be = q(x), q(ga), q(be)
                    mu, va = q(mu), q(va)
                den = jnp.sqrt(va + node.eps)
                y = ga[None, :, None, None] \
                    * (x - mu[None, :, None, None]) \
                    / den[None, :, None, None] + be[None, :, None, None]
                if fr:
                    y = jnp.maximum(y, 0.0)
                return q(y)
        elif isinstance(node, nng.MaxPool2d):
            plan.fallbacks.append(f"{node.label}: MaxPool2d via "
                                  f"reduce_window")

            def step(x, w, node=node, fr=fuse_relu):
                y = lax.reduce_window(
                    x, -jnp.inf, lax.max,
                    (1, 1, node.kernel, node.kernel),
                    (1, 1, node.stride, node.stride), "VALID")
                return jnp.maximum(y, 0.0) if fr else y
        elif isinstance(node, nng.RMSNorm):
            plan.fallbacks.append(f"{node.name}: RMSNorm via jnp")
            pre = node.prefix

            def step(x, w, pre=pre, node=node):
                ga = w[f"{pre}.gamma"]
                if fmt_obj is not None:
                    x, ga = q(x), q(ga)
                ms = jnp.sum(x * x, axis=-1, keepdims=True) \
                    * (1.0 / x.shape[-1])
                return q(x * (1.0 / jnp.sqrt(ms + node.eps)) * ga)
            fuse_relu = False
        elif isinstance(node, nng.Attention):
            steps.append(_attention_step(node, mm_e, sm_e, fa_e, q,
                                         fmt_obj, fmt_tuple, kw, nlb_flash,
                                         plan))
            step_labels.append(_node_label(node))
            fuse_relu = False
            i += 1
            continue
        elif isinstance(node, nng.MLP):
            steps.append(_mlp_step(node, mm_e, q, fmt_obj, plan, kw))
            step_labels.append(_node_label(node))
            fuse_relu = False
            i += 1
            continue
        elif isinstance(node, (nng.ReLU, nng.OutputReLU)):
            def step(x, w):
                return jnp.maximum(x, 0.0)
            fuse_relu = False
        elif isinstance(node, nng.Flatten):
            def step(x, w):
                return x.reshape(x.shape[0], -1)
            fuse_relu = False
        else:  # pragma: no cover - ModuleGraph validates the vocabulary
            raise NotImplementedError(type(node).__name__)
        steps.append(step)
        step_labels.append(_node_label(node) + (":relu" if fuse_relu
                                                else ""))
        i += 2 if fuse_relu else 1

    # the output memref is the last allocating node's (OutputReLU rewrites
    # it in place) — mirror hls.bridge.emit_module
    last_alloc = max(j for j, n in enumerate(nodes)
                     if not isinstance(n, nng.OutputReLU))
    out_name = nodes[last_alloc].out_name
    out_shape = module.shapes()[-1]

    def run(x, weights):
        for step in steps:
            x = step(x, weights)
        return {out_name: x.reshape((x.shape[0],) + tuple(out_shape))}

    def profile(x, weights):
        # unjitted twin of ``run``: one span + device sync per registry
        # kernel, so the per-kernel cost is observable
        import jax
        for label, step in zip(step_labels, steps):
            with obs.span(f"pallas.kernel.{label}", cat="pallas"):
                x = jax.block_until_ready(step(x, weights))
        return {out_name: x.reshape((x.shape[0],) + tuple(out_shape))}

    run.profile = profile
    return run, weight_names, out_name


def _node_label(node) -> str:
    return str(getattr(node, "name", None) or getattr(node, "label", None)
               or type(node).__name__)


def _nlb_step(node, conv_e, sm_e, fa_e, q, fmt_tuple, kw, nlb_flash: bool,
              plan: PallasPlan):
    """The NonLocalBlock composite: three 1x1 convs -> attention ->
    out-projection -> residual, every stage through a registry kernel."""
    import jax.numpy as jnp

    pre = node.prefix
    use_flash = nlb_flash and fmt_tuple is None
    plan.record_kernel(conv_e.name)          # theta/phi/g (batched 1x1)
    if use_flash:
        plan.record_kernel(fa_e.name)
        plan.notes.append(
            f"{node.name}: flash-attention throughput mode — true-exp "
            f"softmax, not the order-{node.taylor_order} Taylor model")
    else:
        plan.record_kernel(sm_e.name)

    def step(x, w):
        b, c1, h, _ = x.shape
        n = h * h
        theta = q(conv_e.fn(x, w[f"{pre}.theta.weight"], None,
                            fmt=fmt_tuple, **kw))
        phi = q(conv_e.fn(x, w[f"{pre}.phi.weight"], None,
                          fmt=fmt_tuple, **kw))
        g = q(conv_e.fn(x, w[f"{pre}.g.weight"], None,
                        fmt=fmt_tuple, **kw))
        c2 = theta.shape[1]
        tf = theta.reshape(b, c2, n)
        pf = phi.reshape(b, c2, n)
        gf = g.reshape(b, c2, n)
        if use_flash:
            # A = softmax(theta^T phi) — flash divides logits by sqrt(D),
            # so pre-scale q to keep the DFG's unscaled scores
            qv = (tf * jnp.sqrt(jnp.float32(c2))).transpose(0, 2, 1)
            kv = pf.transpose(0, 2, 1)
            vv = gf.transpose(0, 2, 1)
            y = fa_e.fn(qv[:, :, None, :], kv[:, :, None, :],
                        vv[:, :, None, :], causal=False, **kw)
            yc = q(y[:, :, 0, :].transpose(0, 2, 1))         # (B, c2, n)
        else:
            scores = q(jnp.einsum("bci,bcj->bij", tf, pf))
            attn = sm_e.fn(scores, taylor_order=node.taylor_order, **kw)
            yc = q(jnp.einsum("bij,bcj->bci", attn, gf))
        y4 = yc.reshape(b, c2, h, h)
        z = q(conv_e.fn(y4, w[f"{pre}.out_cnn.weight"], None,
                        fmt=fmt_tuple, **kw))
        return q(x + z)

    return step


def _rms_jnp(x, gamma, eps, q):
    import jax.numpy as jnp
    ms = jnp.sum(x * x, axis=-1, keepdims=True) * (1.0 / x.shape[-1])
    return q(x * (1.0 / jnp.sqrt(ms + eps)) * gamma)


def _attention_step(node, mm_e, sm_e, fa_e, q, fmt_obj, fmt_tuple, kw,
                    flash: bool, plan: PallasPlan):
    """The Attention composite: optional pre-norm -> q/k/v projections
    (matmul kernel) -> scaled scores -> softmax (Taylor kernel, or flash
    attention in throughput mode) -> mix -> out-projection -> residual."""
    import jax.numpy as jnp

    pre = node.prefix
    h, dh = node.n_heads, node.head_dim
    eb = fmt_obj.exp_bits if fmt_obj is not None else None
    mb = fmt_obj.man_bits if fmt_obj is not None else None
    use_flash = flash and fmt_tuple is None
    plan.record_kernel(mm_e.name)            # q/k/v and out projections
    if use_flash:
        plan.record_kernel(fa_e.name)
        plan.notes.append(
            f"{node.name}: flash-attention throughput mode — true-exp "
            f"softmax, not the order-{node.taylor_order} Taylor model")
    else:
        plan.record_kernel(sm_e.name)

    def step(x, w):
        b, l, d = x.shape
        src = x
        if node.pre_norm:
            ga = w[f"{pre}.norm.gamma"]
            if fmt_obj is not None:
                src, ga = q(src), q(ga)
            src = _rms_jnp(src, ga, node.eps, q)
        x2 = src.reshape(b * l, d)

        def proj(nm):                        # (B*L, D) @ (D, H*dh)
            wk_ = w[f"{pre}.{nm}.kernel"].reshape(d, h * dh)
            y = mm_e.fn(x2, wk_, None, exp_bits=eb, man_bits=mb, **kw)
            return q(y).reshape(b, l, h, dh)

        qh, kh, vh = proj("q"), proj("k"), proj("v")
        if use_flash:
            # flash divides logits by sqrt(dh) — exactly the DFG's scale
            y = fa_e.fn(qh, kh, vh, causal=False, **kw)
        else:
            scores = q(jnp.einsum("bshk,bthk->bhst", qh, kh)
                       * (1.0 / jnp.sqrt(jnp.float32(dh))))
            attn = sm_e.fn(scores, taylor_order=node.taylor_order, **kw)
            y = q(jnp.einsum("bhst,bthk->bshk", attn, vh))
        wo = w[f"{pre}.o.kernel"].reshape(h * dh, d)
        z = q(mm_e.fn(y.reshape(b * l, h * dh), wo, None,
                      exp_bits=eb, man_bits=mb, **kw)).reshape(b, l, d)
        return q(x + z) if node.residual else z

    return step


def _mlp_step(node, mm_e, q, fmt_obj, plan: PallasPlan, kw):
    """The MLP composite: optional pre-norm -> fc1+ReLU -> fc2 -> residual,
    both matmuls through the smallfloat kernel (ReLU fused into fc1)."""
    pre = node.prefix
    eb = fmt_obj.exp_bits if fmt_obj is not None else None
    mb = fmt_obj.man_bits if fmt_obj is not None else None
    plan.record_kernel(mm_e.name + ":relu")  # fc1
    plan.record_kernel(mm_e.name)            # fc2

    def step(x, w):
        b, l, d = x.shape
        src = x
        if node.pre_norm:
            ga = w[f"{pre}.norm.gamma"]
            if fmt_obj is not None:
                src, ga = q(src), q(ga)
            src = _rms_jnp(src, ga, node.eps, q)
        x2 = src.reshape(b * l, d)
        h1 = q(mm_e.fn(x2, w[f"{pre}.fc1.weight"].T,
                       w[f"{pre}.fc1.bias"], exp_bits=eb, man_bits=mb,
                       fuse_relu=True, **kw))
        z = q(mm_e.fn(h1, w[f"{pre}.fc2.weight"].T,
                      w[f"{pre}.fc2.bias"], exp_bits=eb, man_bits=mb,
                      **kw)).reshape(b, l, d)
        return q(x + z) if node.residual else z

    return step


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------

def to_pallas_fn(g: Graph, *, module=None, fmt=None, mode: str = "auto",
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None, nlb_flash: bool = False,
                 opcode_table=None) -> Callable:
    """Compile a DFG (plus optional source ``ModuleGraph``) to a callable.

    The returned callable maps a feed dict (memref name -> array, weights
    batched or not) to ``{output name: (batch,) + shape}`` exactly like
    ``emit.to_jax_fn``'s emission, is internally jitted (do NOT wrap it in
    ``jax.jit`` — the nest tier normalises weight feeds host-side), and
    carries its :class:`PallasPlan` as ``.plan``.

    ``mode='auto'`` picks the nest-pattern tier when ``module`` is given,
    else the generic DFG tier.  ``fmt`` (a FloPoCo key or ``FloatFormat``)
    quantises: per-op in the DFG tier (the functional model), per-kernel
    operand/result in the nest tier.  ``use_pallas=None`` routes through
    real ``pl.pallas_call`` bodies only on an accelerator; force ``True``
    to exercise the Pallas lowering in interpret mode on CPU.
    ``opcode_table`` overrides the DFG tier's opcode registry (tests use
    this to force per-group fallbacks).
    """
    import jax

    fmt_obj, fmt_key = _norm_fmt(fmt)
    accel = _on_accelerator()
    if use_pallas is None:
        use_pallas = accel
    if interpret is None:
        interpret = not accel
    if mode == "auto":
        mode = "nests" if module is not None else "dfg"
    if mode not in ("nests", "dfg"):
        raise ValueError(f"unknown pallas lowering mode {mode!r} "
                         f"(valid: auto, nests, dfg)")
    plan = PallasPlan(mode=mode, use_pallas=bool(use_pallas),
                      interpret=bool(interpret), fmt=fmt_key)

    if mode == "nests":
        if module is None:
            raise ValueError("mode='nests' needs the source ModuleGraph "
                             "(compile through repro.hls with an nn model, "
                             "or use mode='dfg')")
        fmt_tuple = (fmt_obj.exp_bits, fmt_obj.man_bits) \
            if fmt_obj is not None else None
        with obs.span("emit.pallas", cat="pallas", mode=mode,
                      fmt=fmt_key) as sp:
            core, weight_names, _ = _lower_module(
                module, fmt_obj=fmt_obj, fmt_tuple=fmt_tuple,
                use_pallas=use_pallas, interpret=interpret,
                nlb_flash=nlb_flash, plan=plan)
            sp.set(kernels=sum(plan.kernels.values()),
                   fallbacks=len(plan.fallbacks))
        _plan_metrics(plan)
        jcore = jax.jit(core)
        in_name = module.input_name
        in_shape = tuple(module.input_shape)
        rank = len(in_shape)
        profiled = [False]   # first obs-enabled call runs the span'd twin

        def run(feeds):
            missing = [n for n in weight_names if n not in feeds]
            if missing:
                raise KeyError(f"missing weight feeds {missing}")
            x = np.asarray(feeds[in_name], dtype=np.float32)
            if x.ndim == rank:                    # unbatched sample
                x = x[None]
            if in_shape[0] == 1:
                # collapse the loop-nest's per-sample singleton batch axis
                x = x.reshape((x.shape[0],) + in_shape[1:])
            w = {name: np.asarray(feeds[name], dtype=np.float32)
                 for name in weight_names}
            wn = _normalize_weights(w, module)
            if obs.enabled() and not profiled[0]:
                profiled[0] = True
                with obs.span("pallas.profile", cat="pallas", mode=mode):
                    return dict(core.profile(x, wn))
            return dict(jcore(x, wn))

        run.plan = plan
        return run

    with obs.span("emit.pallas", cat="pallas", mode=mode, fmt=fmt_key) as sp:
        core = _lower_dfg(g, fmt_obj=fmt_obj, use_pallas=use_pallas,
                          interpret=interpret,
                          opcode_table=opcode_table or kreg.OPCODE_KERNELS,
                          plan=plan)
        sp.set(segments=plan.n_segments, groups=plan.n_groups,
               fused_scatters=plan.fused_scatters,
               fallbacks=len(plan.fallbacks))
    _plan_metrics(plan)
    jcore = jax.jit(core)
    profiled = [False]       # first obs-enabled call runs the span'd twin

    def run(feeds):
        if obs.enabled() and not profiled[0]:
            profiled[0] = True
            with obs.span("pallas.profile", cat="pallas", mode=mode):
                return core.profile(feeds)
        return jcore(feeds)

    run.plan = plan
    return run


def _plan_metrics(plan: PallasPlan) -> None:
    """Lift the lowering plan's counts into the process metrics."""
    obs.inc("pallas.lowerings")
    obs.inc("pallas.segments", plan.n_segments)
    obs.inc("pallas.groups", plan.n_groups)
    obs.inc("pallas.scatter_elisions", plan.fused_scatters)
    obs.inc("pallas.fallbacks", len(plan.fallbacks))
    for kname, n in plan.kernels.items():
        obs.inc(f"pallas.kernel.{kname}", n)


def _normalize_weights(w: dict[str, np.ndarray], module) -> dict:
    """Unbatch weight feeds (the nest tier shares one weight set across the
    batch, like the tensor path).  A *varying* batched weight feed cannot
    be expressed as shared kernel weights — fail loudly toward mode='dfg'.
    """
    out = {}
    shapes = {}
    for n in module.nodes:
        sub = n.param_specs()
        if sub is None:
            continue
        for memref, path in n.weight_memrefs().items():
            leaf = sub
            for k in path:
                leaf = leaf[k]
            shapes[memref] = tuple(leaf.shape)
    for name, arr in w.items():
        want = shapes.get(name)
        if want is not None and arr.ndim == len(want) + 1:
            if arr.shape[0] > 1 and not np.all(arr == arr[0]):
                raise ValueError(
                    f"weight feed {name!r} varies across the batch; the "
                    f"nest-pattern tier shares one weight set — use "
                    f"mode='dfg' for per-sample weights")
            arr = arr[0]
        out[name] = arr
    return out
