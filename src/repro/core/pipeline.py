"""Flow orchestration: PassManager + CompilerDriver (paper Fig. 1).

The paper's pipeline — trace (symbolic interpretation) -> DFG ->
transformations -> scheduling -> emission -> behavioural verification —
lives here as a single orchestrated flow instead of being re-stitched by
every consumer:

  * ``register_pass``   — decorator-based pass registry.  A pass is any
                          ``Graph -> Graph`` rewrite; options are keyword
                          arguments (e.g. ``reduction_tree``'s threshold).
  * ``PassManager``     — runs a named pipeline to a fixpoint with per-pass
                          instrumentation: op-histogram deltas, wall time,
                          and optional ``topo_check`` / behavioural
                          spot-verify hooks.  Produces one ``PassReport``
                          per pass application.
  * ``CompilerDriver``  — ``compile()`` runs trace -> optimize -> schedule
                          (emission is lazy) and returns a
                          ``CompiledDesign`` bundling every artifact plus a
                          content hash.  Designs are cached in memory and
                          optionally on disk keyed by that hash, so repeated
                          compiles (serving warm-up, benchmark sweeps) are
                          free.

``passes.optimize`` remains as a thin compatibility wrapper over
``PassManager`` — the two produce bit-identical graphs.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import time
from pathlib import Path
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.core import emit, passes
from repro.core.cachedir import CACHE_FORMAT_VERSION
from repro.core.interp import Context
from repro.core.ir import Graph
from repro.core.precision import FloatFormat
from repro.core.schedule import (Schedule, ScheduleParams, list_schedule,
                                 partition_stages)

# ---------------------------------------------------------------------------
# Pass registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PassInfo:
    """A registered pass plus the metadata the incremental fixpoint uses.

    matches:
        the opcodes whose presence/shape this pass's pattern depends on, or
        ``None`` for "anything" (liveness/use-count driven passes).  A pass
        is skipped in a fixpoint round when no opcode it matches was touched
        since its own last application — it provably has nothing new to see.
    self_clean:
        True when the pass is a fixpoint of itself (running it twice in a
        row never changes the second output).  Non-self-clean passes (e.g.
        ``reduction_tree``, which re-rebalances the leftmost spine of its
        own trees) stay dirty after any application that changed the graph.
    """

    fn: Callable[..., Graph]
    matches: Optional[frozenset] = None
    self_clean: bool = False


#: name -> PassInfo.  Populated by ``register_pass``.
PASS_REGISTRY: dict[str, PassInfo] = {}


def register_pass(name: str, *, matches: Optional[frozenset] = None,
                  self_clean: bool = False
                  ) -> Callable[[Callable[..., Graph]], Callable[..., Graph]]:
    """Register ``fn`` as a named pass usable in any pipeline.

    ``fn(g, **options) -> Graph`` must return a rewritten graph whose
    program order is a valid topological order (``Rewriter.finish`` already
    guarantees this for the built-in passes).  A pass that has nothing to
    rewrite should return its input graph *object* unchanged — that is the
    signal the incremental fixpoint uses to mark it clean; passes that
    rewrite may annotate the result with ``_touched`` (a frozenset of
    opcode names) so downstream passes with disjoint ``matches`` can be
    skipped.  Conservative defaults (``matches=None``, ``self_clean=False``)
    make an unannotated external pass always re-run while anything changes.
    """
    def deco(fn: Callable[..., Graph]) -> Callable[..., Graph]:
        if name in PASS_REGISTRY:
            raise ValueError(f"pass {name!r} already registered")
        PASS_REGISTRY[name] = PassInfo(
            fn, frozenset(matches) if matches is not None else None,
            self_clean)
        return fn
    return deco


# The paper's §3.2 inventory, registered under the names the string pipeline
# always used so existing ``pipeline=(...)`` arguments keep working.
# ``matches`` is the dependence footprint of each pattern:
#   * cse keys on every arith row (a touched arith op can create a dup);
#   * relu_recompose only reads cmpugt/select rows (and consts, which never
#     change after tracing);
#   * reduction_tree and fmac_coalesce gate on use counts, which any op
#     change can shift — they match everything;
#   * dce is liveness-driven — any change can strand a value.
register_pass("cse", matches=passes.ARITH_OPS, self_clean=True)(passes.cse)
register_pass("relu_recompose", matches=frozenset({"cmpugt", "select"}),
              self_clean=True)(passes.relu_recompose)
register_pass("reduction_tree")(passes.reduction_tree)
register_pass("fmac_coalesce", self_clean=True)(passes.fmac_coalesce)
register_pass("dce", self_clean=True)(passes.dce)

DEFAULT_PIPELINE: tuple[str, ...] = tuple(passes.DEFAULT_PIPELINE)


def parse_pipeline_spec(spec: str) -> tuple[str, ...]:
    """Parse a ``"cse,dce"``-style CLI pipeline spec against the registry.

    Raises ``ValueError`` naming the first unknown pass; empty segments are
    dropped, so ``""`` is the empty pipeline.
    """
    names = tuple(p for p in (s.strip() for s in spec.split(",")) if p)
    unknown = [p for p in names if p not in PASS_REGISTRY]
    if unknown:
        raise ValueError(f"unknown pass {unknown[0]!r}; registered: "
                         f"{sorted(PASS_REGISTRY)}")
    return names


# ---------------------------------------------------------------------------
# Per-pass instrumentation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PassReport:
    """Instrumentation for one application of one pass."""

    name: str
    round: int
    ops_before: int
    ops_after: int
    hist_before: dict[str, int]
    hist_after: dict[str, int]
    wall_s: float
    topo_ok: Optional[bool] = None       # None = check not requested
    spot_err: Optional[float] = None     # None = spot-verify not requested
    #: True when the incremental fixpoint proved this application a no-op
    #: (none of the pass's matched opcodes were touched since its last run)
    #: and skipped it.  Skipped reports carry zero wall time and identical
    #: before/after histograms.
    skipped: bool = False

    @property
    def ops_delta(self) -> int:
        return self.ops_after - self.ops_before

    def hist_delta(self) -> dict[str, int]:
        """Per-opcode op-count change (only non-zero entries)."""
        keys = set(self.hist_before) | set(self.hist_after)
        delta = {k: self.hist_after.get(k, 0) - self.hist_before.get(k, 0)
                 for k in sorted(keys)}
        return {k: v for k, v in delta.items() if v}

    def summary(self) -> str:
        if self.skipped:
            return (f"[round {self.round}] {self.name}: skipped "
                    f"(matched opcodes untouched)")
        d = self.hist_delta()
        extra = f" {d}" if d else ""
        return (f"[round {self.round}] {self.name}: "
                f"{self.ops_before} -> {self.ops_after} ops "
                f"({self.wall_s * 1e3:.1f} ms){extra}")


def behavioural_spot_check(*, batch: int = 2, seed: int = 0,
                           scale: float = 0.5) -> Callable[[Graph, Graph, str], float]:
    """Build a spot-verify hook: evaluate both graphs on tiny random feeds.

    Returns max-abs deviation of the rewritten graph vs its input graph —
    the per-pass miniature of the paper's behavioural testbenches.  Imported
    lazily by ``PassManager`` when ``spot_verify=True``.
    """
    def check(g_before: Graph, g_after: Graph, name: str) -> float:
        from repro.core import verify
        feeds = verify.random_feeds(g_before, batch=batch, seed=seed,
                                    scale=scale)
        out_a = emit.evaluate(g_before, feeds)
        out_b = emit.evaluate(g_after, feeds)
        err = 0.0
        for k in out_a:
            err = max(err, float(np.max(np.abs(out_a[k] - out_b[k]))))
        return err
    return check


class PassManager:
    """Drives a named pass pipeline to a fixpoint with instrumentation.

    Fixpoint criterion matches the historical ``passes.optimize``: rounds
    repeat (up to ``max_rounds``) until a full round leaves the op count
    unchanged — passes expose each other's opportunities (e.g. DCE drops a
    second use of a mul, enabling FMAC coalescing next round).
    """

    def __init__(
        self,
        pipeline: Sequence[str] = DEFAULT_PIPELINE,
        *,
        max_rounds: int = 4,
        pass_options: Optional[dict[str, dict]] = None,
        topo_check: bool = False,
        spot_verify: Union[bool, Callable[[Graph, Graph, str], float]] = False,
    ):
        unknown = [n for n in pipeline if n not in PASS_REGISTRY]
        if unknown:
            raise ValueError(
                f"unknown pass {unknown[0]!r}; registered: "
                f"{sorted(PASS_REGISTRY)}")
        self.pipeline = tuple(pipeline)
        self.max_rounds = max_rounds
        self.pass_options = dict(pass_options or {})
        self.topo_check = topo_check
        if spot_verify is True:
            spot_verify = behavioural_spot_check()
        self.spot_verify = spot_verify or None

    def run(self, g: Graph) -> tuple[Graph, list[PassReport]]:
        passes.hoist_globals_check(g)
        reports: list[PassReport] = []
        ALL = None   # dirty sentinel: everything touched
        # dirty[p]: opcodes touched since p's last application (ALL before
        # its first).  A round skips p when its matched opcodes are all
        # untouched — p would provably find nothing new.  The fixpoint
        # criterion itself is unchanged (a full round with a stable op
        # count terminates), so skipping never alters the final graph.
        dirty: dict[str, Optional[set]] = {n: ALL for n in self.pipeline}
        changed_last: dict[str, bool] = {}
        infos = {n: PASS_REGISTRY[n] for n in self.pipeline}
        for rnd in range(self.max_rounds):
            before = len(g.ops)
            with obs.span(f"passes.round{rnd}", cat="compile",
                          round=rnd) as round_sp:
                for name in self.pipeline:
                    info = infos[name]
                    d = dirty[name]
                    must_run = (d is ALL
                                or (not info.self_clean
                                    and changed_last.get(name, False)))
                    if not must_run and d:
                        must_run = (info.matches is None
                                    or bool(info.matches & d))
                    if not must_run:
                        hist = g.op_histogram()
                        reports.append(PassReport(
                            name=name, round=rnd, ops_before=len(g.ops),
                            ops_after=len(g.ops), hist_before=hist,
                            hist_after=hist, wall_s=0.0, skipped=True))
                        obs.inc("compile.passes_skipped")
                        continue
                    opts = self.pass_options.get(name, {})
                    hist_before = g.op_histogram()
                    n_before = len(g.ops)
                    t0 = time.perf_counter()
                    with obs.span(f"passes.{name}", cat="compile",
                                  round=rnd) as pass_sp:
                        g_new = info.fn(g, **opts)
                        pass_sp.set(ops_before=n_before,
                                    ops_after=len(g_new.ops),
                                    delta=len(g_new.ops) - n_before)
                    wall = time.perf_counter() - t0
                    rep = PassReport(
                        name=name, round=rnd, ops_before=n_before,
                        ops_after=len(g_new.ops), hist_before=hist_before,
                        hist_after=g_new.op_histogram(), wall_s=wall)
                    if self.topo_check:
                        try:
                            g_new.topo_check()
                            rep.topo_ok = True
                        except ValueError:
                            rep.topo_ok = False
                            reports.append(rep)
                            raise
                    if self.spot_verify is not None:
                        rep.spot_err = self.spot_verify(g, g_new, name)
                    reports.append(rep)
                    obs.inc("compile.passes_run")
                    changed = g_new is not g
                    changed_last[name] = changed
                    dirty[name] = set()
                    if changed:
                        touched = getattr(g_new, "_touched", None)
                        for other in self.pipeline:
                            if other == name:
                                continue
                            if touched is None or dirty[other] is ALL:
                                dirty[other] = ALL
                            else:
                                dirty[other] = dirty[other] | touched
                    g = g_new
                round_sp.set(ops_before=before, ops_after=len(g.ops))
            if len(g.ops) == before:
                break
        return g, reports


# ---------------------------------------------------------------------------
# Compile configuration + artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompilerConfig:
    """Everything that determines the compiled design besides the program.

    Hashable and canonically serialisable — it is folded into the design
    hash, so changing any field is a cache miss.
    """

    pipeline: tuple[str, ...] = DEFAULT_PIPELINE
    tree_threshold: int = 4
    max_rounds: int = 4
    forward: bool = True                 # store-load forwarding in the trace
    binding: str = "pool"
    unroll_factor: Optional[int] = None
    ports_per_array: int = 2
    pipelined_units: bool = False
    alap_compact: bool = True
    n_stages: int = 1                    # pipeline-partition factor (§4.2)
    topo_check: bool = False
    spot_verify: bool = False

    def pass_manager(self) -> PassManager:
        return PassManager(
            self.pipeline, max_rounds=self.max_rounds,
            pass_options={"reduction_tree": {"threshold": self.tree_threshold}},
            topo_check=self.topo_check, spot_verify=self.spot_verify)

    def schedule_params(self) -> ScheduleParams:
        """The schedule-stage slice of the config, as a first-class bundle."""
        return ScheduleParams(
            binding=self.binding, unroll_factor=self.unroll_factor,
            ports_per_array=self.ports_per_array,
            pipelined_units=self.pipelined_units,
            alap_compact=self.alap_compact, n_stages=self.n_stages)

    def pass_key(self) -> str:
        """Canonical string over the fields that determine the *optimised
        graph* (not the schedule).  Two configs sharing a pass key can share
        one pass-stage run — the lever design-space search leans on: mutating
        a schedule knob re-schedules in ~0.1x the cost of re-optimising.
        """
        return repr((self.pipeline, self.tree_threshold, self.max_rounds,
                     self.forward, self.topo_check, self.spot_verify))

    def key(self) -> str:
        """Canonical string folded into the design hash."""
        return repr(tuple(sorted(dataclasses.asdict(self).items())))


def graph_fingerprint(g: Graph) -> str:
    """Content hash of a DFG: ops, constants and interface tables.

    Two structurally identical graphs (same program traced twice) produce
    the same fingerprint — value ids are deterministic under tracing.
    Memoised on the graph object: graphs are frozen after ``finalize`` /
    ``Rewriter.finish``, and benchmark sweeps hash the same traced graph
    once per config.
    """
    cached = getattr(g, "_fingerprint", None)
    if cached is not None:
        return cached
    h = hashlib.sha256()
    # hash the raw column bytes — same information as the historical per-op
    # string rendering at a fraction of the cost (17 MB/s of ops -> one
    # memcpy-speed digest); array names are hashed alongside so interned
    # array ids keep their meaning
    c = g.cols()
    h.update(f"soa:{c.n}:{g.n_values}".encode())
    for arr in (c.opcode, c.args, c.result, c.nest, c.rank, c.array_id):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(repr(g.array_names).encode())
    h.update(repr(sorted(g.consts.items())).encode())
    for label, tables in (("in", g.inputs), ("out", g.outputs)):
        for name in sorted(tables):
            h.update(f"{label}:{name}:{sorted(tables[name].items())}".encode())
    h.update(repr(sorted(g.weight_names)).encode())
    h.update(repr(sorted(g.nest_parallel_space.items())).encode())
    digest = h.hexdigest()
    g._fingerprint = digest
    return digest


@dataclasses.dataclass
class CompiledDesign:
    """The full artifact of one ``CompilerDriver.compile`` run.

    Bundles the raw (traced) graph, the optimised graph, the resource-
    constrained ``Schedule``, per-pass ``PassReport``s, stage timings, and
    the content hash that keys the design cache.  The emitted jittable SIMD
    function is materialised lazily via :meth:`jax_fn` (and therefore not
    pickled into the on-disk cache — it is re-emitted on load).

    ``timings`` always describe the compile that *built* the artifact; a
    cache-served design keeps its original build cost.
    """

    name: str
    config: CompilerConfig
    graph_raw: Graph
    graph_opt: Graph
    schedule: Schedule
    pass_reports: list[PassReport]
    design_hash: str
    timings: dict[str, float]
    #: Stage partition, materialised at compile time when
    #: ``config.n_stages > 1`` (paper §4.2's pipelined deployment); both
    #: stay ``None`` for unpipelined designs.
    stages: Optional[list[list[int]]] = None
    stage_ii: Optional[int] = None
    _jax_fn: Optional[Callable] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- derived metrics ----------------------------------------------------

    @property
    def makespan(self) -> int:
        return self.schedule.makespan

    @property
    def latency_us(self) -> float:
        return self.schedule.latency_us

    @property
    def sample_latency_us(self) -> float:
        """Per-sample latency of the deployed design: the initiation
        interval when the design is stage-pipelined, else the makespan."""
        intervals = self.stage_ii if self.stage_ii is not None \
            else self.schedule.makespan
        from repro.core.schedule import CLOCK_NS
        return intervals * CLOCK_NS * 1e-3

    def pass_time_by_name(self) -> dict[str, float]:
        """Total wall time per pass name across all fixpoint rounds."""
        out: dict[str, float] = {}
        for rep in self.pass_reports:
            out[rep.name] = out.get(rep.name, 0.0) + rep.wall_s
        return out

    def pass_throughput_ops_s(self) -> float:
        """Ops/second through the pass pipeline (executed applications only).

        The compiler-throughput figure benchmarks track across PRs: total
        ops entering each executed pass application divided by total pass
        wall time.  0.0 when nothing was timed (e.g. a cache-served design
        compiled before this field existed).
        """
        wall = sum(r.wall_s for r in self.pass_reports if not r.skipped)
        ops = sum(r.ops_before for r in self.pass_reports if not r.skipped)
        return ops / wall if wall > 0 else 0.0

    # -- execution backends -------------------------------------------------

    def jax_fn(self, *, backend: str = "simd", **pallas_kw) -> Callable:
        """The emitted design as a callable, materialised on first use.

        ``backend='simd'`` (cached): the jittable gather/compute/scatter
        interpretation.  ``backend='pallas'``: the compiled rendering
        (``emit_pallas``), rebuilt per call since its lowering depends on
        the extra keywords (``module=``, ``fmt=``, ``use_pallas=``, ...).
        """
        if backend != "simd":
            return emit.to_jax_fn(self.graph_opt, backend=backend,
                                  **pallas_kw)
        if pallas_kw:
            raise TypeError(f"backend='simd' takes no extra keywords, got "
                            f"{sorted(pallas_kw)}")
        if self._jax_fn is None:
            with obs.span("emit.simd", cat="compile", design=self.name,
                          ops=len(self.graph_opt.ops)):
                self._jax_fn = emit.to_jax_fn(self.graph_opt)
        return self._jax_fn

    def evaluate(self, feeds: dict, *, fmt: Optional[FloatFormat] = None,
                 raw: bool = False) -> dict:
        """Functional simulation (optionally quantised / on the raw graph)."""
        g = self.graph_raw if raw else self.graph_opt
        return emit.evaluate(g, feeds, fmt=fmt)

    def partition(self, n_stages: int) -> tuple[list[list[int]], int]:
        """Pipeline the design: (stages as nest-id lists, initiation interval)."""
        return partition_stages(self.graph_opt, self.schedule, n_stages)

    def summary(self) -> str:
        res = self.schedule.resources()
        return (f"{self.name}: ops {len(self.graph_raw.ops)} -> "
                f"{len(self.graph_opt.ops)}, intervals={self.makespan} "
                f"({self.latency_us:.2f} us, "
                f"{self.sample_latency_us:.2f} us/sample), "
                f"resources={res}, hash={self.design_hash[:12]}")

    # -- pickling (the lazy jax fn is a closure: drop it) --------------------

    def __getstate__(self):
        state = self.__dict__.copy()
        state["_jax_fn"] = None
        return state


# ---------------------------------------------------------------------------
# Warm-boot design artifacts
# ---------------------------------------------------------------------------

#: First bytes-level sanity mark of a ``Design.save`` artifact file.
ARTIFACT_MAGIC = "repro-design-artifact"


def save_artifact(path: Union[str, Path], payload: dict) -> Path:
    """Persist a warm-boot design artifact (versioned pickle, atomic write).

    ``payload`` is the ``Design.save`` bundle: the ``CompiledDesign``, the
    (numpy-ified) bound module, example inputs and the warmed-bucket
    manifest.  The pickle shares the design cache's format version, so a
    layout change invalidates saved artifacts the same way it invalidates
    cached designs — :func:`load_artifact` rejects stale files loudly
    instead of unpickling into incompatible objects.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    record = {"magic": ARTIFACT_MAGIC, "version": CACHE_FORMAT_VERSION,
              **payload}
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as f:
        pickle.dump(record, f)
    tmp.replace(path)
    return path


def load_artifact(path: Union[str, Path]) -> dict:
    """Load and validate a ``save_artifact`` file.

    Raises ``FileNotFoundError`` / ``ValueError`` with the exact reason
    (missing, not an artifact, or saved under a different
    ``CACHE_FORMAT_VERSION`` — re-save from a fresh compile).
    """
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"no design artifact at {path}")
    with open(path, "rb") as f:
        record = pickle.load(f)
    if not isinstance(record, dict) or record.get("magic") != ARTIFACT_MAGIC:
        raise ValueError(f"{path} is not a repro design artifact")
    version = record.get("version")
    if version != CACHE_FORMAT_VERSION:
        raise ValueError(
            f"design artifact {path} was saved with format version "
            f"{version}, this build expects {CACHE_FORMAT_VERSION} — "
            f"recompile and Design.save again")
    return record


# ---------------------------------------------------------------------------
# Design cache
# ---------------------------------------------------------------------------


class DesignCache:
    """In-memory + optional on-disk cache of ``CompiledDesign`` artifacts.

    Keyed by the design hash (graph fingerprint + config key).  The disk
    layer stores one pickle per design under ``cache_dir``; loads re-emit
    the jax fn lazily.
    """

    def __init__(self, cache_dir: Optional[Union[str, Path]] = None, *,
                 max_memory_entries: Optional[int] = None):
        self.memory: dict[str, CompiledDesign] = {}
        self.max_memory_entries = max_memory_entries
        self.cache_dir = Path(cache_dir) if cache_dir else None
        if self.cache_dir:
            # entries are pickles: refuse a directory another user controls
            self.cache_dir.mkdir(parents=True, exist_ok=True, mode=0o700)
            if hasattr(os, "getuid"):
                st = self.cache_dir.stat()
                if st.st_uid != os.getuid():
                    raise RuntimeError(
                        f"design cache dir {self.cache_dir} is owned by "
                        f"uid {st.st_uid}, not the current user — refusing "
                        f"to load pickles from it")
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> Optional[Path]:
        return self.cache_dir / f"{key}.pkl" if self.cache_dir else None

    def get(self, key: str) -> Optional[CompiledDesign]:
        design = self.memory.get(key)
        if design is not None:
            self.hits += 1
            obs.inc("design_cache.hits")
            return design
        path = self._path(key)
        if path is not None and path.exists():
            try:
                with open(path, "rb") as f:
                    design = pickle.load(f)
            except Exception:
                design = None       # corrupt entry: treat as miss
            if design is not None:
                self.memory[key] = design
                self.hits += 1
                obs.inc("design_cache.hits")
                return design
        self.misses += 1
        obs.inc("design_cache.misses")
        return None

    def put(self, key: str, design: CompiledDesign) -> None:
        self.memory[key] = design
        if self.max_memory_entries is not None:
            while len(self.memory) > self.max_memory_entries:
                self.memory.pop(next(iter(self.memory)))  # evict oldest
        path = self._path(key)
        if path is not None:
            tmp = path.with_suffix(".tmp")
            with open(tmp, "wb") as f:
                pickle.dump(design, f)
            tmp.replace(path)

    def clear(self) -> None:
        self.memory.clear()
        if self.cache_dir:
            for p in self.cache_dir.glob("*.pkl"):
                p.unlink()


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------

BuildFn = Callable[[Context], None]


class CompilerDriver:
    """Single entrypoint for the full lowering flow (paper Fig. 1).

    ``compile`` accepts either a build callable (``Context -> None``, the
    trace step runs here) or an already-traced ``Graph``, and returns a
    ``CompiledDesign``.  Repeated compiles of the same program + config are
    served from the cache (tracing still runs for build callables — the
    graph fingerprint requires the traced DFG — but passes, scheduling and
    emission are skipped).
    """

    def __init__(self, config: Optional[CompilerConfig] = None, *,
                 cache: Optional[DesignCache] = None,
                 cache_dir: Optional[Union[str, Path]] = None):
        self.config = config or CompilerConfig()
        self.cache = cache or DesignCache(cache_dir)
        #: full (non-cache-served) builds this driver has performed
        self.recompiles = 0
        #: pass-stage memo hits (builds that skipped the pass pipeline)
        self.pass_memo_hits = 0
        # pass-stage memo: (graph fingerprint, cfg.pass_key()) -> optimised
        # graph + reports.  Configs differing only in schedule knobs reuse
        # the (expensive) pass stage — the design-space explorer's hot path.
        # Precision-only tune candidates go one better: ``precision`` is not
        # a ``CompilerConfig`` field at all (``SearchSpace.to_config`` drops
        # it), so a precision step re-uses the *whole* cached design, not
        # just the pass stage (asserted by ``tests/test_tune.py``).
        self._opt_memo: dict[tuple[str, str],
                             tuple[Graph, list[PassReport]]] = {}

    # -- stages -------------------------------------------------------------

    def trace(self, build: BuildFn, *,
              forward: Optional[bool] = None) -> Graph:
        """Symbolic interpretation: run the loop nests, recover the DFG."""
        ctx = Context(forward=self.config.forward if forward is None
                      else forward)
        build(ctx)
        return ctx.finalize()

    def compile(self, program: Union[BuildFn, Graph], *,
                name: str = "design",
                config: Optional[CompilerConfig] = None) -> CompiledDesign:
        cfg = config or self.config
        timings: dict[str, float] = {}

        with obs.span("compile", cat="compile", design=name) as compile_sp:
            t0 = time.perf_counter()
            with obs.span("compile.trace", cat="compile", design=name) as sp:
                if isinstance(program, Graph):
                    g_raw = program
                else:
                    g_raw = self.trace(program, forward=cfg.forward)
                sp.set(ops=len(g_raw.ops))
            timings["trace_s"] = time.perf_counter() - t0

            key = hashlib.sha256(
                (f"v{CACHE_FORMAT_VERSION}|" + graph_fingerprint(g_raw) + "|"
                 + cfg.key()).encode()).hexdigest()
            cached = self.cache.get(key)
            if cached is not None:
                compile_sp.set(cached=True, design_hash=key[:12])
                if cached.name != name:
                    # relabel for this caller; graphs/schedule/fn stay shared
                    return dataclasses.replace(cached, name=name)
                return cached
            self.recompiles += 1
            obs.inc("compile.recompiles")

            t0 = time.perf_counter()
            memo_key = (graph_fingerprint(g_raw), cfg.pass_key())
            memoised = self._opt_memo.get(memo_key)
            with obs.span("compile.passes", cat="compile", design=name,
                          memo=memoised is not None) as sp:
                if memoised is not None:
                    g_opt, reports = memoised
                    self.pass_memo_hits += 1
                    obs.inc("compile.pass_memo_hits")
                else:
                    g_opt, reports = cfg.pass_manager().run(g_raw)
                    self._opt_memo[memo_key] = (g_opt, reports)
                sp.set(ops_before=len(g_raw.ops), ops_after=len(g_opt.ops),
                       applications=sum(1 for r in reports if not r.skipped))
            timings["passes_s"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            with obs.span("compile.schedule", cat="compile",
                          design=name) as sp:
                sched = list_schedule(g_opt, params=cfg.schedule_params())
                stages = stage_ii = None
                timings["partition_s"] = 0.0
                if cfg.n_stages > 1:
                    tp = time.perf_counter()
                    with obs.span("compile.partition", cat="compile",
                                  design=name, n_stages=cfg.n_stages) as psp:
                        stages, stage_ii = partition_stages(g_opt, sched,
                                                            cfg.n_stages)
                        psp.set(stage_ii=stage_ii)
                    timings["partition_s"] = time.perf_counter() - tp
                sp.set(makespan=sched.makespan, stage_ii=stage_ii)
            timings["schedule_s"] = time.perf_counter() - t0
            # partition_s is a sub-timing of schedule_s, not an extra stage
            timings["total_s"] = (timings["trace_s"] + timings["passes_s"]
                                  + timings["schedule_s"])
            if timings["total_s"] > 0:
                obs.gauge("compiler.ops_per_s",
                          len(g_raw.ops) / timings["total_s"])
            compile_sp.set(cached=False, design_hash=key[:12],
                           ops_raw=len(g_raw.ops), ops_opt=len(g_opt.ops),
                           makespan=sched.makespan,
                           **{f"{k[:-2]}_ms": round(v * 1e3, 3)
                              for k, v in timings.items()})

        design = CompiledDesign(
            name=name, config=cfg, graph_raw=g_raw, graph_opt=g_opt,
            schedule=sched, pass_reports=list(reports), design_hash=key,
            timings=timings, stages=stages, stage_ii=stage_ii)
        self.cache.put(key, design)
        return design


# ---------------------------------------------------------------------------
# Deprecated entry points (forward to repro.hls, the public front door)
# ---------------------------------------------------------------------------

#: shims that already warned this process (each warns exactly once)
_DEPRECATION_WARNED: set[str] = set()


def _warn_deprecated(key: str, msg: str) -> None:
    if key in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(key)
    import warnings
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


def default_driver() -> CompilerDriver:
    """Deprecated: use ``repro.hls`` (``hls.compile`` / ``hls.Session``)."""
    _warn_deprecated(
        "default_driver",
        "repro.core.pipeline.default_driver() is deprecated; use "
        "repro.hls.compile(...) or an explicit repro.hls.Session")
    from repro import hls
    return hls._default_session().driver


def compile(program: Union[BuildFn, Graph], *, name: str = "design",
            config: Optional[CompilerConfig] = None) -> CompiledDesign:
    """Deprecated: use ``repro.hls.compile`` (returns a rich ``Design``;
    its ``.compiled`` is this function's historical return value)."""
    _warn_deprecated(
        "pipeline.compile",
        "repro.core.pipeline.compile() is deprecated; use "
        "repro.hls.compile(...) — the returned Design wraps the same "
        "CompiledDesign (design.compiled)")
    from repro import hls
    return hls.compile(program, name=name, config=config).compiled
