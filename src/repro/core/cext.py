"""Lazy in-tree C kernel builds — stdlib ``ctypes`` plus the system ``cc``.

The compiler hot path has one genuinely order-serial loop (the ASAP
resource-serialisation core); everything around it is numpy array programs.
Rather than pull in a JIT dependency, the reference C source shipped next
to this module (``_asap.c``) is compiled once per source revision into a
content-hashed shared object under ``_cbuild/`` and bound through ctypes.

Every call site must treat ``None`` from :func:`asap_pool_lib` as "no
kernel" and fall back to the pure-Python loop — machines without a C
compiler (or with ``REPRO_NO_CEXT=1``) lose speed, never correctness.
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import pathlib
import subprocess
from typing import Optional

_SRC = pathlib.Path(__file__).with_name("_asap.c")

_lib: Optional[ctypes.CDLL] = None
_tried = False

_I64P = ctypes.POINTER(ctypes.c_int64)
_ASAP_ARGTYPES = (
    [ctypes.c_int64] * 2 + [_I64P] * 8 + [ctypes.c_int64] * 6 + [_I64P] * 5)


def _build() -> ctypes.CDLL:
    src = _SRC.read_bytes()
    tag = hashlib.sha256(src).hexdigest()[:16]
    cache_dir = pathlib.Path(
        os.environ.get("REPRO_CEXT_DIR", str(_SRC.parent / "_cbuild")))
    so = cache_dir / f"_asap_{tag}.so"
    if not so.exists():
        cache_dir.mkdir(parents=True, exist_ok=True)
        tmp = so.with_name(f"{so.name}.tmp{os.getpid()}")
        cc = os.environ.get("CC", "cc")
        subprocess.run(
            [cc, "-O2", "-shared", "-fPIC", "-o", str(tmp), str(_SRC)],
            check=True, capture_output=True, timeout=120)
        os.replace(tmp, so)  # atomic: concurrent builders race benignly
    lib = ctypes.CDLL(str(so))
    lib.asap_pool.restype = ctypes.c_int
    lib.asap_pool.argtypes = _ASAP_ARGTYPES
    return lib


def asap_pool_lib() -> Optional[ctypes.CDLL]:
    """The compiled ASAP kernel, or ``None`` when unavailable.

    The first call pays the (cached) compile; failures of any kind latch to
    ``None`` for the process lifetime so the scheduler probes exactly once.
    """
    global _lib, _tried
    if _tried:
        return _lib
    _tried = True
    if os.environ.get("REPRO_NO_CEXT", "") == "1":
        return None
    try:
        _lib = _build()
    except Exception:
        _lib = None
    return _lib
