"""Behavioural verification — testbench generation (paper §3.2).

OpenHLS trades formal correctness of its rewrites for development-time
speed, and recovers confidence through *behavioural* verification: generated
testbenches drive random vectors through (a) the unoptimised DFG, (b) the
optimised/scheduled DFG, (c) the FloPoCo functional model (quantised
evaluation) and (d) an independent tensor-level reference, then compare.
This module is the cocotb/iverilog analogue and runs inside pytest as part
of CI, exactly as the paper describes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import emit
from repro.core.interp import Context
from repro.core.ir import Graph
from repro.core.pipeline import (CompiledDesign, CompilerConfig,
                                 CompilerDriver)
from repro.core.precision import FloatFormat


def input_shapes(g: Graph) -> dict[str, tuple[int, ...]]:
    """Reconstruct memref shapes from interface tables (max index + 1)."""
    shapes = {}
    for name, table in g.inputs.items():
        rank = len(next(iter(table)))
        shapes[name] = tuple(max(i[d] for i in table) + 1 for d in range(rank))
    return shapes


def random_feeds(g: Graph, *, batch: int = 4, seed: int = 0,
                 scale: float = 1.0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    feeds = {}
    for name, shape in input_shapes(g).items():
        feeds[name] = rng.normal(0.0, scale, size=(batch,) + shape).astype(
            np.float32)
    return feeds


@dataclasses.dataclass
class TestbenchReport:
    name: str
    n_ops_raw: int
    n_ops_opt: int
    makespan: int
    max_abs_err_opt: float        # optimised DFG vs raw DFG
    max_abs_err_ref: float        # raw DFG vs tensor reference (if given)
    max_abs_err_quant: float      # quantised functional model vs raw DFG
    max_abs_err_jax: float        # emitted SIMD design vs raw DFG
    build_seconds: float
    passed: bool

    def summary(self) -> str:
        return (f"[{'PASS' if self.passed else 'FAIL'}] {self.name}: "
                f"ops {self.n_ops_raw}->{self.n_ops_opt}, "
                f"intervals={self.makespan}, "
                f"err(opt)={self.max_abs_err_opt:.2e}, "
                f"err(ref)={self.max_abs_err_ref:.2e}, "
                f"err(quant)={self.max_abs_err_quant:.2e}, "
                f"err(simd)={self.max_abs_err_jax:.2e}")


def _max_err(a: dict[str, np.ndarray], b: dict[str, np.ndarray]) -> float:
    err = 0.0
    for k in a:
        err = max(err, float(np.max(np.abs(a[k] - b[k]))))
    return err


def run_testbench(
    name: str,
    build: Optional[Callable[[Context], None]] = None,
    *,
    design: Optional[CompiledDesign] = None,
    driver: Optional[CompilerDriver] = None,
    ref_fn: Optional[Callable[[dict[str, np.ndarray]], dict[str, np.ndarray]]] = None,
    fmt: Optional[FloatFormat] = None,
    batch: int = 4,
    seed: int = 0,
    scale: float = 1.0,
    atol: float = 1e-3,
    ref_atol: float = 5e-2,
    check_jax: bool = True,
    tree_threshold: int = 4,
    feed_transforms: Optional[dict] = None,
) -> TestbenchReport:
    """Behaviourally verify one design.

    Either pass ``build`` (a ``Context -> None`` builder: the testbench
    compiles it through ``CompilerDriver``) or an already-compiled
    ``design`` — the testbench then consumes the ``CompiledDesign``
    artifact directly instead of re-running the flow.

    ``feed_transforms``: per-input-name callables applied to the random
    feeds (e.g. ``abs`` for a variance input).
    """
    report_name = name
    if design is None:
        if build is None:
            raise ValueError("run_testbench needs either build= or design=")
        drv = driver or CompilerDriver(
            CompilerConfig(tree_threshold=tree_threshold))
        design = drv.compile(build, name=name)
    g_raw, g_opt = design.graph_raw, design.graph_opt
    build_s = design.timings.get("total_s", 0.0)

    feeds = random_feeds(g_raw, batch=batch, seed=seed, scale=scale)
    for name, fn in (feed_transforms or {}).items():
        feeds[name] = np.asarray(fn(feeds[name]), dtype=np.float32)
    out_raw = emit.evaluate(g_raw, feeds)
    out_opt = emit.evaluate(g_opt, feeds)
    err_opt = _max_err(out_raw, out_opt)

    err_ref = 0.0
    if ref_fn is not None:
        out_ref = ref_fn(feeds)
        err_ref = _max_err(out_raw, out_ref)

    err_quant = 0.0
    if fmt is not None:
        out_q = emit.evaluate(g_opt, feeds, fmt=fmt)
        err_quant = _max_err(out_raw, out_q)

    err_jax = 0.0
    if check_jax:
        fn = design.jax_fn()
        out_jax = {k: np.asarray(v) for k, v in fn(feeds).items()}
        err_jax = _max_err(out_raw, out_jax)

    # reassociation (reduction trees) and fmac fusion change rounding; the
    # optimised design must match within reassociation tolerance, the
    # reference within modelling tolerance (Taylor-series exp etc.).
    passed = (err_opt <= atol and err_jax <= atol
              and (ref_fn is None or err_ref <= ref_atol))
    return TestbenchReport(
        name=report_name, n_ops_raw=len(g_raw.ops), n_ops_opt=len(g_opt.ops),
        makespan=design.makespan, max_abs_err_opt=err_opt,
        max_abs_err_ref=err_ref, max_abs_err_quant=err_quant,
        max_abs_err_jax=err_jax, build_seconds=build_s, passed=passed)
