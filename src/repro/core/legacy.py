"""The historical per-``Op`` (object-graph) compiler implementations.

These are the literal PR-3 algorithms — pass rewrites driven one ``Op`` at a
time through ``passes.Rewriter``, the per-op list scheduler, and the per-op
functional simulator.  They are kept for two reasons:

  * **Golden equivalence.**  The vectorised struct-of-arrays hot path in
    ``passes`` / ``schedule`` / ``emit`` must produce *bit-identical*
    op streams, schedules and evaluations.  The golden suite
    (``tests/test_golden_equivalence.py``) runs every workload through both
    paths and compares exactly.
  * **Escape hatch.**  Setting ``REPRO_LEGACY_IR=1`` in the environment
    routes ``passes.*``, ``schedule.list_schedule`` and ``emit.evaluate``
    through these implementations at call time — a live A/B switch when
    debugging a suspected vectorisation fault.

Everything here consumes the SoA ``Graph`` through its ``ops`` record view,
so the two paths share one IR type, one fingerprint, and one design cache.
"""

from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from repro.core.ir import (ARITH_OPS, DEFAULT_DELAYS, RESOURCE_CLASS, Graph,
                           Op)

# ---------------------------------------------------------------------------
# Passes (paper §3.2) — the object-graph originals
# ---------------------------------------------------------------------------


def _rewriter(g: Graph):
    from repro.core.passes import Rewriter  # deferred: passes imports us lazily
    return Rewriter(g)


def dce(g: Graph) -> Graph:
    """Dead-code elimination backwards from graph outputs.

    ``store`` ops are always considered live (baseline no-forwarding mode
    models a tool that cannot eliminate memory traffic).
    """
    live_vals = set(g.output_values())
    keep = [False] * g.n_ops
    for op in reversed(g.ops):
        if op.opcode == "store" or (op.result >= 0 and op.result in live_vals):
            keep[op.idx] = True
            live_vals.update(op.args)
    rw = _rewriter(g)
    for op in g.ops:
        if keep[op.idx]:
            rw.keep(op)
    return rw.finish()


def cse(g: Graph) -> Graph:
    """Common-subexpression elimination (commutative-aware)."""
    commutative = {"mulf", "addf", "maxf", "minf"}
    seen: dict[tuple, int] = {}
    rw = _rewriter(g)
    for op in g.ops:
        if op.opcode not in ARITH_OPS:
            rw.keep(op)
            continue
        args = tuple(rw.lookup(a) for a in op.args)
        key_args = tuple(sorted(args)) if op.opcode in commutative else args
        key = (op.opcode, key_args)
        hit = seen.get(key)
        if hit is not None:
            rw.replace(op.result, hit)
        else:
            seen[key] = op.result
            rw.keep(op, args=args)
    return rw.finish()


def relu_recompose(g: Graph) -> Graph:
    """select(cmpf_ugt(x, 0), x, 0) -> relu(x)   (paper §3.2 item 2)."""
    uses = g.use_counts()
    zero_consts = {vid for vid, v in g.consts.items() if v == 0.0}
    # result vid -> (op, x vid) for candidate compares
    cmps: dict[int, tuple[Op, int]] = {}
    for op in g.ops:
        if (op.opcode == "cmpugt" and len(op.args) == 2
                and op.args[1] in zero_consts):
            cmps[op.result] = (op, op.args[0])
    dead_cmp: set[int] = set()
    rw = _rewriter(g)
    for op in g.ops:
        if op.opcode == "select" and op.args[0] in cmps:
            cmp_op, x = cmps[op.args[0]]
            if op.args[1] == x and op.args[2] in zero_consts:
                rw.emit("relu", (x,), nest=op.nest, rank=op.rank,
                        result=op.result)
                if uses[cmp_op.result] == 1:
                    dead_cmp.add(cmp_op.idx)
                continue
        rw.keep(op)
    out = rw.finish()
    if dead_cmp:
        out = dce(out)
    return out


def reduction_tree(g: Graph, *, threshold: int = 4) -> Graph:
    """Rebalance sequential reduction chains into binary trees (§3.2 item 4).

    A chain is a maximal run  o_1, ..., o_n  of the same associative opcode
    where each o_{t+1} consumes o_t's result and that result has no other
    use.  The chain is replaced by a balanced tree over its leaves, halving
    depth from O(n) to O(log n) — the dominant latency lever for the inner
    reduction loops of conv/linear layers.
    """
    associative = {"addf", "maxf", "minf"}
    uses = g.use_counts()
    ops = list(g.ops)
    # chain_next[i] = op idx of the chain continuation of op i (or -1)
    chain_next = [-1] * len(ops)
    chain_prev = [-1] * len(ops)
    producer = g.producer
    for op in ops:
        if op.opcode not in associative:
            continue
        for a in op.args:
            p = producer[a]
            if p < 0:
                continue
            pred = ops[p]
            if (pred.opcode == op.opcode and uses[pred.result] == 1
                    and pred.nest == op.nest and pred.rank == op.rank):
                chain_next[p] = op.idx
                chain_prev[op.idx] = p
                break  # at most one chain predecessor
    in_chain = [False] * len(ops)
    chains: list[list[int]] = []  # lists of op idxs, head first
    for op in ops:
        if chain_prev[op.idx] >= 0 or chain_next[op.idx] < 0:
            continue  # not a chain head
        run = [op.idx]
        cur = op.idx
        while chain_next[cur] >= 0:
            cur = chain_next[cur]
            run.append(cur)
        if len(run) >= threshold - 1:  # n ops reduce n+1 leaves
            chains.append(run)
            for i in run:
                in_chain[i] = True

    tail_to_chain = {run[-1]: run for run in chains}
    rw = _rewriter(g)
    for op in ops:
        if in_chain[op.idx] and op.idx not in tail_to_chain:
            continue  # interior chain op: dropped, replaced at the tail
        if op.idx in tail_to_chain:
            run = tail_to_chain[op.idx]
            opcode = op.opcode
            # collect leaves in chain order
            leaves: list[int] = []
            chain_results = {ops[i].result for i in run}
            first = ops[run[0]]
            leaves.extend(first.args)
            for i in run[1:]:
                for a in ops[i].args:
                    if a not in chain_results:
                        leaves.append(a)
            # balanced pairwise tree
            level = leaves
            while len(level) > 1:
                nxt: list[int] = []
                for i in range(0, len(level) - 1, 2):
                    if len(level) == 2:
                        # root of the tree takes over the chain's result id
                        vid = rw.emit(opcode, (level[i], level[i + 1]),
                                      nest=op.nest, rank=op.rank,
                                      result=op.result)
                    else:
                        vid = rw.emit(opcode, (level[i], level[i + 1]),
                                      nest=op.nest, rank=op.rank)
                    nxt.append(vid)
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            continue
        rw.keep(op)
    return rw.finish()


def fmac_coalesce(g: Graph) -> Graph:
    """addf(a, mulf(b, c)) with single-use mul -> fmac(b, c, a) (§3.2 item 3)."""
    uses = g.use_counts()
    muls: dict[int, Op] = {}
    for op in g.ops:
        if op.opcode == "mulf" and uses[op.result] == 1:
            muls[op.result] = op
    fused_muls: set[int] = set()
    rw = _rewriter(g)
    for op in g.ops:
        if op.idx in fused_muls:
            continue
        if op.opcode == "addf":
            a0, a1 = op.args
            mul = None
            addend = None
            if a1 in muls:
                mul, addend = muls[a1], a0
            elif a0 in muls:
                mul, addend = muls[a0], a1
            if mul is not None:
                rw.emit("fmac", (mul.args[0], mul.args[1], addend),
                        nest=op.nest, rank=op.rank, result=op.result)
                fused_muls.add(mul.idx)
                continue
        rw.keep(op)
    out = rw.finish()
    return dce(out)


LEGACY_PASSES = {
    "cse": cse,
    "dce": dce,
    "relu_recompose": relu_recompose,
    "reduction_tree": reduction_tree,
    "fmac_coalesce": fmac_coalesce,
}


# ---------------------------------------------------------------------------
# Scheduling (paper §3.3) — the per-op original
# ---------------------------------------------------------------------------


class _UnitPool:
    """Earliest-free-unit allocator with lazy instantiation up to capacity."""

    __slots__ = ("capacity", "heap", "allocated")

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self.heap: list[tuple[int, int]] = []  # (free_time, unit_id)
        self.allocated = 0

    def acquire(self, t_ready: int, occupancy: int) -> tuple[int, int]:
        """Returns (start_time, unit_id)."""
        if self.heap and self.heap[0][0] <= t_ready:
            _, uid = heapq.heappop(self.heap)
            start = t_ready
        elif self.allocated < self.capacity:
            uid = self.allocated
            self.allocated += 1
            start = t_ready
        else:
            free, uid = heapq.heappop(self.heap)
            start = max(free, t_ready)
        heapq.heappush(self.heap, (start + occupancy, uid))
        return start, uid


def list_schedule(
    g: Graph,
    *,
    binding: str = "pool",
    unroll_factor: Optional[int] = None,
    ports_per_array: int = 2,
    pipelined_units: bool = False,
    delays: Optional[dict[str, int]] = None,
    alap_compact: bool = True,
):
    """The historical per-op list scheduler (see ``schedule.list_schedule``)."""
    from repro.core.schedule import Schedule
    assert binding in ("pool", "rank"), binding
    delays = delays or DEFAULT_DELAYS
    ops = list(g.ops)
    n = len(ops)
    start = [0] * n
    ready_at = [0] * g.n_values
    keys: list[Optional[tuple]] = [None] * n  # op -> (class, unit) binding

    K = g.K() if unroll_factor is None else max(1, unroll_factor)
    pools: dict[str, _UnitPool] = {}
    port_pools: dict[str, _UnitPool] = {}
    unit_free: dict[tuple, int] = {}   # rank-binding mode
    units_used: dict[str, set] = {}

    for op in ops:
        d = delays.get(op.opcode, 0)
        occ = 1 if pipelined_units else max(d, 1)
        t = 0
        for a in op.args:
            ta = ready_at[a]
            if ta > t:
                t = ta
        cls = RESOURCE_CLASS.get(op.opcode)
        if cls == "port":
            pool = port_pools.get(op.array)
            if pool is None:
                pool = port_pools[op.array] = _UnitPool(ports_per_array)
            t, uid = pool.acquire(t, occ)
            keys[op.idx] = ("port", op.array, uid)
            units_used.setdefault("port", set()).add((op.array, uid))
        elif cls is not None:
            if binding == "pool":
                pool = pools.get(cls)
                if pool is None:
                    pool = pools[cls] = _UnitPool(K)
                t, uid = pool.acquire(t, occ)
                keys[op.idx] = (cls, uid)
                units_used.setdefault(cls, set()).add(uid)
            else:
                k_i = g.nest_parallel_space.get(op.nest, 1)
                lanes = k_i if unroll_factor is None else max(
                    1, min(unroll_factor, k_i))
                rank = op.rank if op.rank >= 0 else 0
                key = (cls, rank % lanes)
                tf = unit_free.get(key, 0)
                if tf > t:
                    t = tf
                unit_free[key] = t + occ
                keys[op.idx] = key
                units_used.setdefault(cls, set()).add(key)
        start[op.idx] = t
        if op.result >= 0:
            ready_at[op.result] = t + d

    makespan = 0
    for op in ops:
        end = start[op.idx] + delays.get(op.opcode, 0)
        if end > makespan:
            makespan = end

    if alap_compact:
        start = _alap_compact(g, ops, start, makespan, delays,
                              pipelined_units, keys)

    nest_spans: dict[int, tuple[int, int]] = {}
    for op in ops:
        s = start[op.idx]
        e = s + delays.get(op.opcode, 0)
        lo, hi = nest_spans.get(op.nest, (s, e))
        nest_spans[op.nest] = (min(lo, s), max(hi, e))

    peak_live = _peak_live_values(g, ops, start, delays)
    units = {c: len(k) for c, k in units_used.items()}
    return Schedule(start=start, makespan=makespan, resource_units=units,
                    nest_spans=nest_spans, peak_live=peak_live, n_ops=n)


def _alap_compact(g: Graph, ops: list[Op], start: list[int], makespan: int,
                  delays: dict[str, int], pipelined_units: bool,
                  keys: list[Optional[tuple]]) -> list[int]:
    """Retime ops as late as possible without growing the makespan."""
    new_start = list(start)
    latest = [makespan] * g.n_values
    next_same_key: dict[int, int] = {}
    last_seen: dict[tuple, int] = {}
    for op in reversed(ops):
        k = keys[op.idx]
        if k is not None:
            if k in last_seen:
                next_same_key[op.idx] = last_seen[k]
            last_seen[k] = op.idx
    for op in reversed(ops):
        d = delays.get(op.opcode, 0)
        limit = makespan - d
        if op.result >= 0:
            limit = min(limit, latest[op.result] - d)
        nxt = next_same_key.get(op.idx)
        if nxt is not None:
            occupancy = 1 if pipelined_units else max(d, 1)
            limit = min(limit, new_start[nxt] - occupancy)
        t = new_start[op.idx]
        if limit > t:
            t = limit
        new_start[op.idx] = t
        for a in op.args:
            if t < latest[a]:
                latest[a] = t
    return new_start


def _peak_live_values(g: Graph, ops: list[Op], start: list[int],
                      delays: dict[str, int]) -> int:
    """Peak number of simultaneously live values — the FF-usage analogue."""
    last_use: dict[int, int] = {}
    born: dict[int, int] = {}
    for op in ops:
        if op.result >= 0:
            born[op.result] = start[op.idx] + delays.get(op.opcode, 0)
        for a in op.args:
            t = start[op.idx]
            if last_use.get(a, -1) < t:
                last_use[a] = t
    events: list[tuple[int, int]] = []
    for vid, b in born.items():
        e = last_use.get(vid)
        if e is None or e < b:
            continue
        events.append((b, 1))
        events.append((e + 1, -1))
    events.sort()
    live = peak = 0
    for _, delta in events:
        live += delta
        if live > peak:
            peak = live
    return peak


# ---------------------------------------------------------------------------
# Functional simulation — the per-op original
# ---------------------------------------------------------------------------


def evaluate(g: Graph, vals: dict[int, np.ndarray], batch: int,
             q) -> dict[int, np.ndarray]:
    """Per-op program-order simulation over pre-scattered input vectors.

    ``vals`` maps value id -> (batch,) float32 vector (inputs and constants
    already quantised by the caller); returns the same dict filled with
    every computed value.  The caller (``emit.evaluate``) assembles output
    tensors — shared with the vectorised path so the two only differ in how
    the op stream is executed.
    """
    for op in g.ops:
        a = op.args
        oc = op.opcode
        if oc == "mulf":
            r = vals[a[0]] * vals[a[1]]
        elif oc == "addf":
            r = vals[a[0]] + vals[a[1]]
        elif oc == "subf":
            r = vals[a[0]] - vals[a[1]]
        elif oc == "divf":
            r = vals[a[0]] / vals[a[1]]
        elif oc == "sqrtf":
            r = np.sqrt(vals[a[0]])
        elif oc == "maxf":
            r = np.maximum(vals[a[0]], vals[a[1]])
        elif oc == "minf":
            r = np.minimum(vals[a[0]], vals[a[1]])
        elif oc == "negf":
            r = -vals[a[0]]
        elif oc == "relu":
            r = np.maximum(vals[a[0]], 0.0)
        elif oc == "fmac":
            # fmac(b, c, a) = b*c + a, rounded once (fused on FPGA)
            r = vals[a[0]] * vals[a[1]] + vals[a[2]]
        elif oc == "cmpugt":
            r = (vals[a[0]] > vals[a[1]]).astype(np.float32)
        elif oc == "select":
            r = np.where(vals[a[0]] > 0.5, vals[a[1]], vals[a[2]])
        elif oc == "load":
            r = vals[a[0]]
        elif oc == "store":
            r = vals[a[0]]
        elif oc == "copy":
            r = vals[a[0]]
        else:  # pragma: no cover
            raise NotImplementedError(oc)
        if oc not in ("cmpugt", "load", "store", "copy"):
            r = q(r)
        if op.result >= 0:
            vals[op.result] = r
    return vals
