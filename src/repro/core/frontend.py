"""Loop-nest (scf-dialect-style) definitions of DNN layers (paper §2.1, §3).

Each function here is the Python translation of the scf-dialect lowering of a
DNN operation (paper Listing 3 -> Listing 1 correspondence), executed under
the symbolic interpreter.  Outer *parallel* loops use ``ctx.parallel`` (the
scf.parallel form, Listing 4) — their iteration space is the resource binding
K_i.  Inner reduction loops are plain Python ``for`` loops whose sequential
add chains the reduction-tree pass later balances (paper §3.2 item 4).

All layers read and write memrefs through explicit loads/stores on the
output array (as in Listing 1), so store-load forwarding is genuinely
exercised rather than side-stepped.
"""

from __future__ import annotations

from typing import Optional

from repro.core.interp import Context, MemRef, SymVal


# ---------------------------------------------------------------------------
# Paper §4.1 layer suite
# ---------------------------------------------------------------------------

def conv2d(ctx: Context, inp: MemRef, weight: MemRef, bias: Optional[MemRef],
           out: MemRef, *, stride: int = 1, padding: int = 0,
           label: str = "conv2d") -> None:
    """2D convolution with bias (paper Listing 1 / Listing 4).

    inp:    (B, Cin, H, W)
    weight: (Cout, Cin, k, k)
    bias:   (Cout,) or None
    out:    (B, Cout, Ho, Wo)
    """
    b, c_in, h, w = inp.shape
    c_out, c_in2, k, k2 = weight.shape
    assert c_in == c_in2 and k == k2, (inp.shape, weight.shape)
    bo, co, ho, wo = out.shape
    assert bo == b and co == c_out
    for (i1, i2, i3, i4) in ctx.parallel(b, c_out, ho, wo, label=label):
        # initialise the accumulator slot (bias or zero), then accumulate
        # through load/store pairs on the output array — the forwarding
        # opportunity of paper Listing 2.
        out[i1, i2, i3, i4] = bias[i2] if bias is not None else ctx.const(0.0)
        for i5 in range(c_in):
            for i6 in range(k):
                for i7 in range(k):
                    i3s = i3 * stride + i6 - padding
                    i4s = i4 * stride + i7 - padding
                    if not (0 <= i3s < h and 0 <= i4s < w):
                        continue  # zero-pad taps contribute nothing
                    x = inp[i1, i5, i3s, i4s]
                    f = weight[i2, i5, i6, i7]
                    acc = out[i1, i2, i3, i4]
                    out[i1, i2, i3, i4] = acc + x * f


def addmm(ctx: Context, a: MemRef, b: MemRef, c: MemRef, out: MemRef,
          *, label: str = "addmm") -> None:
    """out = a @ b + c.   a: (M, K), b: (K, N), c: (M, N), out: (M, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    for (i, j) in ctx.parallel(m, n, label=label):
        out[i, j] = c[i, j]
        for p in range(k):
            out[i, j] = out[i, j] + a[i, p] * b[p, j]


def matmul(ctx: Context, a: MemRef, b: MemRef, out: MemRef,
           *, label: str = "matmul") -> None:
    """out = a @ b (no addend)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    for (i, j) in ctx.parallel(m, n, label=label):
        out[i, j] = ctx.const(0.0)
        for p in range(k):
            out[i, j] = out[i, j] + a[i, p] * b[p, j]


def batch_norm_2d(ctx: Context, inp: MemRef, gamma: MemRef, beta: MemRef,
                  mean: MemRef, var: MemRef, out: MemRef, *,
                  eps: float = 1e-5, label: str = "batch_norm_2d") -> None:
    """Inference-mode batchnorm over a 4D input (paper Table 1).

    out = gamma * (x - mean) / sqrt(var + eps) + beta — exercises subf,
    divf and sqrtf, per the paper's op-coverage rationale.
    """
    b, c, h, w = inp.shape
    for (i1, i2, i3, i4) in ctx.parallel(b, c, h, w, label=label):
        denom = (var[i2] + ctx.const(eps)).sqrt()
        out[i1, i2, i3, i4] = gamma[i2] * (inp[i1, i2, i3, i4] - mean[i2]) / denom + beta[i2]


def max_pool_2d(ctx: Context, inp: MemRef, out: MemRef, *, k: int,
                stride: int, label: str = "max_pool_2d") -> None:
    """k x k max pooling with striding; max chains become reduction trees."""
    b, c, h, w = inp.shape
    bo, co, ho, wo = out.shape
    assert bo == b and co == c
    for (i1, i2, i3, i4) in ctx.parallel(b, c, ho, wo, label=label):
        acc: Optional[SymVal] = None
        for i5 in range(k):
            for i6 in range(k):
                i3s, i4s = i3 * stride + i5, i4 * stride + i6
                if not (0 <= i3s < h and 0 <= i4s < w):
                    continue
                x = inp[i1, i2, i3s, i4s]
                acc = x if acc is None else acc.max(x)
        assert acc is not None
        out[i1, i2, i3, i4] = acc


def soft_max(ctx: Context, inp: MemRef, out: MemRef, *,
             taylor_order: int = 8, range_reduce: int = 2,
             label: str = "soft_max") -> None:
    """Softmax over the last axis, numerically stabilised by max-subtraction.

    Lowered the way linalg decomposes softmax — four loop nests:
      1. row-parallel max reduction;
      2. element-parallel subtract + exp (k-th-order Taylor with 2^r range
         reduction, paper §3: exp(x) = exp(x/2^r)^(2^r));
      3. row-parallel sum reduction;
      4. element-parallel divide.
    The element-parallel nests expose the full K_i = prod(shape) binding;
    the reduction nests expose the rows and leave the inner chain to the
    reduction-tree pass.
    """
    *outer, n = inp.shape
    outer = tuple(outer) or (1,)
    flat = outer  # parallel space of the row nests
    assert tuple(out.shape) == tuple(inp.shape)

    def row(idx):
        return idx if len(inp.shape) > 1 else ()

    # 1) max reduction per row
    mx = ctx.temp(f"{label}_max_{id(inp)}", outer)
    for idx in ctx.parallel(*flat, label=f"{label}.max"):
        acc = inp[row(idx) + (0,)]
        for j in range(1, n):
            acc = acc.max(inp[row(idx) + (j,)])
        mx[idx] = acc

    # 2) elementwise exp(x - max)
    exps = ctx.temp(f"{label}_exp_{id(inp)}", tuple(inp.shape))
    scale = ctx.const(1.0 / (1 << range_reduce))
    for idx in ctx.parallel(*outer, n, label=f"{label}.exp"):
        r, j = idx[:-1], idx[-1]
        src = row(r) + (j,) if len(inp.shape) > 1 else (j,)
        z = (inp[src] - mx[r]) * scale
        e = ctx.exp(z, order=taylor_order)
        for _ in range(range_reduce):
            e = e * e
        exps[src] = e

    # 3) sum reduction per row
    sums = ctx.temp(f"{label}_sum_{id(inp)}", outer)
    for idx in ctx.parallel(*flat, label=f"{label}.sum"):
        acc = exps[row(idx) + (0,)]
        for j in range(1, n):
            acc = acc + exps[row(idx) + (j,)]
        sums[idx] = acc

    # 4) elementwise normalise
    for idx in ctx.parallel(*outer, n, label=f"{label}.div"):
        r, j = idx[:-1], idx[-1]
        src = row(r) + (j,) if len(inp.shape) > 1 else (j,)
        out[src] = exps[src] / sums[r]


# ---------------------------------------------------------------------------
# Additional building blocks for BraggNN
# ---------------------------------------------------------------------------

def linear(ctx: Context, inp: MemRef, weight: MemRef, bias: Optional[MemRef],
           out: MemRef, *, label: str = "linear") -> None:
    """out = inp @ weight.T + bias.   inp: (B, K), weight: (N, K), out: (B, N)."""
    b, k = inp.shape
    n, k2 = weight.shape
    assert k == k2
    for (i, j) in ctx.parallel(b, n, label=label):
        out[i, j] = bias[j] if bias is not None else ctx.const(0.0)
        for p in range(k):
            out[i, j] = out[i, j] + inp[i, p] * weight[j, p]


def relu_layer(ctx: Context, inp: MemRef, out: MemRef, *,
               label: str = "relu") -> None:
    """Elementwise ReLU, emitted as cmpf+select (scf lowering form) and later
    recomposed by the relu_recompose pass (paper §3.2 item 2)."""
    assert tuple(inp.shape) == tuple(out.shape)
    for idx in ctx.parallel(*inp.shape, label=label):
        out[idx] = ctx.relu(inp[idx])


def copy_reshape(src: MemRef, dst: MemRef) -> None:
    """Zero-cost reshape: move symbols between geometric symbol tables.

    No ops are emitted — a reshape is pure index arithmetic on an FPGA
    (rewiring), exactly as in the paper's flattening between conv and dense
    stages.
    """
    import itertools
    import numpy as np
    src_idx = list(itertools.product(*[range(d) for d in src.shape]))
    dst_idx = list(itertools.product(*[range(d) for d in dst.shape]))
    assert len(src_idx) == len(dst_idx), (src.shape, dst.shape)
    for si, di in zip(src_idx, dst_idx):
        dst.table[di] = src[si]
    del np


# ---------------------------------------------------------------------------
# BraggNN (paper Listing 5, s=1 or 2) as a full scalar program
# ---------------------------------------------------------------------------

def non_local_block(ctx: Context, feat: MemRef, *, channels: int,
                    mid_channels: int, prefix: str = "nlb",
                    taylor_order: int = 8) -> MemRef:
    """BraggNN's non-local attention block (paper Listing 5, NLB section).

    feat: (1, channels, h, w) -> returns the residual output memref of the
    same shape.  theta/phi/g are 1x1 convs to ``mid_channels``; attention
    is softmax(theta^T phi) over the h*w spatial positions; the out conv
    projects back to ``channels`` and a residual add closes the block.

    ``prefix`` names the weight memrefs (``{prefix}.theta.weight`` ...) and
    nest labels — shared by the hand-written :func:`braggnn` program and
    the nn-module bridge (``repro.hls.bridge``), which therefore emit
    bit-identical DFGs.
    """
    c1, c2 = channels, mid_channels
    _, c_in, h1, w1 = feat.shape
    assert c_in == c1 and h1 == w1, feat.shape
    n_pos = h1 * h1

    thetas = {}
    for name in ("theta", "phi", "g"):
        w = ctx.memref(f"{prefix}.{name}.weight", (c2, c1, 1, 1), "weight")
        o = ctx.temp(f"{prefix}_{name}", (1, c2, h1, h1))
        conv2d(ctx, feat, w, None, o, label=f"{prefix}.{name}_layer")
        thetas[name] = o
    theta, phi, g = thetas["theta"], thetas["phi"], thetas["g"]

    # attention scores A[i, j] = sum_c theta[c, i] * phi[c, j]
    scores = ctx.temp(f"{prefix}_scores", (n_pos, n_pos))
    for (i, j) in ctx.parallel(n_pos, n_pos, label=f"{prefix}.scores"):
        ih, iw = divmod(i, h1)
        jh, jw = divmod(j, h1)
        scores[i, j] = ctx.const(0.0)
        for c in range(c2):
            scores[i, j] = scores[i, j] + theta[0, c, ih, iw] * phi[0, c, jh, jw]

    attn = ctx.temp(f"{prefix}_attn", (n_pos, n_pos))
    soft_max(ctx, scores, attn, taylor_order=taylor_order,
             label=f"{prefix}.soft")

    # y[c, i] = sum_j A[i, j] * g[c, j]
    y = ctx.temp(f"{prefix}_y", (1, c2, h1, h1))
    for (c, i) in ctx.parallel(c2, n_pos, label=f"{prefix}.aggregate"):
        ih, iw = divmod(i, h1)
        y[0, c, ih, iw] = ctx.const(0.0)
        for j in range(n_pos):
            jh, jw = divmod(j, h1)
            y[0, c, ih, iw] = y[0, c, ih, iw] + attn[i, j] * g[0, c, jh, jw]

    # out_cnn (1x1, c2 -> c1) + residual
    w_out = ctx.memref(f"{prefix}.out_cnn.weight", (c1, c2, 1, 1), "weight")
    z = ctx.temp(f"{prefix}_z", (1, c1, h1, h1))
    conv2d(ctx, y, w_out, None, z, label=f"{prefix}.out_cnn")
    nlb_out = ctx.temp(f"{prefix}_out", (1, c1, h1, h1))
    for (i1, i2, i3, i4) in ctx.parallel(1, c1, h1, h1,
                                         label=f"{prefix}.residual"):
        nlb_out[i1, i2, i3, i4] = z[i1, i2, i3, i4] + feat[i1, i2, i3, i4]
    return nlb_out


def braggnn(ctx: Context, *, s: int = 1, img: int = 11,
            taylor_order: int = 8) -> None:
    """Build the complete BraggNN(s) DFG on an (1, 1, img, img) input patch.

    Architecture (paper Listing 5):
      conv1:  Conv2d(1 -> 16s, k=3)                      -> (16s, 9, 9)
      NLB:    theta/phi/g 1x1 convs 16s -> 8s; A = softmax(theta^T phi);
              y = A g^T; out_cnn 1x1 8s -> 16s; residual  -> (16s, 9, 9)
      cnn2:   ReLU, Conv2d(16s -> 8s, k=3), ReLU, Conv2d(8s -> 2s, k=3), ReLU
                                                          -> (2s, 5, 5)
      dense:  50s -> 16s -> 8s -> 4s -> 2 with ReLUs (flatten = rewiring)
    """
    c1, c2 = 16 * s, 8 * s
    h1 = img - 2                      # conv1 output spatial (valid, k=3)

    x = ctx.memref("input", (1, 1, img, img), "input")

    # --- cnn_layers_1 ------------------------------------------------------
    w_conv1 = ctx.memref("conv1.weight", (c1, 1, 3, 3), "weight")
    b_conv1 = ctx.memref("conv1.bias", (c1,), "weight")
    feat = ctx.temp("feat", (1, c1, h1, h1))
    conv2d(ctx, x, w_conv1, b_conv1, feat, label="cnn_layers_1")

    # --- NLB ----------------------------------------------------------------
    nlb_out = non_local_block(ctx, feat, channels=c1, mid_channels=c2,
                              taylor_order=taylor_order)

    # --- cnn_layers_2 -------------------------------------------------------
    r0 = ctx.temp("cnn2_relu0", (1, c1, h1, h1))
    relu_layer(ctx, nlb_out, r0, label="cnn_layers_2.relu0")
    w_c2a = ctx.memref("cnn2.conv1.weight", (c2, c1, 3, 3), "weight")
    b_c2a = ctx.memref("cnn2.conv1.bias", (c2,), "weight")
    h2 = h1 - 2
    c2a = ctx.temp("cnn2_conv1", (1, c2, h2, h2))
    conv2d(ctx, r0, w_c2a, b_c2a, c2a, label="cnn_layers_2.conv1")
    r1 = ctx.temp("cnn2_relu1", (1, c2, h2, h2))
    relu_layer(ctx, c2a, r1, label="cnn_layers_2.relu1")
    w_c2b = ctx.memref("cnn2.conv2.weight", (2 * s, c2, 3, 3), "weight")
    b_c2b = ctx.memref("cnn2.conv2.bias", (2 * s,), "weight")
    h3 = h2 - 2
    c2b = ctx.temp("cnn2_conv2", (1, 2 * s, h3, h3))
    conv2d(ctx, r1, w_c2b, b_c2b, c2b, label="cnn_layers_2.conv2")
    r2 = ctx.temp("cnn2_relu2", (1, 2 * s, h3, h3))
    relu_layer(ctx, c2b, r2, label="cnn_layers_2.relu2")

    # --- dense_layers -------------------------------------------------------
    n_flat = 2 * s * h3 * h3
    flat = ctx.temp("flat", (1, n_flat))
    copy_reshape(r2, flat)

    dims = [n_flat, 16 * s, 8 * s, 4 * s, 2]
    cur = flat
    for li in range(4):
        w = ctx.memref(f"dense.{li}.weight", (dims[li + 1], dims[li]), "weight")
        bb = ctx.memref(f"dense.{li}.bias", (dims[li + 1],), "weight")
        kind = "output" if li == 3 else "temp"
        nxt = ctx.memref(f"dense_{li}_out", (1, dims[li + 1]), kind)
        linear(ctx, cur, w, bb, nxt, label=f"dense.{li}")
        if li < 3:
            r = ctx.temp(f"dense_{li}_relu", (1, dims[li + 1]))
            relu_layer(ctx, nxt, r, label=f"dense.{li}.relu")
            cur = r
        else:
            # final ReLU writes the output memref
            pass
    # paper Listing 5 ends with a ReLU after the last linear; peak centre
    # coordinates are non-negative so this is safe.  Re-bind output through
    # a relu by rewriting the output table in-place.
    out_mem = ctx.memrefs["dense_3_out"]
    for idx in list(out_mem.table.keys()):
        with ctx.sequential(label="dense.final_relu"):
            out_mem.table[idx] = ctx.relu(out_mem.table[idx])


# ---------------------------------------------------------------------------
# Transformer encoder block layers (sequence-model vocabulary)
# ---------------------------------------------------------------------------

def rms_norm(ctx: Context, inp: MemRef, gamma: MemRef, out: MemRef, *,
             eps: float = 1e-5, label: str = "rms_norm") -> None:
    """RMS normalisation over the last axis: out = x * gamma / rms(x).

    inp/out: (L, D), gamma: (D,).  Three nests: a row-parallel
    sum-of-squares reduction, a row-parallel reciprocal-rms
    (1/sqrt(ms/D + eps)), and an element-parallel scale.  The sequential
    square-sum chain is balanced by the reduction-tree pass.
    """
    l, d = inp.shape
    assert tuple(out.shape) == (l, d), (inp.shape, out.shape)
    assert tuple(gamma.shape) == (d,), gamma.shape

    ms = ctx.temp(f"{label}_ms_{id(inp)}", (l,))
    for idx in ctx.parallel(l, label=f"{label}.ss"):
        acc: Optional[SymVal] = None
        for j in range(d):
            x = inp[idx + (j,)]
            t = x * x
            acc = t if acc is None else acc + t
        assert acc is not None
        ms[idx] = acc

    rinv = ctx.temp(f"{label}_rinv_{id(inp)}", (l,))
    one = ctx.const(1.0)
    inv_d = ctx.const(1.0 / d)
    c_eps = ctx.const(eps)
    for idx in ctx.parallel(l, label=f"{label}.rinv"):
        rinv[idx] = one / (ms[idx] * inv_d + c_eps).sqrt()

    for (i, j) in ctx.parallel(l, d, label=f"{label}.scale"):
        out[i, j] = inp[i, j] * rinv[i] * gamma[j]


def attention(ctx: Context, inp: MemRef, wq: MemRef, wk: MemRef, wv: MemRef,
              wo: MemRef, out: MemRef, *, n_heads: int,
              taylor_order: int = 8, label: str = "attn") -> None:
    """Multi-head bidirectional self-attention (encoder form, no mask).

    inp/out: (L, D); wq/wk/wv: (D, H, dh); wo: (H, dh, D) with D = H*dh
    (the ``repro.nn.attention.attn_specs`` layout).  Scores are scaled by
    1/sqrt(dh) and softmaxed per head-row with the paper's Taylor-exp
    functional model (:func:`soft_max` on an (H*L, L) memref).
    """
    import math

    l, d = inp.shape
    h = n_heads
    dh = d // h
    assert h * dh == d, (d, h)
    assert tuple(out.shape) == (l, d), out.shape
    for w in (wq, wk, wv):
        assert tuple(w.shape) == (d, h, dh), w.shape
    assert tuple(wo.shape) == (h, dh, d), wo.shape

    # q/k/v projections: (L, D) x (D, H, dh) -> (L, H, dh)
    proj = {}
    for nm, w in (("q", wq), ("k", wk), ("v", wv)):
        o = ctx.temp(f"{label}_{nm}_{id(inp)}", (l, h, dh))
        for (i, hh, kk) in ctx.parallel(l, h, dh, label=f"{label}.{nm}"):
            acc: Optional[SymVal] = None
            for p in range(d):
                t = inp[i, p] * w[p, hh, kk]
                acc = t if acc is None else acc + t
            assert acc is not None
            o[i, hh, kk] = acc
        proj[nm] = o
    q, k, v = proj["q"], proj["k"], proj["v"]

    # scores[h*L + i, j] = (q_i . k_j) / sqrt(dh), one softmax row per
    # (head, query) pair so soft_max sees a plain 2-D memref
    scale = ctx.const(1.0 / math.sqrt(dh))
    scores = ctx.temp(f"{label}_scores_{id(inp)}", (h * l, l))
    for (hh, i, j) in ctx.parallel(h, l, l, label=f"{label}.scores"):
        acc = None
        for kk in range(dh):
            t = q[i, hh, kk] * k[j, hh, kk]
            acc = t if acc is None else acc + t
        assert acc is not None
        scores[hh * l + i, j] = acc * scale

    attn = ctx.temp(f"{label}_attn_{id(inp)}", (h * l, l))
    soft_max(ctx, scores, attn, taylor_order=taylor_order,
             label=f"{label}.soft")

    # per-head mix: y[i, h, k] = sum_j attn[h*L + i, j] * v[j, h, k]
    y = ctx.temp(f"{label}_y_{id(inp)}", (l, h, dh))
    for (i, hh, kk) in ctx.parallel(l, h, dh, label=f"{label}.mix"):
        acc = None
        for j in range(l):
            t = attn[hh * l + i, j] * v[j, hh, kk]
            acc = t if acc is None else acc + t
        assert acc is not None
        y[i, hh, kk] = acc

    # out-projection back to (L, D)
    for (i, dd) in ctx.parallel(l, d, label=f"{label}.out"):
        acc = None
        for hh in range(h):
            for kk in range(dh):
                t = y[i, hh, kk] * wo[hh, kk, dd]
                acc = t if acc is None else acc + t
        assert acc is not None
        out[i, dd] = acc


def mlp(ctx: Context, inp: MemRef, w1: MemRef, b1: MemRef, w2: MemRef,
        b2: MemRef, out: MemRef, *, label: str = "mlp") -> None:
    """Position-wise feed-forward: relu(x @ w1.T + b1) @ w2.T + b2.

    inp/out: (L, D); w1: (hidden, D), w2: (D, hidden) — the
    :func:`linear` (N, K) weight layout applied per sequence position.
    """
    l, d = inp.shape
    hidden, d2 = w1.shape
    assert d == d2, (inp.shape, w1.shape)
    assert tuple(w2.shape) == (d, hidden), w2.shape
    assert tuple(out.shape) == (l, d), out.shape

    hid = ctx.temp(f"{label}_fc1_{id(inp)}", (l, hidden))
    linear(ctx, inp, w1, b1, hid, label=f"{label}.fc1")
    act = ctx.temp(f"{label}_act_{id(inp)}", (l, hidden))
    relu_layer(ctx, hid, act, label=f"{label}.act")
    linear(ctx, act, w2, b2, out, label=f"{label}.fc2")


def add_residual(ctx: Context, a: MemRef, b: MemRef, out: MemRef, *,
                 label: str = "residual") -> None:
    """Elementwise residual add: out = a + b."""
    assert tuple(a.shape) == tuple(b.shape) == tuple(out.shape)
    for idx in ctx.parallel(*a.shape, label=label):
        out[idx] = a[idx] + b[idx]


def transformer_encoder_block(ctx: Context, *, seq: int = 16,
                              d_model: int = 64, n_heads: int = 4,
                              ffn: int = 256, taylor_order: int = 8,
                              eps: float = 1e-5) -> None:
    """A whisper_tiny-shaped pre-norm transformer encoder block.

        x = x + Attn(RMS(x));  x = x + MLP(RMS(x));  out = RMS(x)

    Weight memref names and nest labels match the nn-module bridge
    (``Attention("attn") / MLP("mlp") / RMSNorm("ln_post")`` through
    ``repro.hls.bridge``), which therefore emits a bit-identical DFG —
    the same contract :func:`braggnn` keeps with its module twin.
    """
    dh = d_model // n_heads
    assert n_heads * dh == d_model, (d_model, n_heads)

    x = ctx.memref("input", (seq, d_model), "input")

    # --- attention sub-block ------------------------------------------------
    g1 = ctx.memref("attn.norm.gamma", (d_model,), "weight")
    n1 = ctx.temp("attn_norm", (seq, d_model))
    rms_norm(ctx, x, g1, n1, eps=eps, label="attn.norm")
    wq = ctx.memref("attn.q.kernel", (d_model, n_heads, dh), "weight")
    wk = ctx.memref("attn.k.kernel", (d_model, n_heads, dh), "weight")
    wv = ctx.memref("attn.v.kernel", (d_model, n_heads, dh), "weight")
    wo = ctx.memref("attn.o.kernel", (n_heads, dh, d_model), "weight")
    mix = ctx.temp("attn_mix", (seq, d_model))
    attention(ctx, n1, wq, wk, wv, wo, mix, n_heads=n_heads,
              taylor_order=taylor_order, label="attn")
    r1 = ctx.temp("attn_out", (seq, d_model))
    add_residual(ctx, mix, x, r1, label="attn.residual")

    # --- MLP sub-block ------------------------------------------------------
    g2 = ctx.memref("mlp.norm.gamma", (d_model,), "weight")
    n2 = ctx.temp("mlp_norm", (seq, d_model))
    rms_norm(ctx, r1, g2, n2, eps=eps, label="mlp.norm")
    w1 = ctx.memref("mlp.fc1.weight", (ffn, d_model), "weight")
    b1 = ctx.memref("mlp.fc1.bias", (ffn,), "weight")
    w2 = ctx.memref("mlp.fc2.weight", (d_model, ffn), "weight")
    b2 = ctx.memref("mlp.fc2.bias", (d_model,), "weight")
    m = ctx.temp("mlp_fc", (seq, d_model))
    mlp(ctx, n2, w1, b1, w2, b2, m, label="mlp")
    r2 = ctx.temp("mlp_out", (seq, d_model))
    add_residual(ctx, m, r1, r2, label="mlp.residual")

    # --- final norm writes the output ---------------------------------------
    g3 = ctx.memref("ln_post.gamma", (d_model,), "weight")
    out = ctx.memref("ln_post_out", (seq, d_model), "output")
    rms_norm(ctx, r2, g3, out, eps=eps, label="ln_post")
