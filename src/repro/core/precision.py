"""FloPoCo-style reduced-precision floating point emulation (paper §3, §4.2).

OpenHLS delegates arithmetic to FloPoCo-generated cores parameterised by
(wE, wF) = (exponent bits, fraction bits).  FloPoCo's representation differs
from IEEE-754: **no subnormals** (values below the smallest normal flush to
zero) and two extra exception bits instead of reserved exponent codes, so a
(wE, wF) number occupies  1 + wE + wF + 2  wires — e.g. (5,4) is 12 bits,
which is exactly the width used in the paper's SLL-crossing computation
(§4.2: (1x16x9x9 + 1x8x9x9) x 12 = 23,328 > 23,040 SLLs).

We emulate the value lattice of these formats inside fp32 containers:
round-to-nearest-even on the fraction, exponent clamping with flush-to-zero
below ``emin`` and saturation above ``emax``.  A straight-through-estimator
wrapper makes the quantiser differentiable for quantisation-aware training.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A FloPoCo (wE, wF) floating-point format."""

    exp_bits: int
    man_bits: int
    name: str = ""

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emax(self) -> int:
        return self.bias

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def max_value(self) -> float:
        return float((2.0 - 2.0 ** (-self.man_bits)) * 2.0 ** self.emax)

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.emin)

    @property
    def wire_bits(self) -> int:
        """Bits on a wire: sign + wE + wF + 2 exception bits (paper §4.2)."""
        return 1 + self.exp_bits + self.man_bits + 2

    def __str__(self) -> str:
        return self.name or f"({self.exp_bits},{self.man_bits})"


#: The three formats the paper steps through for BraggNN.
FP_5_11 = FloatFormat(5, 11, "(5,11)")   # ~IEEE half precision
FP_5_4 = FloatFormat(5, 4, "(5,4)")
FP_5_3 = FloatFormat(5, 3, "(5,3)")
FORMATS = {"5_11": FP_5_11, "5_4": FP_5_4, "5_3": FP_5_3}


def _quantize_generic(x, fmt: FloatFormat, xp):
    """Shared numpy/jnp quantiser.  RNE fraction rounding, FTZ, saturation."""
    x = xp.asarray(x, dtype=xp.float32)
    sign = xp.sign(x)
    v = xp.abs(x)
    # decompose |x| = f * 2^E with f in [0.5, 1)  ->  m = 2f in [1, 2)
    f, e = xp.frexp(v)
    m = f * 2.0
    e = e - 1
    # round-to-nearest-even on the fraction
    scale = float(1 << fmt.man_bits)
    q = xp.round((m - 1.0) * scale)
    carry = q >= scale
    m_q = xp.where(carry, 1.0, 1.0 + q / scale)
    e_q = xp.where(carry, e + 1, e)
    out = sign * m_q * xp.exp2(e_q.astype(xp.float32))
    # flush-to-zero below min normal (FloPoCo: no subnormals)
    out = xp.where(v < fmt.min_normal * 0.5, 0.0, out)
    out = xp.where((v >= fmt.min_normal * 0.5) & (v < fmt.min_normal),
                   sign * fmt.min_normal, out)
    # saturate above max finite (FloPoCo raises the overflow exception bit;
    # we saturate, which is the DNN-friendly policy — noted in DESIGN.md)
    out = xp.where(v > fmt.max_value, sign * fmt.max_value, out)
    # exact zeros / non-finites pass through
    out = xp.where(v == 0.0, x, out)
    out = xp.where(xp.isfinite(x), out, x)
    return out


def quantize_np(x: np.ndarray, fmt: FloatFormat) -> np.ndarray:
    """Numpy quantiser — used by the scalar-DFG functional models."""
    return _quantize_generic(x, fmt, np).astype(np.float32)


def quantize(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """jnp quantiser — used by the tensor-level production path."""
    return _quantize_generic(x, fmt, jnp)


@jax.custom_vjp
def ste_quantize(x: jax.Array, exp_bits: int, man_bits: int) -> jax.Array:
    """Quantise with a straight-through gradient (for QAT of BraggNN)."""
    return quantize(x, FloatFormat(int(exp_bits), int(man_bits)))


def _ste_fwd(x, exp_bits, man_bits):
    return ste_quantize(x, exp_bits, man_bits), None


def _ste_bwd(_, g):
    return (g, None, None)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def quantize_tree(tree, fmt: FloatFormat):
    """Quantise every leaf of a parameter pytree (weights-to-registers)."""
    return jax.tree_util.tree_map(
        lambda x: quantize(x, fmt) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def exponent_histogram(tree) -> dict[int, int]:
    """Histogram of weight exponents (paper Fig. 7) over a parameter tree."""
    hist: dict[int, int] = {}
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf, dtype=np.float32).ravel()
        arr = arr[np.isfinite(arr) & (arr != 0.0)]
        if arr.size == 0:
            continue
        _, e = np.frexp(np.abs(arr))
        e = e - 1
        vals, counts = np.unique(e, return_counts=True)
        for v, c in zip(vals.tolist(), counts.tolist()):
            hist[int(v)] = hist.get(int(v), 0) + int(c)
    return hist


def required_exponent_bits(hist: dict[int, int], coverage: float = 1.0) -> int:
    """Smallest wE covering ``coverage`` of the exponent mass (Fig. 7 logic)."""
    if not hist:
        return 1
    total = sum(hist.values())
    items = sorted(hist.items(), key=lambda kv: -kv[1])
    kept: list[int] = []
    acc = 0
    for e, c in items:
        kept.append(e)
        acc += c
        if acc >= coverage * total:
            break
    lo, hi = min(kept), max(kept)
    for we in range(2, 12):
        fmt = FloatFormat(we, 1)
        if fmt.emin <= lo and hi <= fmt.emax:
            return we
    return 12
