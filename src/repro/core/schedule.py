"""Resource-constrained list scheduling (paper §3.3).

The paper's observation: in the resource-unconstrained case the scheduling
ILP's constraint matrix is totally unimodular, so an LP (equivalently, a
longest-path/ASAP computation) solves it optimally; resource constraints are
folded in as *precedence* constraints by fixing a linear order on the
operations bound to each resource.  OpenHLS derives resource capacity from
the explicit parallelism of scf.parallel nests:  K_i = |parallel iteration
space of nest i| functional units serve nest i, and K = max_i K_i units of
each class exist in the design.

Two binding disciplines are implemented:

  * ``binding="pool"``  (default, OpenHLS mode) — per-class pools of K units;
    each op in program order grabs the earliest-free unit.  Equivalent to
    list scheduling with the paper's capacity bound, and the discipline that
    reproduces the paper's interval counts.
  * ``binding="rank"``  — static binding of parallel instance ``rank`` to
    unit ``rank mod lanes``; this is the stricter literal reading of the
    linear-order construction and also serves, with small ``unroll_factor``,
    as the conventional-HLS (Vitis) baseline model of §4.1.

A final ALAP compaction retimes ops as late as their consumers and unit
successors allow (paper: ALAP "amongst the subtrees" of reduction trees),
which shortens register lifetimes — the FF-usage analogue.

Terminology mirrors the paper's evaluation: the *interval count* is the
makespan in clock cycles; end-to-end latency = interval count x achieved
clock period (10 ns target).

Implementation: the scheduler consumes the IR's struct-of-arrays columns.
Everything around the ASAP resource-serialisation core is an array program:
ALAP compaction runs as a reverse-Kahn *wave* relaxation (each dependency
wave retimes vectorised; ``latest`` updates are commuting minima), stage
partitioning is a numpy-batched DP with an incremental suffix-max cost
matrix, and nest spans / peak-live / unit counts are bulk reductions.  The
ASAP core itself is inherently order-serial — each op's issue slot depends
on every earlier allocation in its pool, and wave-batching it measurably
collapses to ~1 op per wave on rank-major traces (each parallel instance's
reduction chain is contiguous in program order) — so it runs as a compiled
C kernel (``_asap.c`` via :mod:`repro.core.cext`, built lazily with the
system compiler) that is a literal port of the pure-Python reference loop
``_asap_scalar``, which remains the fallback and the rank-binding path.
The historical per-op scheduler survives in ``repro.core.legacy`` and all
paths produce bit-identical schedules (golden suite).
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from typing import Optional

import numpy as np

from repro.core import cext
from repro.core.ir import (CLASS_TABLE, PORT_CLASS_ID, RESOURCE_CLASSES,
                           Graph, delay_table)

CLOCK_NS = 10.0  # paper §4: all designs synthesised for a 10 ns target clock

_EMPTY_I64 = np.empty(0, dtype=np.int64)

#: ALAP wave vectorisation bails to the scalar sweep when some unit's
#: program-order chain exceeds this many ops (wave count is bounded below
#: by the longest chain, so degenerate bindings — e.g. unroll_factor=4 on a
#: 100k-op graph — would decay into thousands of tiny waves).
_ALAP_WAVE_MAX_CHAIN = 2048


@dataclasses.dataclass(frozen=True)
class ScheduleParams:
    """The schedule-shaping knobs, bundled as one first-class value.

    These are exactly the parameters a design-space explorer mutates
    (``repro.tune``): ``unroll_factor`` caps per-class unit capacity,
    ``n_stages`` is the pipeline-partition (tile) factor consumed by
    ``partition_stages``, and the remaining fields select the binding
    discipline and compaction.  ``list_schedule(g, params=...)`` accepts
    the bundle directly; ``n_stages`` is carried for the stage-partition
    step that follows scheduling.
    """

    binding: str = "pool"
    unroll_factor: Optional[int] = None
    ports_per_array: int = 2
    pipelined_units: bool = False
    alap_compact: bool = True
    n_stages: int = 1


@dataclasses.dataclass
class Schedule:
    """A fully scheduled design."""

    start: list[int]                      # per-op start cycle
    makespan: int                         # interval count
    resource_units: dict[str, int]        # units instantiated per class
    nest_spans: dict[int, tuple[int, int]]  # nest -> (min start, max end)
    peak_live: int                        # peak # of live values (FF analogue)
    n_ops: int

    @property
    def latency_us(self) -> float:
        return self.makespan * CLOCK_NS * 1e-3

    def resources(self) -> dict[str, int]:
        """FPGA-resource analogues (paper Fig. 4 bars).

        DSP  <- mul/add/mac/div/sqrt units
        LUT  <- cmp/select/relu units (combinational logic)
        FF   <- peak live values (registered symbols)
        BRAM <- arrays with surviving load/store traffic (0 in forwarding
                mode — the paper's headline resource result)
        """
        dsp = sum(n for c, n in self.resource_units.items()
                  if c in ("mul", "add", "mac", "div", "sqrt"))
        lut = sum(n for c, n in self.resource_units.items() if c == "cmp")
        bram = sum(n for c, n in self.resource_units.items() if c == "port")
        return {"DSP": dsp, "LUT_units": lut, "FF": self.peak_live,
                "BRAM_ports": bram}


class _UnitPool:
    """Earliest-free-unit allocator with lazy instantiation up to capacity."""

    __slots__ = ("capacity", "heap", "allocated")

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self.heap: list[tuple[int, int]] = []  # (free_time, unit_id)
        self.allocated = 0

    def acquire(self, t_ready: int, occupancy: int) -> tuple[int, int]:
        """Returns (start_time, unit_id)."""
        if self.heap and self.heap[0][0] <= t_ready:
            _, uid = heapq.heappop(self.heap)
            start = t_ready
        elif self.allocated < self.capacity:
            uid = self.allocated
            self.allocated += 1
            start = t_ready
        else:
            free, uid = heapq.heappop(self.heap)
            start = max(free, t_ready)
        heapq.heappush(self.heap, (start + occupancy, uid))
        return start, uid


def list_schedule(
    g: Graph,
    *,
    params: Optional[ScheduleParams] = None,
    binding: str = "pool",
    unroll_factor: Optional[int] = None,
    ports_per_array: int = 2,
    pipelined_units: bool = False,
    delays: Optional[dict[str, int]] = None,
    alap_compact: bool = True,
) -> Schedule:
    """Schedule ``g``.

    params:
        a ``ScheduleParams`` bundle; when given it overrides the individual
        keyword knobs (``n_stages`` is ignored here — it parameterises the
        ``partition_stages`` step that follows).
    binding:
        "pool" — OpenHLS mode (per-class capacity K = max_i K_i, or
        ``unroll_factor`` when given).
        "rank" — static rank binding (paper's literal linear-order form).
    unroll_factor:
        caps per-class capacity (models a k-fold unrolled conventional-HLS
        design, paper §4.1); ``None`` = the design's own K.
    ports_per_array:
        memory ports per array for surviving load/store ops (baseline mode).
    pipelined_units:
        if True, units have initiation interval 1 (FloPoCo cores are fully
        pipelined); if False, a unit is busy for the op's full delay —
        matching the paper's precedence-constraint transformation
        (start_a + delay_a <= start_b, footnote 2).
    """
    if params is not None:
        binding = params.binding
        unroll_factor = params.unroll_factor
        ports_per_array = params.ports_per_array
        pipelined_units = params.pipelined_units
        alap_compact = params.alap_compact
    assert binding in ("pool", "rank"), binding
    if os.environ.get("REPRO_LEGACY_IR", "") == "1":
        from repro.core import legacy
        return legacy.list_schedule(
            g, binding=binding, unroll_factor=unroll_factor,
            ports_per_array=ports_per_array,
            pipelined_units=pipelined_units, delays=delays,
            alap_compact=alap_compact)

    c = g.cols()
    n = c.n
    if n == 0:
        return Schedule(start=[], makespan=0, resource_units={},
                        nest_spans={}, peak_live=0, n_ops=0)

    dtab = delay_table(delays)
    delay_arr = dtab[c.opcode]                       # int64[n]
    occ_arr = (np.ones(n, dtype=np.int64) if pipelined_units
               else np.maximum(delay_arr, 1))
    cls_arr = CLASS_TABLE[c.opcode]                  # 0 = unconstrained

    # resource keys are packed ints: (class axis) * STRIDE + unit.  The
    # class axis separates per-class pools, per-array port pools, and
    # rank-mode lanes so no two pools ever share a key.
    STRIDE = n + max(g.n_values, 1) + 2
    lane_arr = None
    if binding == "rank":
        nest_u, nest_inv = np.unique(c.nest, return_inverse=True)
        k_i = np.array([g.nest_parallel_space.get(int(t), 1) for t in nest_u],
                       dtype=np.int64)
        lanes = k_i[nest_inv]
        if unroll_factor is not None:
            lanes = np.maximum(1, np.minimum(unroll_factor, lanes))
        lane_arr = (np.where(c.rank >= 0, c.rank, 0) % lanes).tolist()

    K = g.K() if unroll_factor is None else max(1, unroll_factor)
    K = max(1, K)
    ports_cap = max(1, ports_per_array)

    rank_units: set[int] = set()
    out = None
    if binding == "pool" and os.environ.get("REPRO_SCHED_SCALAR", "") != "1":
        out = _asap_c(g, c, delay_arr, occ_arr, cls_arr, K, ports_cap,
                      STRIDE)
    if out is not None:
        start_arr, key_arr, pool_alloc, port_alloc = out
    else:
        start_l, key_l, pool_alloc, port_alloc, rank_units = _asap_scalar(
            g, c, delay_arr, occ_arr, cls_arr, lane_arr,
            binding == "pool", K, ports_cap, STRIDE)
        start_arr = np.asarray(start_l, dtype=np.int64)
        key_arr = np.asarray(key_l, dtype=np.int64)

    makespan = int((start_arr + delay_arr).max())

    if alap_compact:
        start_arr = _alap_compact(g, c, start_arr, makespan,
                                  delay_arr, occ_arr, key_arr)

    # ---- vectorised post-processing ---------------------------------------
    ends = start_arr + delay_arr
    nest_u, nest_inv = np.unique(c.nest, return_inverse=True)
    lo = np.full(len(nest_u), np.iinfo(np.int64).max, dtype=np.int64)
    hi = np.full(len(nest_u), np.iinfo(np.int64).min, dtype=np.int64)
    np.minimum.at(lo, nest_inv, start_arr)
    np.maximum.at(hi, nest_inv, ends)
    nest_spans = {int(t): (int(a), int(b))
                  for t, a, b in zip(nest_u, lo, hi)}

    peak_live = _peak_live_values(c, start_arr, delay_arr, makespan,
                                  g.n_values)

    units: dict[str, int] = {}
    if port_alloc:
        units["port"] = sum(port_alloc.values())
    if binding == "pool":
        for cls, alloc in pool_alloc.items():
            units[RESOURCE_CLASSES[cls]] = alloc
    elif rank_units:
        per_cls = np.bincount(
            np.asarray(sorted(rank_units), dtype=np.int64) // STRIDE,
            minlength=len(RESOURCE_CLASSES))
        for cls in range(1, len(RESOURCE_CLASSES)):
            if per_cls[cls]:
                units[RESOURCE_CLASSES[cls]] = int(per_cls[cls])
    return Schedule(start=[int(t) for t in start_arr], makespan=makespan,
                    resource_units=units, nest_spans=nest_spans,
                    peak_live=peak_live, n_ops=n)


def _asap_scalar(g: Graph, c, delay_arr, occ_arr, cls_arr, lane_arr,
                 pool_mode: bool, K: int, ports_cap: int, STRIDE: int):
    """The historical one-op-at-a-time ASAP core over primitive lists.

    Still the implementation for ``binding="rank"`` (static lane binding has
    no pool state worth batching) and the reference for the wave-batched
    core (``REPRO_SCHED_SCALAR=1`` forces it; the golden and property suites
    compare the two).
    """
    n = c.n
    a0l = c.args[:, 0].tolist()
    a1l = c.args[:, 1].tolist()
    a2l = c.args[:, 2].tolist()
    resl = c.result.tolist()
    dl = delay_arr.tolist()
    ol = occ_arr.tolist()
    cl = cls_arr.tolist()
    arrl = c.array_id.tolist()

    ready = [0] * max(g.n_values, 1)
    start = [0] * n
    key_l = [-1] * n                 # packed resource key per op (-1 = none)
    # Pool state, inlined for the hot loop.  Heap entries pack
    # (free_time, unit_id) into one int — free_time * capacity + uid orders
    # exactly like the historical tuple (free ascending, unit id tie-break)
    # but compares at machine-int speed instead of tuple speed.
    pool_heap: dict[int, list[int]] = {}   # class id -> packed heap
    pool_alloc: dict[int, int] = {}        # class id -> units instantiated
    port_heap: dict[int, list[int]] = {}   # array id -> packed heap
    port_alloc: dict[int, int] = {}
    unit_free: dict[int, int] = {}         # packed key -> free time (rank)
    rank_units: set[int] = set()           # packed keys seen in rank mode
    n_classes = len(RESOURCE_CLASSES)
    heappush = heapq.heappush
    heappop = heapq.heappop

    for i in range(n):
        t = 0
        a = a0l[i]
        if a >= 0:
            ta = ready[a]
            if ta > t:
                t = ta
            a = a1l[i]
            if a >= 0:
                ta = ready[a]
                if ta > t:
                    t = ta
                a = a2l[i]
                if a >= 0:
                    ta = ready[a]
                    if ta > t:
                        t = ta
        cls = cl[i]
        if cls:
            if cls == PORT_CLASS_ID:
                aid = arrl[i]
                heap = port_heap.get(aid)
                if heap is None:
                    heap = port_heap[aid] = []
                    port_alloc[aid] = 0
                cap = ports_cap
                alloc_map, pool_id = port_alloc, aid
                key_base = (n_classes + aid) * STRIDE
            elif pool_mode:
                heap = pool_heap.get(cls)
                if heap is None:
                    heap = pool_heap[cls] = []
                    pool_alloc[cls] = 0
                cap = K
                alloc_map, pool_id = pool_alloc, cls
                key_base = cls * STRIDE
            else:
                key = cls * STRIDE + lane_arr[i]
                tf = unit_free.get(key, 0)
                if tf > t:
                    t = tf
                unit_free[key] = t + ol[i]
                key_l[i] = key
                rank_units.add(key)
                start[i] = t
                r = resl[i]
                if r >= 0:
                    ready[r] = t + dl[i]
                continue
            # earliest-free-unit acquire (packed-int heap)
            if heap and heap[0] <= t * cap + cap - 1:
                packed = heappop(heap)
                uid = packed % cap
            else:
                alloc = alloc_map[pool_id]
                if alloc < cap:
                    uid = alloc
                    alloc_map[pool_id] = alloc + 1
                else:
                    packed = heappop(heap)
                    free = packed // cap
                    uid = packed % cap
                    if free > t:
                        t = free
            heappush(heap, (t + ol[i]) * cap + uid)
            key_l[i] = key_base + uid
        start[i] = t
        r = resl[i]
        if r >= 0:
            ready[r] = t + dl[i]

    return start, key_l, pool_alloc, port_alloc, rank_units


def _asap_c(g: Graph, c, delay_arr, occ_arr, cls_arr,
            K: int, ports_cap: int, STRIDE: int):
    """Run the ASAP core through the compiled kernel (pool binding only).

    Returns ``(start, key, pool_alloc, port_alloc)`` or ``None`` when the
    kernel is unavailable — callers then take the pure-Python loop.  The C
    source is a literal port of ``_asap_scalar``; bit-identity is covered
    by the golden suite (and ``REPRO_SCHED_SCALAR=1`` A/Bs the two).
    """
    lib = cext.asap_pool_lib()
    if lib is None:
        return None
    import ctypes
    n = c.n
    nv = max(g.n_values, 1)
    n_classes = len(RESOURCE_CLASSES)

    def _i64(a):
        return np.ascontiguousarray(a, dtype=np.int64)

    a0 = _i64(c.args[:, 0])
    a1 = _i64(c.args[:, 1])
    a2 = _i64(c.args[:, 2])
    res = _i64(c.result)
    dl = _i64(delay_arr)
    ol = _i64(occ_arr)
    cl = _i64(cls_arr)
    aid = _i64(c.array_id)
    is_port = cls_arr == PORT_CLASS_ID
    n_arrays = int(aid[is_port].max()) + 1 if is_port.any() else 0

    start = np.zeros(n, dtype=np.int64)
    key = np.full(n, -1, dtype=np.int64)
    ready = np.zeros(nv, dtype=np.int64)
    class_alloc = np.zeros(n_classes, dtype=np.int64)
    port_alloc = np.zeros(max(n_arrays, 1), dtype=np.int64)

    p = ctypes.POINTER(ctypes.c_int64)
    rc = lib.asap_pool(
        n, nv,
        a0.ctypes.data_as(p), a1.ctypes.data_as(p), a2.ctypes.data_as(p),
        res.ctypes.data_as(p), dl.ctypes.data_as(p), ol.ctypes.data_as(p),
        cl.ctypes.data_as(p), aid.ctypes.data_as(p),
        n_classes, K, ports_cap, STRIDE, n_arrays, PORT_CLASS_ID,
        start.ctypes.data_as(p), key.ctypes.data_as(p),
        ready.ctypes.data_as(p),
        class_alloc.ctypes.data_as(p), port_alloc.ctypes.data_as(p))
    if rc != 0:
        return None
    pool_alloc = {i: int(a) for i, a in enumerate(class_alloc) if a}
    port_alloc_d = {i: int(a)
                    for i, a in enumerate(port_alloc[:n_arrays]) if a}
    return start, key, pool_alloc, port_alloc_d


def _alap_compact(g: Graph, c, start_arr: np.ndarray, makespan: int,
                  delay_arr: np.ndarray, occ_arr: np.ndarray,
                  key_arr: np.ndarray) -> np.ndarray:
    """Retime ops as late as possible without growing the makespan.

    Implements the paper's ALAP scheduling "amongst the subtrees" of
    reduction trees — applied to every op, which subsumes it.  Safety: an op
    keeps its unit assignment and may not move past the next op scheduled on
    the same unit, so the forward schedule's resource feasibility and
    program order per unit are preserved.

    The sweep is a reverse-Kahn wave relaxation: an op's slack is final once
    every consumer of its result and its same-unit successor are retimed, so
    each wave retimes all such ops vectorised (``latest`` updates commute —
    they are minima).  When some unit's program-order chain is longer than
    ``_ALAP_WAVE_MAX_CHAIN`` (which lower-bounds the wave count) the scalar
    reverse sweep runs instead; both orders compute the same fixpoint.
    """
    n = len(start_arr)
    order = np.argsort(key_arr, kind="stable")
    next_same = np.full(n, -1, dtype=np.int64)
    if n > 1:
        same = key_arr[order[:-1]] == key_arr[order[1:]]
        same &= key_arr[order[:-1]] >= 0
        next_same[order[:-1][same]] = order[1:][same]

    keyed = key_arr[key_arr >= 0]
    max_chain = 0
    if keyed.size:
        _, counts = np.unique(keyed, return_counts=True)
        max_chain = int(counts.max())
    if max_chain > _ALAP_WAVE_MAX_CHAIN:
        return _alap_scalar(g, c, start_arr, makespan, delay_arr, occ_arr,
                            next_same)

    nv = max(g.n_values, 1)
    args64 = c.args.astype(np.int64)
    resv = c.result.astype(np.int64)
    prod = np.full(nv, -1, dtype=np.int64)
    has_r = resv >= 0
    prod[resv[has_r]] = np.flatnonzero(has_r)
    # producer op per arg slot (-1 where the arg is absent or an input)
    pa = prod[np.where(args64 >= 0, args64, 0)]
    pa[args64 < 0] = -1

    flat_pa = pa[pa >= 0]
    cnt = (np.bincount(flat_pa, minlength=n) if flat_pa.size
           else np.zeros(n, dtype=np.int64))
    cnt += (next_same >= 0).astype(np.int64)
    prev_same = np.full(n, -1, dtype=np.int64)
    has_nx = next_same >= 0
    prev_same[next_same[has_nx]] = np.flatnonzero(has_nx)

    new_start = start_arr.copy()
    latest = np.full(nv, makespan, dtype=np.int64)
    F = np.flatnonzero(cnt == 0)
    remaining = n
    while remaining:
        assert F.size, "ALAP wave made no progress"
        d = delay_arr[F]
        limit = makespan - d
        r = resv[F]
        mr = r >= 0
        lr = np.where(mr, latest[np.where(mr, r, 0)], 0) - d
        limit = np.where(mr, np.minimum(limit, lr), limit)
        nx = next_same[F]
        mn = nx >= 0
        l2 = np.where(mn, new_start[np.where(mn, nx, 0)], 0) - occ_arr[F]
        limit = np.where(mn, np.minimum(limit, l2), limit)
        t = np.maximum(new_start[F], limit)
        new_start[F] = t
        av = args64[F]
        am = av >= 0
        if am.any():
            np.minimum.at(latest, av[am],
                          np.broadcast_to(t[:, None], av.shape)[am])
        paf = pa[F]
        touched_p = paf[paf >= 0]
        ps = prev_same[F]
        touched = np.concatenate((touched_p, ps[ps >= 0]))
        remaining -= len(F)
        if touched.size:
            np.subtract.at(cnt, touched, 1)
            u = np.unique(touched)
            F = u[cnt[u] == 0]
        else:
            F = _EMPTY_I64
    return new_start


def _alap_scalar(g: Graph, c, start_arr, makespan: int, delay_arr, occ_arr,
                 next_same: np.ndarray) -> np.ndarray:
    """Reference reverse sweep over primitive lists (exact, order n-1..0)."""
    n = len(start_arr)
    a0l = c.args[:, 0].tolist()
    a1l = c.args[:, 1].tolist()
    a2l = c.args[:, 2].tolist()
    resl = c.result.tolist()
    dl = delay_arr.tolist()
    ol = occ_arr.tolist()
    nsl = next_same.tolist()
    new_start = start_arr.tolist()
    latest = [makespan] * max(g.n_values, 1)
    for i in range(n - 1, -1, -1):
        d = dl[i]
        limit = makespan - d
        r = resl[i]
        if r >= 0:
            lr = latest[r] - d
            if lr < limit:
                limit = lr
        nx = nsl[i]
        if nx >= 0:
            lim2 = new_start[nx] - ol[i]
            if lim2 < limit:
                limit = lim2
        t = new_start[i]
        if limit > t:
            t = limit
        new_start[i] = t
        a = a0l[i]
        if a >= 0:
            if t < latest[a]:
                latest[a] = t
            a = a1l[i]
            if a >= 0:
                if t < latest[a]:
                    latest[a] = t
                a = a2l[i]
                if a >= 0 and t < latest[a]:
                    latest[a] = t
    return np.asarray(new_start, dtype=np.int64)


def _peak_live_values(c, start_arr: np.ndarray, delay_arr: np.ndarray,
                      makespan: int, n_values: int) -> int:
    """Peak number of simultaneously live values — the FF-usage analogue."""
    if n_values == 0:
        return 0
    born = np.full(n_values, -1, dtype=np.int64)
    has_res = c.result >= 0
    born[c.result[has_res]] = (start_arr + delay_arr)[has_res]
    last_use = np.full(n_values, -1, dtype=np.int64)
    am = c.args >= 0
    flat_args = c.args[am].astype(np.int64)
    flat_t = np.broadcast_to(start_arr[:, None], c.args.shape)[am]
    np.maximum.at(last_use, flat_args, flat_t)
    mask = (born >= 0) & (last_use >= born)
    if not mask.any():
        return 0
    b = born[mask]
    e = last_use[mask] + 1
    hist = np.zeros(makespan + 2, dtype=np.int64)
    np.add.at(hist, b, 1)
    np.add.at(hist, e, -1)
    return int(np.cumsum(hist).max())


def partition_stages(g: Graph, sched: Schedule, n_stages: int
                     ) -> tuple[list[list[int]], int]:
    """Partition nests (in program order) into pipeline stages.

    Returns (stages as lists of nest ids, initiation interval = longest
    stage span).  This reproduces the paper's BraggNN deployment: a 3-stage
    pipeline whose throughput is set by the longest stage (480 intervals in
    the paper).  DP over contiguous partitions minimising the max stage span.

    The recurrence dp[s][j] = min_i max(dp[s-1][i], cost(i, j-1)) runs
    numpy-batched over ``i``: nests are sorted by span start, so
    cost(i, j-1) = max(E[i..j-1]) - S[i], and the max term is maintained as
    an incremental suffix-max as ``j`` grows — no per-pair recomputation.
    ``np.argmin``'s first-occurrence tie-break matches the scalar
    strict-less-than first minimiser (``_partition_stages_scalar``, kept as
    the property-test reference).
    """
    nests = sorted(sched.nest_spans, key=lambda t: sched.nest_spans[t][0])
    if not nests:
        return [[]], 0
    S = np.array([sched.nest_spans[t][0] for t in nests], dtype=np.int64)
    E = np.array([sched.nest_spans[t][1] for t in nests], dtype=np.int64)
    m = len(nests)
    n_stages = min(n_stages, m)

    INF = np.iinfo(np.int64).max // 4
    dp_prev = np.full(m + 1, INF, dtype=np.int64)
    dp_prev[0] = 0
    cut = np.zeros((n_stages + 1, m + 1), dtype=np.int64)
    for s in range(1, n_stages + 1):
        dp_cur = np.full(m + 1, INF, dtype=np.int64)
        gmax = np.full(m, np.iinfo(np.int64).min, dtype=np.int64)
        first = s - 1
        for j in range(1, m + 1):
            np.maximum(gmax[:j], E[j - 1], out=gmax[:j])
            if j <= first:
                continue
            cand = np.maximum(dp_prev[first:j], gmax[first:j] - S[first:j])
            k = int(np.argmin(cand))
            dp_cur[j] = cand[k]
            cut[s, j] = first + k
        dp_prev = dp_cur

    stages: list[list[int]] = []
    j = m
    for s in range(n_stages, 0, -1):
        i = int(cut[s, j])
        stages.append(nests[i:j])
        j = i
    stages.reverse()
    ii = int(dp_prev[m])
    return stages, ii


def _partition_stages_scalar(g: Graph, sched: Schedule, n_stages: int
                             ) -> tuple[list[list[int]], int]:
    """The historical O(nests^2 * stages) Python DP — reference for the
    vectorised ``partition_stages`` (property-tested equal)."""
    nests = sorted(sched.nest_spans, key=lambda t: sched.nest_spans[t][0])
    if not nests:
        return [[]], 0
    spans = [sched.nest_spans[t] for t in nests]
    m = len(nests)
    n_stages = min(n_stages, m)

    def stage_cost(i: int, j: int) -> int:  # nests i..j inclusive
        lo = min(s for s, _ in spans[i:j + 1])
        hi = max(e for _, e in spans[i:j + 1])
        return hi - lo

    INF = float("inf")
    dp = [[INF] * (m + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (m + 1) for _ in range(n_stages + 1)]
    dp[0][0] = 0
    for s in range(1, n_stages + 1):
        for j in range(1, m + 1):
            for i in range(s - 1, j):
                c = max(dp[s - 1][i], stage_cost(i, j - 1))
                if c < dp[s][j]:
                    dp[s][j] = c
                    cut[s][j] = i
    stages: list[list[int]] = []
    j = m
    for s in range(n_stages, 0, -1):
        i = cut[s][j]
        stages.append(nests[i:j])
        j = i
    stages.reverse()
    ii = int(dp[n_stages][m])
    return stages, ii
