"""Resource-constrained list scheduling (paper §3.3).

The paper's observation: in the resource-unconstrained case the scheduling
ILP's constraint matrix is totally unimodular, so an LP (equivalently, a
longest-path/ASAP computation) solves it optimally; resource constraints are
folded in as *precedence* constraints by fixing a linear order on the
operations bound to each resource.  OpenHLS derives resource capacity from
the explicit parallelism of scf.parallel nests:  K_i = |parallel iteration
space of nest i| functional units serve nest i, and K = max_i K_i units of
each class exist in the design.

Two binding disciplines are implemented:

  * ``binding="pool"``  (default, OpenHLS mode) — per-class pools of K units;
    each op in program order grabs the earliest-free unit.  Equivalent to
    list scheduling with the paper's capacity bound, and the discipline that
    reproduces the paper's interval counts.
  * ``binding="rank"``  — static binding of parallel instance ``rank`` to
    unit ``rank mod lanes``; this is the stricter literal reading of the
    linear-order construction and also serves, with small ``unroll_factor``,
    as the conventional-HLS (Vitis) baseline model of §4.1.

A final ALAP compaction retimes ops as late as their consumers and unit
successors allow (paper: ALAP "amongst the subtrees" of reduction trees),
which shortens register lifetimes — the FF-usage analogue.

Terminology mirrors the paper's evaluation: the *interval count* is the
makespan in clock cycles; end-to-end latency = interval count x achieved
clock period (10 ns target).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from repro.core.ir import DEFAULT_DELAYS, RESOURCE_CLASS, Graph

CLOCK_NS = 10.0  # paper §4: all designs synthesised for a 10 ns target clock


@dataclasses.dataclass(frozen=True)
class ScheduleParams:
    """The schedule-shaping knobs, bundled as one first-class value.

    These are exactly the parameters a design-space explorer mutates
    (``repro.tune``): ``unroll_factor`` caps per-class unit capacity,
    ``n_stages`` is the pipeline-partition (tile) factor consumed by
    ``partition_stages``, and the remaining fields select the binding
    discipline and compaction.  ``list_schedule(g, params=...)`` accepts
    the bundle directly; ``n_stages`` is carried for the stage-partition
    step that follows scheduling.
    """

    binding: str = "pool"
    unroll_factor: Optional[int] = None
    ports_per_array: int = 2
    pipelined_units: bool = False
    alap_compact: bool = True
    n_stages: int = 1


@dataclasses.dataclass
class Schedule:
    """A fully scheduled design."""

    start: list[int]                      # per-op start cycle
    makespan: int                         # interval count
    resource_units: dict[str, int]        # units instantiated per class
    nest_spans: dict[int, tuple[int, int]]  # nest -> (min start, max end)
    peak_live: int                        # peak # of live values (FF analogue)
    n_ops: int

    @property
    def latency_us(self) -> float:
        return self.makespan * CLOCK_NS * 1e-3

    def resources(self) -> dict[str, int]:
        """FPGA-resource analogues (paper Fig. 4 bars).

        DSP  <- mul/add/mac/div/sqrt units
        LUT  <- cmp/select/relu units (combinational logic)
        FF   <- peak live values (registered symbols)
        BRAM <- arrays with surviving load/store traffic (0 in forwarding
                mode — the paper's headline resource result)
        """
        dsp = sum(n for c, n in self.resource_units.items()
                  if c in ("mul", "add", "mac", "div", "sqrt"))
        lut = sum(n for c, n in self.resource_units.items() if c == "cmp")
        bram = sum(n for c, n in self.resource_units.items() if c == "port")
        return {"DSP": dsp, "LUT_units": lut, "FF": self.peak_live,
                "BRAM_ports": bram}


class _UnitPool:
    """Earliest-free-unit allocator with lazy instantiation up to capacity."""

    __slots__ = ("capacity", "heap", "allocated")

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self.heap: list[tuple[int, int]] = []  # (free_time, unit_id)
        self.allocated = 0

    def acquire(self, t_ready: int, occupancy: int) -> tuple[int, int]:
        """Returns (start_time, unit_id)."""
        if self.heap and self.heap[0][0] <= t_ready:
            _, uid = heapq.heappop(self.heap)
            start = t_ready
        elif self.allocated < self.capacity:
            uid = self.allocated
            self.allocated += 1
            start = t_ready
        else:
            free, uid = heapq.heappop(self.heap)
            start = max(free, t_ready)
        heapq.heappush(self.heap, (start + occupancy, uid))
        return start, uid


def list_schedule(
    g: Graph,
    *,
    params: Optional[ScheduleParams] = None,
    binding: str = "pool",
    unroll_factor: Optional[int] = None,
    ports_per_array: int = 2,
    pipelined_units: bool = False,
    delays: Optional[dict[str, int]] = None,
    alap_compact: bool = True,
) -> Schedule:
    """Schedule ``g``.

    params:
        a ``ScheduleParams`` bundle; when given it overrides the individual
        keyword knobs (``n_stages`` is ignored here — it parameterises the
        ``partition_stages`` step that follows).
    binding:
        "pool" — OpenHLS mode (per-class capacity K = max_i K_i, or
        ``unroll_factor`` when given).
        "rank" — static rank binding (paper's literal linear-order form).
    unroll_factor:
        caps per-class capacity (models a k-fold unrolled conventional-HLS
        design, paper §4.1); ``None`` = the design's own K.
    ports_per_array:
        memory ports per array for surviving load/store ops (baseline mode).
    pipelined_units:
        if True, units have initiation interval 1 (FloPoCo cores are fully
        pipelined); if False, a unit is busy for the op's full delay —
        matching the paper's precedence-constraint transformation
        (start_a + delay_a <= start_b, footnote 2).
    """
    if params is not None:
        binding = params.binding
        unroll_factor = params.unroll_factor
        ports_per_array = params.ports_per_array
        pipelined_units = params.pipelined_units
        alap_compact = params.alap_compact
    assert binding in ("pool", "rank"), binding
    delays = delays or DEFAULT_DELAYS
    n = len(g.ops)
    start = [0] * n
    ready_at = [0] * g.n_values
    keys: list[Optional[tuple]] = [None] * n  # op -> (class, unit) binding

    K = g.K() if unroll_factor is None else max(1, unroll_factor)
    pools: dict[str, _UnitPool] = {}
    port_pools: dict[str, _UnitPool] = {}
    unit_free: dict[tuple, int] = {}   # rank-binding mode
    units_used: dict[str, set] = {}

    for op in g.ops:
        d = delays.get(op.opcode, 0)
        occ = 1 if pipelined_units else max(d, 1)
        t = 0
        for a in op.args:
            ta = ready_at[a]
            if ta > t:
                t = ta
        cls = RESOURCE_CLASS.get(op.opcode)
        if cls == "port":
            pool = port_pools.get(op.array)
            if pool is None:
                pool = port_pools[op.array] = _UnitPool(ports_per_array)
            t, uid = pool.acquire(t, occ)
            keys[op.idx] = ("port", op.array, uid)
            units_used.setdefault("port", set()).add((op.array, uid))
        elif cls is not None:
            if binding == "pool":
                pool = pools.get(cls)
                if pool is None:
                    pool = pools[cls] = _UnitPool(K)
                t, uid = pool.acquire(t, occ)
                keys[op.idx] = (cls, uid)
                units_used.setdefault(cls, set()).add(uid)
            else:
                k_i = g.nest_parallel_space.get(op.nest, 1)
                lanes = k_i if unroll_factor is None else max(
                    1, min(unroll_factor, k_i))
                rank = op.rank if op.rank >= 0 else 0
                key = (cls, rank % lanes)
                tf = unit_free.get(key, 0)
                if tf > t:
                    t = tf
                unit_free[key] = t + occ
                keys[op.idx] = key
                units_used.setdefault(cls, set()).add(key)
        start[op.idx] = t
        if op.result >= 0:
            ready_at[op.result] = t + d

    makespan = 0
    for op in g.ops:
        end = start[op.idx] + delays.get(op.opcode, 0)
        if end > makespan:
            makespan = end

    if alap_compact:
        start = _alap_compact(g, start, makespan, delays, pipelined_units,
                              keys)

    nest_spans: dict[int, tuple[int, int]] = {}
    for op in g.ops:
        s = start[op.idx]
        e = s + delays.get(op.opcode, 0)
        lo, hi = nest_spans.get(op.nest, (s, e))
        nest_spans[op.nest] = (min(lo, s), max(hi, e))

    peak_live = _peak_live_values(g, start, delays)
    units = {c: len(k) for c, k in units_used.items()}
    return Schedule(start=start, makespan=makespan, resource_units=units,
                    nest_spans=nest_spans, peak_live=peak_live, n_ops=n)


def _alap_compact(g: Graph, start: list[int], makespan: int,
                  delays: dict[str, int], pipelined_units: bool,
                  keys: list[Optional[tuple]]) -> list[int]:
    """Retime ops as late as possible without growing the makespan.

    Implements the paper's ALAP scheduling "amongst the subtrees" of
    reduction trees — applied to every op, which subsumes it.  Safety: an op
    keeps its unit assignment and may not move past the next op scheduled on
    the same unit, so the forward schedule's resource feasibility and
    program order per unit are preserved.
    """
    new_start = list(start)
    latest = [makespan] * g.n_values
    next_same_key: dict[int, int] = {}
    last_seen: dict[tuple, int] = {}
    for op in reversed(g.ops):
        k = keys[op.idx]
        if k is not None:
            if k in last_seen:
                next_same_key[op.idx] = last_seen[k]
            last_seen[k] = op.idx
    for op in reversed(g.ops):
        d = delays.get(op.opcode, 0)
        limit = makespan - d
        if op.result >= 0:
            limit = min(limit, latest[op.result] - d)
        nxt = next_same_key.get(op.idx)
        if nxt is not None:
            occupancy = 1 if pipelined_units else max(d, 1)
            limit = min(limit, new_start[nxt] - occupancy)
        t = new_start[op.idx]
        if limit > t:
            t = limit
        new_start[op.idx] = t
        for a in op.args:
            if t < latest[a]:
                latest[a] = t
    return new_start


def _peak_live_values(g: Graph, start: list[int],
                      delays: dict[str, int]) -> int:
    """Peak number of simultaneously live values — the FF-usage analogue."""
    last_use: dict[int, int] = {}
    born: dict[int, int] = {}
    for op in g.ops:
        if op.result >= 0:
            born[op.result] = start[op.idx] + delays.get(op.opcode, 0)
        for a in op.args:
            t = start[op.idx]
            if last_use.get(a, -1) < t:
                last_use[a] = t
    events: list[tuple[int, int]] = []
    for vid, b in born.items():
        e = last_use.get(vid)
        if e is None or e < b:
            continue
        events.append((b, 1))
        events.append((e + 1, -1))
    events.sort()
    live = peak = 0
    for _, delta in events:
        live += delta
        if live > peak:
            peak = live
    return peak


def partition_stages(g: Graph, sched: Schedule, n_stages: int
                     ) -> tuple[list[list[int]], int]:
    """Partition nests (in program order) into pipeline stages.

    Returns (stages as lists of nest ids, initiation interval = longest
    stage span).  This reproduces the paper's BraggNN deployment: a 3-stage
    pipeline whose throughput is set by the longest stage (480 intervals in
    the paper).  DP over contiguous partitions minimising the max stage span.
    """
    nests = sorted(sched.nest_spans, key=lambda t: sched.nest_spans[t][0])
    if not nests:
        return [[]], 0
    spans = [sched.nest_spans[t] for t in nests]
    m = len(nests)
    n_stages = min(n_stages, m)

    def stage_cost(i: int, j: int) -> int:  # nests i..j inclusive
        lo = min(s for s, _ in spans[i:j + 1])
        hi = max(e for _, e in spans[i:j + 1])
        return hi - lo

    INF = float("inf")
    dp = [[INF] * (m + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (m + 1) for _ in range(n_stages + 1)]
    dp[0][0] = 0
    for s in range(1, n_stages + 1):
        for j in range(1, m + 1):
            for i in range(s - 1, j):
                c = max(dp[s - 1][i], stage_cost(i, j - 1))
                if c < dp[s][j]:
                    dp[s][j] = c
                    cut[s][j] = i
    stages: list[list[int]] = []
    j = m
    for s in range(n_stages, 0, -1):
        i = cut[s][j]
        stages.append(nests[i:j])
        j = i
    stages.reverse()
    ii = int(dp[n_stages][m])
    return stages, ii
