"""Symbolic interpretation of loop-nest DNN programs (paper §3.1).

The paper's key move: rather than statically analysing unrolled loop nests
(prohibitively expensive — Fig. 2), *execute* them under the Python
interpreter with arithmetic and memory operations overloaded to act on
symbols.  Memrefs become *geometric symbol tables* (symbol tables indexed by
array index rather than identifier), so:

  * store-load forwarding falls out for free — a load simply returns the
    symbol most recently stored at that address;
  * loop unrolling is just iteration — every executed arithmetic op appends
    a fresh SSA op to the graph;
  * memory-dependence verification becomes a runtime assertion — parallel
    loop bodies must write disjoint addresses (checked per nest).

Two functional modes (paper §3.1 item 4, "swap evaluation rules"):

  * ``forward=True``   — OpenHLS mode: no load/store ops survive.
  * ``forward=False``  — conventional-HLS baseline mode (models Vitis HLS in
    §4.1): loads/stores stay in the DFG, serialised per-address and bound to
    per-array memory-port resources.
"""

from __future__ import annotations

import itertools
from contextlib import contextmanager
from typing import Iterator, Optional, Sequence, Union

from repro.core.ir import OPCODE_ID, TRACE_CHUNK, Graph

Number = Union[int, float]


class SymVal:
    """A scalar SSA symbol.  Arithmetic builds DFG ops (paper Fig. 3 rules)."""

    __slots__ = ("ctx", "id")

    def __init__(self, ctx: "Context", vid: int):
        self.ctx = ctx
        self.id = vid

    # -- helpers ------------------------------------------------------------

    def _coerce(self, other: Union["SymVal", Number]) -> "SymVal":
        if isinstance(other, SymVal):
            return other
        return self.ctx.const(float(other))

    def _bin(self, opcode: str, other: Union["SymVal", Number]) -> "SymVal":
        o = self._coerce(other)
        return self.ctx._emit(opcode, (self.id, o.id))

    # -- arith.* ------------------------------------------------------------

    def __mul__(self, other):  # arith.mulf
        return self._bin("mulf", other)

    __rmul__ = __mul__

    def __add__(self, other):  # arith.addf
        return self._bin("addf", other)

    __radd__ = __add__

    def __sub__(self, other):  # arith.subf
        return self._bin("subf", other)

    def __rsub__(self, other):
        return self._coerce(other)._bin("subf", self)

    def __truediv__(self, other):  # arith.divf
        return self._bin("divf", other)

    def __rtruediv__(self, other):
        return self._coerce(other)._bin("divf", self)

    def __neg__(self):
        return self.ctx._emit("negf", (self.id,))

    def sqrt(self) -> "SymVal":
        return self.ctx._emit("sqrtf", (self.id,))

    def max(self, other: Union["SymVal", Number]) -> "SymVal":
        return self._bin("maxf", other)

    def min(self, other: Union["SymVal", Number]) -> "SymVal":
        return self._bin("minf", other)

    def cmpugt(self, other: Union["SymVal", Number]) -> "SymVal":
        """arith.cmpf "ugt" — unordered greater-than."""
        return self._bin("cmpugt", other)

    def select(self, if_true: "SymVal", if_false: "SymVal") -> "SymVal":
        """arith.select %self, %if_true, %if_false."""
        return self.ctx._emit(
            "select", (self.id, if_true.id, self.ctx._as_val(if_false).id))

    def __repr__(self) -> str:  # pragma: no cover
        return f"%{self.id}"


class MemRef:
    """Geometric symbol table (paper §3.1 item 3).

    Indexed by concrete integer index tuples; each slot holds the SSA symbol
    most recently stored there.  Loads of input/weight memrefs lazily create
    interface ``input`` symbols; loads of uninitialised temps are a runtime
    memory-dependence error (paper §3.1 item 1).
    """

    __slots__ = ("ctx", "name", "shape", "kind", "table", "_mem_token")

    KINDS = ("input", "weight", "temp", "output")

    def __init__(self, ctx: "Context", name: str, shape: Sequence[int],
                 kind: str):
        assert kind in self.KINDS, kind
        self.ctx = ctx
        self.name = name
        self.shape = tuple(shape)
        self.kind = kind
        self.table: dict[tuple[int, ...], SymVal] = {}
        # per-address last-access token for no-forwarding mode (serialises
        # accesses to the same address — conservative WAR/WAW ordering)
        self._mem_token: dict[tuple[int, ...], int] = {}

    def _norm(self, idx) -> tuple[int, ...]:
        shape = self.shape
        if type(idx) is tuple and len(idx) == len(shape):
            # fast path: plain in-bounds int tuple (the interpreter's own
            # loop indices) — no copy, no per-axis int() coercion
            for x, n in zip(idx, shape):
                if type(x) is not int or x < 0 or x >= n:
                    break
            else:
                return idx
        if not isinstance(idx, tuple):
            idx = (idx,)
        if len(idx) != len(shape):
            raise IndexError(
                f"{self.name}: rank mismatch {idx} vs shape {shape}")
        out = []
        for i, (x, n) in enumerate(zip(idx, shape)):
            x = int(x)
            if not (0 <= x < n):
                raise IndexError(
                    f"{self.name}: index {idx} out of bounds {shape} "
                    f"(axis {i})")
            out.append(x)
        return tuple(out)

    # -- memref.load --------------------------------------------------------

    def __getitem__(self, idx) -> SymVal:
        ctx = self.ctx
        # fast path: a slot that already holds a symbol was bounds-checked
        # when it was created — skip renormalisation
        if type(idx) is tuple:
            try:
                sym = self.table.get(idx)
            except TypeError:       # unhashable element (e.g. 0-d ndarray)
                sym = None
        else:
            sym = None
        if sym is None:
            idx = self._norm(idx)
            sym = self.table.get(idx)
        if sym is None:
            if self.kind in ("input", "weight"):
                # lazily materialise an interface symbol
                vid = ctx.graph.new_value()
                ctx.graph.inputs.setdefault(self.name, {})[idx] = vid
                if self.kind == "weight":
                    ctx.graph.weight_names.add(self.name)
                sym = SymVal(ctx, vid)
                self.table[idx] = sym
            else:
                raise RuntimeError(
                    f"memory-dependence violation: load of uninitialised "
                    f"{self.kind} memref {self.name}{list(idx)} (paper §3.1: "
                    f"runtime dependence assertion)")
        if ctx.forward:
            return sym
        # no-forwarding mode: emit an explicit load, chained on the last
        # access to this address
        prev = self._mem_token.get(idx)
        args = (sym.id,) if prev is None else (sym.id, prev)
        loaded = ctx._emit("load", args, array=self.name)
        self._mem_token[idx] = loaded.id
        return loaded

    # -- memref.store -------------------------------------------------------

    def __setitem__(self, idx, value: Union[SymVal, Number]) -> None:
        # fast path mirrors __getitem__: rewriting a slot that already holds
        # a symbol needs no renormalisation
        try:
            known = type(idx) is tuple and idx in self.table
        except TypeError:           # unhashable element (e.g. 0-d ndarray)
            known = False
        if not known:
            idx = self._norm(idx)
        ctx = self.ctx
        val = ctx._as_val(value)
        ctx._record_write(self, idx)
        if ctx.forward:
            self.table[idx] = val
            return
        prev = self._mem_token.get(idx)
        args = (val.id,) if prev is None else (val.id, prev)
        tok_vid = ctx.graph.new_value()
        ctx._emit("store", args, array=self.name, result=tok_vid)
        self._mem_token[idx] = tok_vid
        # semantics: the stored symbol is what a forwarding load would see,
        # but in no-forward mode the *token* is what later loads read through.
        self.table[idx] = SymVal(ctx, tok_vid)

    def indices(self) -> Iterator[tuple[int, ...]]:
        return itertools.product(*[range(n) for n in self.shape])


class Context:
    """Interpretation context: owns the graph under construction."""

    def __init__(self, forward: bool = True):
        self.graph = Graph()
        self.forward = forward
        self.memrefs: dict[str, MemRef] = {}
        self._const_cache: dict[float, SymVal] = {}
        self._nest_counter = 0
        self._cur_nest = -1
        self._cur_rank = -1
        # (memref name, idx) -> rank of parallel instance that wrote it;
        # reset per parallel nest (disjoint-write assertion)
        self._parallel_writes: Optional[dict[tuple[str, tuple[int, ...]], int]] = None

    # -- values -------------------------------------------------------------

    def const(self, x: float) -> SymVal:
        x = float(x)
        sym = self._const_cache.get(x)
        if sym is None:
            vid = self.graph.add_const(x)
            sym = SymVal(self, vid)
            self._const_cache[x] = sym
        return sym

    def _as_val(self, v: Union[SymVal, Number]) -> SymVal:
        return v if isinstance(v, SymVal) else self.const(float(v))

    def _emit(self, opcode: str, args: tuple[int, ...], *, array: str = "",
              result: Optional[int] = None) -> SymVal:
        # trace-time fast path: append straight into the graph's column
        # buffers (the body of ``Graph.add_op``, inlined — this is the
        # hottest call in symbolic interpretation)
        g = self.graph
        if g._lists is None:
            g._mutable_lists()
        o, a0, a1, a2, r, ne, rk, ai = g._lists
        if result is None:
            if opcode in ("store", "output"):   # same default as Graph.add_op
                result = -1
            else:
                result = g.n_values
                g.n_values = result + 1
        n = len(args)
        o.append(OPCODE_ID[opcode])
        a0.append(args[0] if n > 0 else -1)
        a1.append(args[1] if n > 1 else -1)
        a2.append(args[2] if n > 2 else -1)
        r.append(result)
        ne.append(self._cur_nest)
        rk.append(self._cur_rank)
        ai.append(g.intern_array(array) if array else 0)
        g._n_ops += 1
        g._cols = None
        if len(o) >= TRACE_CHUNK:
            g._flush_chunk()
        return SymVal(self, result)

    # -- memrefs ------------------------------------------------------------

    def memref(self, name: str, shape: Sequence[int], kind: str) -> MemRef:
        if name in self.memrefs:
            raise ValueError(f"duplicate memref {name}")
        m = MemRef(self, name, shape, kind)
        self.memrefs[name] = m
        return m

    def temp(self, name: str, shape: Sequence[int]) -> MemRef:
        return self.memref(name, shape, "temp")

    # -- loop nests ---------------------------------------------------------

    def parallel(self, *dims: int, label: str = "") -> Iterator[tuple[int, ...]]:
        """scf.parallel loop nest: iterate the cartesian product of ``dims``.

        Each yielded instance gets a linear resource rank (the paper's
        ordering "according to their execution order during symbolic
        interpretation", §3.3).  On exit, asserts that distinct instances
        wrote disjoint addresses — the behavioural stand-in for static
        dependence analysis.
        """
        nest = self._nest_counter
        self._nest_counter += 1
        k_i = 1
        for d in dims:
            k_i *= int(d)
        self.graph.nest_parallel_space[nest] = k_i
        self.graph.nest_labels[nest] = label or f"parallel_{nest}"
        outer_nest, outer_rank = self._cur_nest, self._cur_rank
        outer_writes = self._parallel_writes
        self._parallel_writes = {}
        self._cur_nest = nest
        try:
            for rank, idx in enumerate(
                    itertools.product(*[range(int(d)) for d in dims])):
                self._cur_rank = rank
                yield idx
        finally:
            self._cur_nest, self._cur_rank = outer_nest, outer_rank
            self._parallel_writes = outer_writes

    @contextmanager
    def sequential(self, label: str = ""):
        """A sequential (scf.for-only) nest — e.g. a global reduction."""
        nest = self._nest_counter
        self._nest_counter += 1
        self.graph.nest_parallel_space[nest] = 1
        self.graph.nest_labels[nest] = label or f"seq_{nest}"
        outer_nest, outer_rank = self._cur_nest, self._cur_rank
        self._cur_nest, self._cur_rank = nest, -1
        try:
            yield
        finally:
            self._cur_nest, self._cur_rank = outer_nest, outer_rank

    def _record_write(self, mem: MemRef, idx: tuple[int, ...]) -> None:
        if self._parallel_writes is None or self._cur_rank < 0:
            return
        key = (mem.name, idx)
        prev = self._parallel_writes.get(key)
        if prev is not None and prev != self._cur_rank:
            raise RuntimeError(
                f"memory-dependence violation: parallel instances {prev} and "
                f"{self._cur_rank} both write {mem.name}{list(idx)} "
                f"(scf.parallel write sets must be disjoint)")
        self._parallel_writes[key] = self._cur_rank

    # -- transcendentals (paper §3: Taylor expansion) -------------------------

    def exp(self, x: SymVal, order: int = 6) -> SymVal:
        """exp(x) via k-th order Taylor series (paper §3).

        Powers are computed by binary decomposition (x^k as a product of
        x^(2^j) factors, CSE-shared across terms) so the series has O(log k)
        depth, and the term summation is a sequential chain the
        reduction-tree pass later balances.
        """
        # x^(2^j) ladder
        pow2: list[SymVal] = [x]
        j = 1
        while (1 << j) <= order:
            pow2.append(pow2[-1] * pow2[-1])
            j += 1

        def power(k: int) -> SymVal:
            factors = [pow2[j] for j in range(len(pow2)) if k & (1 << j)]
            acc = factors[0]
            for f in factors[1:]:
                acc = acc * f
            return acc

        terms: list[SymVal] = [self.const(1.0), x]
        fact = 1.0
        for k in range(2, order + 1):
            fact *= k
            terms.append(power(k) * self.const(1.0 / fact))
        acc = terms[0]
        for t in terms[1:]:
            acc = acc + t
        return acc

    def relu(self, x: SymVal) -> SymVal:
        """Emit relu the way scf lowering produces it: cmpf ugt + select.

        The AST pass ``relu_recompose`` (paper §3.2 item 2) later coalesces
        this pair back into a single combinational ``relu`` op.
        """
        zero = self.const(0.0)
        cond = x.cmpugt(zero)
        return cond.select(x, zero)

    # -- finalisation ---------------------------------------------------------

    def finalize(self) -> Graph:
        """Freeze the graph: collect output interfaces and validate SSA."""
        for m in self.memrefs.values():
            if m.kind != "output":
                continue
            table = self.graph.outputs.setdefault(m.name, {})
            for idx in m.indices():
                sym = m.table.get(idx)
                if sym is None:
                    raise RuntimeError(
                        f"output memref {m.name}{list(idx)} never written")
                table[idx] = sym.id
        self.graph.topo_check()
        return self.graph
