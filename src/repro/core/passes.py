"""Graph transformations (paper §3.2 "AST transformations and verification").

All passes are *behaviourally* verified (see ``repro.core.verify``) rather
than formally proven — the paper's explicit trade of formal correctness for
development-time performance.  Each pass is a linear rewrite over the op
list, preserving program order (and therefore topological validity and the
resource serialisation order of §3.3).

Pass inventory, mapped to the paper:
  * ``hoist_globals``    — structural in this implementation: weights are
                           declared as interface memrefs by the frontend, and
                           this pass *verifies* no weight-like constant tensor
                           remains inline.
  * ``relu_recompose``   — cmpf ugt + select  ->  relu        (§3.2 item 2)
  * ``reduction_tree``   — sequential add/max chains -> balanced trees,
                           scheduled ALAP among subtrees      (§3.2 item 4, §3.3)
  * ``fmac_coalesce``    — mul feeding a single add -> fmac   (§3.2 item 3)
  * ``cse`` / ``dce``    — standard cleanups enabled by SSA recovery.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core.ir import ARITH_OPS, Graph, Op


class Rewriter:
    """Builds a rewritten graph while preserving the old value-id space."""

    def __init__(self, g: Graph):
        self.src = g
        self.out = Graph()
        self.out.n_values = g.n_values
        self.out.producer = [-1] * g.n_values
        self.out.inputs = {k: dict(v) for k, v in g.inputs.items()}
        self.out.outputs = {k: dict(v) for k, v in g.outputs.items()}
        self.out.consts = dict(g.consts)
        self.out.nest_parallel_space = dict(g.nest_parallel_space)
        self.out.nest_labels = dict(g.nest_labels)
        self.out.weight_names = set(g.weight_names)
        self.repl: dict[int, int] = {}

    def lookup(self, vid: int) -> int:
        while vid in self.repl:
            vid = self.repl[vid]
        return vid

    def keep(self, op: Op) -> None:
        args = tuple(self.lookup(a) for a in op.args)
        self.out.ops.append(Op(len(self.out.ops), op.opcode, args, op.result,
                               op.nest, op.rank, op.array))
        if op.result >= 0:
            self.out.producer[op.result] = len(self.out.ops) - 1

    def emit(self, opcode: str, args: Sequence[int], *, nest: int, rank: int,
             array: str = "", result: Optional[int] = None) -> int:
        args = tuple(self.lookup(a) for a in args)
        if result is None:
            result = self.out.new_value()
        self.out.ops.append(Op(len(self.out.ops), opcode, args, result, nest,
                               rank, array))
        if result >= 0:
            self.out.producer[result] = len(self.out.ops) - 1
        return result

    def replace(self, old_vid: int, new_vid: int) -> None:
        self.repl[old_vid] = new_vid

    def finish(self) -> Graph:
        # remap interface outputs through the replacement table
        for name, table in self.out.outputs.items():
            for idx in table:
                table[idx] = self.lookup(table[idx])
        self.out.topo_check()
        return self.out


# ---------------------------------------------------------------------------


def dce(g: Graph) -> Graph:
    """Dead-code elimination backwards from graph outputs.

    ``store`` ops are always considered live (baseline no-forwarding mode
    models a tool that cannot eliminate memory traffic).
    """
    live_vals = set(g.output_values())
    keep = [False] * len(g.ops)
    for op in reversed(g.ops):
        if op.opcode == "store" or (op.result >= 0 and op.result in live_vals):
            keep[op.idx] = True
            live_vals.update(op.args)
    rw = Rewriter(g)
    for op in g.ops:
        if keep[op.idx]:
            rw.keep(op)
    return rw.finish()


def cse(g: Graph) -> Graph:
    """Common-subexpression elimination (commutative-aware)."""
    commutative = {"mulf", "addf", "maxf", "minf"}
    seen: dict[tuple, int] = {}
    rw = Rewriter(g)
    for op in g.ops:
        if op.opcode not in ARITH_OPS:
            rw.keep(op)
            continue
        args = tuple(rw.lookup(a) for a in op.args)
        key_args = tuple(sorted(args)) if op.opcode in commutative else args
        key = (op.opcode, key_args)
        hit = seen.get(key)
        if hit is not None:
            rw.replace(op.result, hit)
        else:
            seen[key] = op.result
            rw.keep(op)
    return rw.finish()


def relu_recompose(g: Graph) -> Graph:
    """select(cmpf_ugt(x, 0), x, 0) -> relu(x)   (paper §3.2 item 2)."""
    uses = g.use_counts()
    zero_consts = {vid for vid, v in g.consts.items() if v == 0.0}
    # result vid -> (op, x vid) for candidate compares
    cmps: dict[int, tuple[Op, int]] = {}
    for op in g.ops:
        if (op.opcode == "cmpugt" and len(op.args) == 2
                and op.args[1] in zero_consts):
            cmps[op.result] = (op, op.args[0])
    dead_cmp: set[int] = set()
    rw = Rewriter(g)
    for op in g.ops:
        if op.opcode == "select" and op.args[0] in cmps:
            cmp_op, x = cmps[op.args[0]]
            if op.args[1] == x and op.args[2] in zero_consts:
                rw.emit("relu", (x,), nest=op.nest, rank=op.rank,
                        result=op.result)
                if uses[cmp_op.result] == 1:
                    dead_cmp.add(cmp_op.idx)
                continue
        rw.keep(op)
    out = rw.finish()
    if dead_cmp:
        out = dce(out)
    return out


def reduction_tree(g: Graph, *, threshold: int = 4) -> Graph:
    """Rebalance sequential reduction chains into binary trees (§3.2 item 4).

    A chain is a maximal run  o_1, ..., o_n  of the same associative opcode
    where each o_{t+1} consumes o_t's result and that result has no other
    use.  The chain is replaced by a balanced tree over its leaves, halving
    depth from O(n) to O(log n) — the dominant latency lever for the inner
    reduction loops of conv/linear layers.
    """
    associative = {"addf", "maxf", "minf"}
    uses = g.use_counts()
    # chain_next[i] = op idx of the chain continuation of op i (or -1)
    chain_next = [-1] * len(g.ops)
    chain_prev = [-1] * len(g.ops)
    for op in g.ops:
        if op.opcode not in associative:
            continue
        for a in op.args:
            p = g.producer[a]
            if p < 0:
                continue
            pred = g.ops[p]
            if (pred.opcode == op.opcode and uses[pred.result] == 1
                    and pred.nest == op.nest and pred.rank == op.rank):
                chain_next[p] = op.idx
                chain_prev[op.idx] = p
                break  # at most one chain predecessor

    in_chain = [False] * len(g.ops)
    chains: list[list[int]] = []  # lists of op idxs, head first
    for op in g.ops:
        if chain_prev[op.idx] >= 0 or chain_next[op.idx] < 0:
            continue  # not a chain head
        run = [op.idx]
        cur = op.idx
        while chain_next[cur] >= 0:
            cur = chain_next[cur]
            run.append(cur)
        if len(run) >= threshold - 1:  # n ops reduce n+1 leaves
            chains.append(run)
            for i in run:
                in_chain[i] = True

    tail_to_chain = {run[-1]: run for run in chains}
    rw = Rewriter(g)
    for op in g.ops:
        if in_chain[op.idx] and op.idx not in tail_to_chain:
            continue  # interior chain op: dropped, replaced at the tail
        if op.idx in tail_to_chain:
            run = tail_to_chain[op.idx]
            opcode = op.opcode
            # collect leaves in chain order
            leaves: list[int] = []
            chain_results = {g.ops[i].result for i in run}
            first = g.ops[run[0]]
            leaves.extend(first.args)
            for i in run[1:]:
                for a in g.ops[i].args:
                    if a not in chain_results:
                        leaves.append(a)
            # balanced pairwise tree
            level = leaves
            while len(level) > 1:
                nxt: list[int] = []
                for i in range(0, len(level) - 1, 2):
                    if len(level) == 2:
                        # root of the tree takes over the chain's result id
                        vid = rw.emit(opcode, (level[i], level[i + 1]),
                                      nest=op.nest, rank=op.rank,
                                      result=op.result)
                    else:
                        vid = rw.emit(opcode, (level[i], level[i + 1]),
                                      nest=op.nest, rank=op.rank)
                    nxt.append(vid)
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            continue
        rw.keep(op)
    return rw.finish()


def fmac_coalesce(g: Graph) -> Graph:
    """addf(a, mulf(b, c)) with single-use mul -> fmac(b, c, a) (§3.2 item 3)."""
    uses = g.use_counts()
    muls: dict[int, Op] = {}
    for op in g.ops:
        if op.opcode == "mulf" and uses[op.result] == 1:
            muls[op.result] = op
    fused_muls: set[int] = set()
    rw = Rewriter(g)
    for op in g.ops:
        if op.idx in fused_muls:
            continue
        if op.opcode == "addf":
            a0, a1 = op.args
            mul = None
            addend = None
            if a1 in muls:
                mul, addend = muls[a1], a0
            elif a0 in muls:
                mul, addend = muls[a0], a1
            if mul is not None:
                rw.emit("fmac", (mul.args[0], mul.args[1], addend),
                        nest=op.nest, rank=op.rank, result=op.result)
                fused_muls.add(mul.idx)
                continue
        rw.keep(op)
    out = rw.finish()
    return dce(out)


def hoist_globals_check(g: Graph) -> None:
    """Verify weights live at the interface, not inline (paper §3.2 item 1).

    In this implementation hoisting happens by construction (the frontend
    declares weights as interface memrefs), so the pass is an assertion:
    every weight name must appear in ``graph.inputs``.
    """
    for name in g.weight_names:
        if name not in g.inputs:
            raise AssertionError(f"weight {name} not hoisted to interface")


DEFAULT_PIPELINE = ("cse", "relu_recompose", "reduction_tree",
                    "fmac_coalesce", "dce")


def optimize(g: Graph, *, pipeline: Sequence[str] = DEFAULT_PIPELINE,
             tree_threshold: int = 4, max_rounds: int = 4) -> Graph:
    """Run the standard pass pipeline to a fixpoint (the OpenHLS 'opt' flow).

    Compatibility wrapper: the flow now lives in
    ``repro.core.pipeline.PassManager`` (decorator-registered passes,
    per-pass ``PassReport`` instrumentation, fixpoint driving).  This
    wrapper produces bit-identical graphs and is kept for callers that only
    want the optimised graph.
    """
    from repro.core.pipeline import PassManager  # deferred: avoids cycle
    pm = PassManager(
        pipeline, max_rounds=max_rounds,
        pass_options={"reduction_tree": {"threshold": tree_threshold}})
    g, _reports = pm.run(g)
    return g
