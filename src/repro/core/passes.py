"""Graph transformations (paper §3.2 "AST transformations and verification").

All passes are *behaviourally* verified (see ``repro.core.verify``) rather
than formally proven — the paper's explicit trade of formal correctness for
development-time performance.  Each pass is a linear rewrite over the op
table, preserving program order (and therefore topological validity and the
resource serialisation order of §3.3).

Pass inventory, mapped to the paper:
  * ``hoist_globals``    — structural in this implementation: weights are
                           declared as interface memrefs by the frontend, and
                           this pass *verifies* no weight-like constant tensor
                           remains inline.
  * ``relu_recompose``   — cmpf ugt + select  ->  relu        (§3.2 item 2)
  * ``reduction_tree``   — sequential add/max chains -> balanced trees,
                           scheduled ALAP among subtrees      (§3.2 item 4, §3.3)
  * ``fmac_coalesce``    — mul feeding a single add -> fmac   (§3.2 item 3)
  * ``cse`` / ``dce``    — standard cleanups enabled by SSA recovery.

Implementation notes
--------------------
The passes here are the *vectorised* struct-of-arrays implementations: each
consumes ``Graph.cols()`` (dense int32 columns), computes its rewrite with
numpy array operations — row hashing for CSE, a frontier liveness sweep for
DCE, pattern masks for relu/fmac, array chain-walking for reduction trees —
and builds its output in one shot with ``Graph.from_columns``.  They are
bit-identical to the historical per-``Op`` rewrites, which survive in
``repro.core.legacy`` (set ``REPRO_LEGACY_IR=1`` to route through them; the
golden suite compares both paths exactly).

Two contracts the incremental ``PassManager`` fixpoint relies on:

  * a pass that has nothing to rewrite returns its input ``Graph`` object
    *unchanged* (identity comparison = cheap "did anything happen");
  * a pass that does rewrite annotates the result with ``_touched`` — the
    frozenset of opcode names whose rows were added, removed, or had
    operands remapped — which drives the per-pass dirty bits.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

import numpy as np

from repro.core.ir import (ARITH_MASK, ARITH_OPS, ID_ADDF, ID_CMPUGT,
                           ID_MAXF, ID_MINF, ID_MULF, ID_RELU, ID_SELECT,
                           ID_STORE, OPCODE_ID, OPCODES, Graph, Op)

#: Opcodes whose operand order does not matter for CSE.
COMMUTATIVE_OPS = frozenset({"mulf", "addf", "maxf", "minf"})
_COMMUT_MASK = np.array([name in COMMUTATIVE_OPS for name in OPCODES],
                        dtype=bool)


def _use_legacy() -> bool:
    """Route through the historical per-op implementations on demand."""
    return os.environ.get("REPRO_LEGACY_IR", "") == "1"


def _opcode_names(opcode_rows: np.ndarray) -> frozenset:
    return frozenset(OPCODES[i] for i in np.unique(opcode_rows))


class Rewriter:
    """Builds a rewritten graph while preserving the old value-id space.

    Compatibility shim for per-op rewrites (the legacy pass path and any
    external pass that prefers record-level rewriting).  ``lookup`` is a
    path-compressed union-find: resolving a replacement chain of length k
    costs O(k) once and O(1) thereafter — the historical implementation
    re-walked the whole chain on every query.
    """

    def __init__(self, g: Graph):
        self.src = g
        self.out = Graph()
        self.out._copy_meta(g)
        self.repl: dict[int, int] = {}

    def lookup(self, vid: int) -> int:
        repl = self.repl
        root = vid
        while root in repl:
            root = repl[root]
        # path compression: point every chain member at the root
        while vid != root:
            nxt = repl[vid]
            repl[vid] = root
            vid = nxt
        return root

    def keep(self, op: Op, *, args: Optional[tuple[int, ...]] = None) -> None:
        """Copy ``op`` into the output, remapping operands.

        ``args`` short-circuits the remap when the caller already resolved
        the operands (CSE computes them for its value-numbering key; the
        historical code looked every operand up a second time here).
        """
        if args is None:
            args = tuple(self.lookup(a) for a in op.args)
        self.out.add_op(op.opcode, args, nest=op.nest, rank=op.rank,
                        array=op.array, result=op.result)

    def emit(self, opcode: str, args: Sequence[int], *, nest: int, rank: int,
             array: str = "", result: Optional[int] = None) -> int:
        args = tuple(self.lookup(a) for a in args)
        return self.out.add_op(opcode, args, nest=nest, rank=rank,
                               array=array, result=result)

    def replace(self, old_vid: int, new_vid: int) -> None:
        self.repl[old_vid] = new_vid

    def finish(self) -> Graph:
        # remap interface outputs through the replacement table
        for name, table in self.out.outputs.items():
            for idx in table:
                table[idx] = self.lookup(table[idx])
        self.out.topo_check()
        return self.out


# ---------------------------------------------------------------------------
# dce
# ---------------------------------------------------------------------------


def _dce_impl(g: Graph) -> tuple[Graph, frozenset]:
    c = g.cols()
    n = c.n
    if n == 0:
        return g, frozenset()
    keep = c.opcode == ID_STORE
    live = np.zeros(max(g.n_values, 1), dtype=bool)
    seeds = []
    out_vals = g.output_values()
    if out_vals:
        seeds.append(np.asarray(out_vals, dtype=np.int64))
    if keep.any():
        sa = c.args[keep]
        seeds.append(sa[sa >= 0].astype(np.int64))
    frontier = (np.unique(np.concatenate(seeds)) if seeds
                else np.empty(0, dtype=np.int64))
    live[frontier] = True
    prod = c.producer
    # frontier liveness sweep: each round marks the producers of newly-live
    # values and enqueues their operands — linear total work (each op joins
    # the frontier at most once), O(DAG depth) numpy rounds
    while frontier.size:
        p = prod[frontier]
        p = p[p >= 0]
        p = p[~keep[p]]
        if p.size == 0:
            break
        keep[p] = True
        na = c.args[p]
        na = na[na >= 0]
        na = na[~live[na]]
        frontier = np.unique(na)
        live[frontier] = True
    if keep.all():
        return g, frozenset()
    touched = _opcode_names(c.opcode[~keep])
    idx = np.flatnonzero(keep)
    g2 = Graph.from_columns(g, c.opcode[idx], c.args[idx], c.result[idx],
                            c.nest[idx], c.rank[idx], c.array_id[idx])
    return g2, touched


def dce(g: Graph) -> Graph:
    """Dead-code elimination backwards from graph outputs.

    ``store`` ops are always considered live (baseline no-forwarding mode
    models a tool that cannot eliminate memory traffic).
    """
    if _use_legacy():
        from repro.core import legacy
        return legacy.dce(g)
    out, touched = _dce_impl(g)
    if out is not g:
        out._touched = touched
        out.topo_check()   # same SSA validation Rewriter.finish always ran
    return out


# ---------------------------------------------------------------------------
# cse
# ---------------------------------------------------------------------------


def _cse_impl(g: Graph) -> tuple[Graph, frozenset]:
    c = g.cols()
    n = c.n
    arith_rows = ARITH_MASK[c.opcode] if n else np.zeros(0, dtype=bool)
    if not arith_rows.any():
        return g, frozenset()
    opc64 = c.opcode.astype(np.int64)
    commut = _COMMUT_MASK[c.opcode]
    args = c.args.astype(np.int64)
    res = c.result.astype(np.int64)
    repl = np.arange(g.n_values, dtype=np.int64)
    is_dup = np.zeros(n, dtype=bool)
    # Value-numbering to a fixpoint: each round canonicalises operands
    # through the replacement map, hashes the rows, and marks every row
    # whose (opcode, canonical args) key was first produced by an earlier
    # row.  Later rounds catch duplicates that only become apparent once
    # their operands were themselves deduplicated — the same closure the
    # sequential first-wins scan computed one op at a time.
    while True:
        rows = np.flatnonzero(arith_rows & ~is_dup)
        a = args[rows]
        m = np.where(a >= 0, repl[np.clip(a, 0, None)], np.int64(-1))
        cm = commut[rows]
        lo = np.minimum(m[:, 0], m[:, 1])
        hi = np.maximum(m[:, 0], m[:, 1])
        k0 = opc64[rows]
        k1 = np.where(cm, lo, m[:, 0])
        k2 = np.where(cm, hi, m[:, 1])
        k3 = m[:, 2]
        # group identical keys via one lexsort (stable: rows stay in program
        # order inside each group, so the group leader = first occurrence)
        order = np.lexsort((k3, k2, k1, k0))
        sr = rows[order]
        k0s, k1s, k2s, k3s = k0[order], k1[order], k2[order], k3[order]
        newgrp = np.empty(len(sr), dtype=bool)
        newgrp[:1] = True
        newgrp[1:] = ((k0s[1:] != k0s[:-1]) | (k1s[1:] != k1s[:-1])
                      | (k2s[1:] != k2s[:-1]) | (k3s[1:] != k3s[:-1]))
        grp_starts = np.flatnonzero(newgrp)
        grp_sizes = np.diff(np.append(grp_starts, len(sr)))
        owner_sorted = np.repeat(sr[grp_starts], grp_sizes)
        dupm = sr != owner_sorted
        if not dupm.any():
            break
        new_dups = sr[dupm]
        is_dup[new_dups] = True
        repl[res[new_dups]] = repl[res[owner_sorted[dupm]]]
    if not is_dup.any():
        return g, frozenset()
    # resolve replacement chains (a round-2 duplicate may point at a value
    # that round 3 itself deduplicated)
    while True:
        r2 = repl[repl]
        if np.array_equal(r2, repl):
            break
        repl = r2
    kept = np.flatnonzero(~is_dup)
    a = args[kept]
    new_args = np.where(a >= 0, repl[np.clip(a, 0, None)], np.int64(-1))
    remapped = (new_args != a).any(axis=1)
    g2 = Graph.from_columns(g, c.opcode[kept], new_args, res[kept],
                            c.nest[kept], c.rank[kept], c.array_id[kept])
    for table in g2.outputs.values():
        for k in table:
            table[k] = int(repl[table[k]])
    touched = (_opcode_names(c.opcode[is_dup])
               | _opcode_names(c.opcode[kept][remapped]))
    return g2, touched


def cse(g: Graph) -> Graph:
    """Common-subexpression elimination (commutative-aware, row-hashed)."""
    if _use_legacy():
        from repro.core import legacy
        return legacy.cse(g)
    out, touched = _cse_impl(g)
    if out is not g:
        out._touched = touched
        out.topo_check()   # same SSA validation Rewriter.finish always ran
    return out


# ---------------------------------------------------------------------------
# relu_recompose
# ---------------------------------------------------------------------------


def _relu_impl(g: Graph) -> tuple[Graph, frozenset]:
    c = g.cols()
    n = c.n
    if n == 0 or not g.consts:
        return g, frozenset()
    zero = np.zeros(max(g.n_values, 1), dtype=bool)
    zvids = [vid for vid, v in g.consts.items() if v == 0.0]
    if not zvids:
        return g, frozenset()
    zero[np.asarray(zvids, dtype=np.int64)] = True
    opc = c.opcode
    a0, a1, a2 = c.args[:, 0], c.args[:, 1], c.args[:, 2]
    # candidate compares: cmpugt(x, 0)
    cmp_rows = (opc == ID_CMPUGT) & (a1 >= 0) \
        & np.take(zero, np.clip(a1, 0, None)) & (a2 < 0)
    if not cmp_rows.any():
        return g, frozenset()
    cmp_x = np.full(max(g.n_values, 1), -1, dtype=np.int64)
    cmp_x[c.result[cmp_rows]] = a0[cmp_rows]
    # matching selects: select(cmp, x, 0) with the same x
    sel = opc == ID_SELECT
    xv = np.take(cmp_x, np.clip(a0, 0, None))
    match = sel & (a0 >= 0) & (xv >= 0) & (a1 == xv) & (a2 >= 0) \
        & np.take(zero, np.clip(a2, 0, None))
    if not match.any():
        return g, frozenset()
    new_opc = opc.copy()
    new_opc[match] = ID_RELU
    new_args = c.args.copy()
    new_args[match, 0] = xv[match]
    new_args[match, 1] = -1
    new_args[match, 2] = -1
    g2 = Graph.from_columns(g, new_opc, new_args, c.result, c.nest, c.rank,
                            c.array_id)
    touched = frozenset({"select", "relu"})
    uses = g.use_counts()
    if (uses[a0[match]] == 1).any():    # the rewritten selects' compares died
        g3, t2 = _dce_impl(g2)
        return g3, touched | t2
    return g2, touched


def relu_recompose(g: Graph) -> Graph:
    """select(cmpf_ugt(x, 0), x, 0) -> relu(x)   (paper §3.2 item 2)."""
    if _use_legacy():
        from repro.core import legacy
        return legacy.relu_recompose(g)
    out, touched = _relu_impl(g)
    if out is not g:
        out._touched = touched
        out.topo_check()   # same SSA validation Rewriter.finish always ran
    return out


# ---------------------------------------------------------------------------
# reduction_tree
# ---------------------------------------------------------------------------


def _reduction_tree_impl(g: Graph, threshold: int) -> tuple[Graph, frozenset]:
    c = g.cols()
    n = c.n
    if n == 0:
        return g, frozenset()
    opc = c.opcode
    assoc = (opc == ID_ADDF) | (opc == ID_MAXF) | (opc == ID_MINF)
    if not assoc.any():
        return g, frozenset()
    uses = g.use_counts()
    args, res, nest, rank = c.args, c.result, c.nest, c.rank
    prod = c.producer
    rows = np.flatnonzero(assoc)

    def link(acol: np.ndarray) -> np.ndarray:
        """Chain predecessor of each candidate row through one arg column."""
        ok = acol >= 0
        p = np.where(ok, prod[np.clip(acol, 0, None)], -1).astype(np.int64)
        ok &= p >= 0
        pc = np.clip(p, 0, None)
        ok &= opc[pc] == opc[rows]
        ok &= uses[np.clip(res[pc], 0, None)] == 1
        ok &= nest[pc] == nest[rows]
        ok &= rank[pc] == rank[rows]
        return np.where(ok, p, np.int64(-1))

    p0 = link(args[rows, 0])
    p1 = link(args[rows, 1])
    prev_rows = np.where(p0 >= 0, p0, p1)   # first matching arg wins
    chain_prev = np.full(n, -1, dtype=np.int64)
    chain_prev[rows] = prev_rows
    chain_next = np.full(n, -1, dtype=np.int64)
    linked = prev_rows >= 0
    chain_next[prev_rows[linked]] = rows[linked]

    heads = np.flatnonzero((chain_prev < 0) & (chain_next >= 0))
    cnl = chain_next.tolist()
    chains: list[list[int]] = []
    for h in heads.tolist():
        run = [h]
        cur = h
        while cnl[cur] >= 0:
            cur = cnl[cur]
            run.append(cur)
        if len(run) >= threshold - 1:   # n ops reduce n+1 leaves
            chains.append(run)
    if not chains:
        return g, frozenset()

    # splice layout: interior chain rows vanish, each tail expands into its
    # balanced tree (same op count: a chain of k ops has k+1 leaves)
    out_size = np.ones(n, dtype=np.int64)
    all_rows = np.concatenate([np.asarray(r, dtype=np.int64) for r in chains])
    out_size[all_rows] = 0
    for run in chains:
        out_size[run[-1]] = len(run)
    starts = np.zeros(n, dtype=np.int64)
    np.cumsum(out_size[:-1], out=starts[1:])
    total = int(out_size.sum())

    new_opc = np.empty(total, dtype=np.int32)
    new_args = np.full((total, 3), -1, dtype=np.int32)
    new_res = np.empty(total, dtype=np.int32)
    new_nest = np.empty(total, dtype=np.int32)
    new_rank = np.empty(total, dtype=np.int32)
    new_arr = np.zeros(total, dtype=np.int32)

    copy_src = np.flatnonzero(out_size == 1)
    copy_dst = starts[copy_src]
    new_opc[copy_dst] = opc[copy_src]
    new_args[copy_dst] = args[copy_src]
    new_res[copy_dst] = res[copy_src]
    new_nest[copy_dst] = nest[copy_src]
    new_rank[copy_dst] = rank[copy_src]
    new_arr[copy_dst] = c.array_id[copy_src]

    # per-chain tree emission, in tail program order (value-id allocation
    # order matches the sequential rewriter exactly); ops accumulate into
    # flat lists and scatter into the output columns in one shot
    a0l = args[:, 0].tolist()
    a1l = args[:, 1].tolist()
    resl = res.tolist()
    nestl = nest.tolist()
    rankl = rank.tolist()
    opcl = opc.tolist()
    nv = g.n_values
    touched: set[str] = set()
    t_op: list[int] = []
    t_a0: list[int] = []
    t_a1: list[int] = []
    t_res: list[int] = []
    t_nest: list[int] = []
    t_rank: list[int] = []
    by_tail = sorted(chains, key=lambda r: r[-1])
    for run in by_tail:
        tail = run[-1]
        ocode = opcl[tail]
        touched.add(OPCODES[ocode])
        tl_nest = nestl[tail]
        tl_rank = rankl[tail]
        tl_res = resl[tail]
        chain_res = {resl[i] for i in run}
        head = run[0]
        leaves = [a0l[head], a1l[head]]
        for i in run[1:]:
            a = a0l[i]
            if a not in chain_res:
                leaves.append(a)
            a = a1l[i]
            if a not in chain_res:
                leaves.append(a)
        level = leaves
        while len(level) > 1:
            nxt: list[int] = []
            L = len(level)
            for i in range(0, L - 1, 2):
                if L == 2:
                    vid = tl_res     # tree root takes over the chain result
                else:
                    vid = nv
                    nv += 1
                t_op.append(ocode)
                t_a0.append(level[i])
                t_a1.append(level[i + 1])
                t_res.append(vid)
                t_nest.append(tl_nest)
                t_rank.append(tl_rank)
                nxt.append(vid)
            if L % 2:
                nxt.append(level[-1])
            level = nxt

    tails = np.array([run[-1] for run in by_tail], dtype=np.int64)
    lens = np.array([len(run) for run in by_tail], dtype=np.int64)
    base = np.repeat(starts[tails], lens)
    within = np.arange(int(lens.sum())) - np.repeat(np.cumsum(lens) - lens,
                                                    lens)
    pos = base + within
    new_opc[pos] = t_op
    new_args[pos, 0] = t_a0
    new_args[pos, 1] = t_a1
    new_res[pos] = t_res
    new_nest[pos] = t_nest
    new_rank[pos] = t_rank

    g2 = Graph.from_columns(g, new_opc, new_args, new_res, new_nest,
                            new_rank, new_arr, n_values=nv)
    return g2, frozenset(touched)


def reduction_tree(g: Graph, *, threshold: int = 4) -> Graph:
    """Rebalance sequential reduction chains into binary trees (§3.2 item 4).

    A chain is a maximal run  o_1, ..., o_n  of the same associative opcode
    where each o_{t+1} consumes o_t's result and that result has no other
    use.  The chain is replaced by a balanced tree over its leaves, halving
    depth from O(n) to O(log n) — the dominant latency lever for the inner
    reduction loops of conv/linear layers.
    """
    if _use_legacy():
        from repro.core import legacy
        return legacy.reduction_tree(g, threshold=threshold)
    out, touched = _reduction_tree_impl(g, threshold)
    if out is not g:
        out._touched = touched
        out.topo_check()   # same SSA validation Rewriter.finish always ran
    return out


# ---------------------------------------------------------------------------
# fmac_coalesce
# ---------------------------------------------------------------------------


def _fmac_impl(g: Graph) -> tuple[Graph, frozenset]:
    c = g.cols()
    n = c.n
    if n == 0:
        return _dce_impl(g)
    opc = c.opcode
    uses = g.use_counts()
    res = c.result
    mul_rows = (opc == ID_MULF) & (uses[np.clip(res, 0, None)] == 1) \
        & (res >= 0)
    if not mul_rows.any():
        return _dce_impl(g)
    mul_of = np.full(max(g.n_values, 1), -1, dtype=np.int64)
    mul_of[res[mul_rows]] = np.flatnonzero(mul_rows)
    a0, a1 = c.args[:, 0], c.args[:, 1]
    addf = opc == ID_ADDF
    m1 = np.where(addf & (a1 >= 0),
                  np.take(mul_of, np.clip(a1, 0, None)), np.int64(-1))
    m0 = np.where(addf & (a0 >= 0),
                  np.take(mul_of, np.clip(a0, 0, None)), np.int64(-1))
    use1 = m1 >= 0                 # mul on the right wins, as in the original
    use0 = ~use1 & (m0 >= 0)
    match = use1 | use0
    if not match.any():
        return _dce_impl(g)
    mrow = np.where(use1, m1, m0)[match]
    addend = np.where(use1, a0, a1)[match]
    new_opc = opc.copy()
    new_opc[match] = OPCODE_ID["fmac"]
    new_args = c.args.copy()
    new_args[match, 0] = c.args[mrow, 0]
    new_args[match, 1] = c.args[mrow, 1]
    new_args[match, 2] = addend
    g2 = Graph.from_columns(g, new_opc, new_args, res, c.nest, c.rank,
                            c.array_id)
    touched = frozenset({"addf", "fmac"})
    g3, t2 = _dce_impl(g2)          # the fused muls are dead now
    return g3, touched | t2


def fmac_coalesce(g: Graph) -> Graph:
    """addf(a, mulf(b, c)) with single-use mul -> fmac(b, c, a) (§3.2 item 3)."""
    if _use_legacy():
        from repro.core import legacy
        return legacy.fmac_coalesce(g)
    out, touched = _fmac_impl(g)
    if out is not g:
        out._touched = touched
        out.topo_check()   # same SSA validation Rewriter.finish always ran
    return out


# ---------------------------------------------------------------------------


def hoist_globals_check(g: Graph) -> None:
    """Verify weights live at the interface, not inline (paper §3.2 item 1).

    In this implementation hoisting happens by construction (the frontend
    declares weights as interface memrefs), so the pass is an assertion:
    every weight name must appear in ``graph.inputs``.
    """
    for name in g.weight_names:
        if name not in g.inputs:
            raise AssertionError(f"weight {name} not hoisted to interface")


DEFAULT_PIPELINE = ("cse", "relu_recompose", "reduction_tree",
                    "fmac_coalesce", "dce")


def optimize(g: Graph, *, pipeline: Sequence[str] = DEFAULT_PIPELINE,
             tree_threshold: int = 4, max_rounds: int = 4) -> Graph:
    """Run the standard pass pipeline to a fixpoint (the OpenHLS 'opt' flow).

    Compatibility wrapper: the flow now lives in
    ``repro.core.pipeline.PassManager`` (decorator-registered passes,
    per-pass ``PassReport`` instrumentation, fixpoint driving).  This
    wrapper produces bit-identical graphs and is kept for callers that only
    want the optimised graph.
    """
    from repro.core.pipeline import PassManager  # deferred: avoids cycle
    pm = PassManager(
        pipeline, max_rounds=max_rounds,
        pass_options={"reduction_tree": {"threshold": tree_threshold}})
    g, _reports = pm.run(g)
    return g
