"""Latency + resource budgets as first-class, checkable constraints.

The paper's 4.8 µs/sample BraggNN number is a *budget*, not just a
benchmark: a trigger design that misses the interval target or spills the
device's DSP pool does not deploy, full stop.  This module turns that
into structure:

  * :class:`TriggerBudget` — the envelope: max per-sample latency (µs),
    max initiation interval (intervals), and per-resource caps (explicit,
    or inherited from a named :class:`~repro.trigger.parts.Part`);
  * :class:`BudgetReport` — the verdict of checking one compiled design
    against a budget: one :class:`BudgetCheck` row per constraint with
    used/cap/margin, ``passed``, and the *named* offending resources;
  * :func:`check_design` — reads ``schedule.resources()``, ``stage_ii``
    and ``sample_latency_us`` off a ``CompiledDesign`` (or the
    ``Design`` wrapper) and produces the report.

``Design.check_budget(...)`` and ``Design.report(budget=...)`` are the
front doors; ``repro.tune``'s evaluator uses the same check as a hard
feasibility gate (an over-budget candidate can never win a search).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.trigger.parts import Part, get_part

#: check-row kinds that are not device resource pools
_LATENCY = "latency_us"
_II = "stage_ii"


@dataclasses.dataclass(frozen=True)
class TriggerBudget:
    """One deployment envelope.

    ``max_latency_us`` bounds the scheduled per-sample decision latency
    (``CompiledDesign.sample_latency_us``: II x clock for pipelined
    designs, makespan x clock otherwise); ``max_ii`` bounds the stage
    initiation interval in raw intervals (an unpipelined design is
    checked on its makespan).  Resource caps come from ``part`` and can
    be tightened per pool (an explicit ``max_*`` always wins over the
    part's number).  ``margin`` demands fractional headroom on every
    resource pool: with ``margin=0.2`` a design may use at most 80% of
    each cap — latency/II caps are applied exactly, margins there belong
    in the number you pick.
    """

    max_latency_us: Optional[float] = None
    max_ii: Optional[int] = None
    part: Optional[Union[str, Part]] = None
    max_dsp: Optional[int] = None
    max_ff: Optional[int] = None
    max_bram_ports: Optional[int] = None
    max_lut: Optional[int] = None
    margin: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.margin < 1.0:
            raise ValueError(f"margin must be in [0, 1), got {self.margin}")
        # normalise part references eagerly so a typo fails at
        # construction, not at the first check
        object.__setattr__(self, "part", get_part(self.part))

    def resource_caps(self) -> dict[str, int]:
        """Merged per-resource caps (explicit ``max_*`` over the part)."""
        caps: dict[str, int] = dict(self.part.caps()) if self.part else {}
        for key, cap in (("DSP", self.max_dsp), ("FF", self.max_ff),
                         ("BRAM_ports", self.max_bram_ports),
                         ("LUT_units", self.max_lut)):
            if cap is not None:
                caps[key] = cap
        return caps

    def key(self) -> str:
        """Stable identity string (tuning-run context hashing)."""
        caps = ",".join(f"{k}={v}" for k, v in
                        sorted(self.resource_caps().items()))
        return (f"lat<={self.max_latency_us}|ii<={self.max_ii}|{caps}"
                f"|margin={self.margin}")

    def describe(self) -> str:
        bits = []
        if self.max_latency_us is not None:
            bits.append(f"latency <= {self.max_latency_us:g} us")
        if self.max_ii is not None:
            bits.append(f"II <= {self.max_ii}")
        if self.part is not None:
            bits.append(f"part {self.part.name}")
        over = {k: v for k, v in self.resource_caps().items()
                if self.part is None or self.part.caps().get(k) != v}
        if over:
            bits.append(", ".join(f"{k} <= {v:,}" for k, v in over.items()))
        if self.margin:
            bits.append(f"{self.margin:.0%} headroom")
        return "; ".join(bits) or "(unconstrained)"


@dataclasses.dataclass(frozen=True)
class BudgetCheck:
    """One constraint row: what the design uses vs what the budget allows.

    ``cap`` is the *effective* cap (resource margins already applied).
    """

    name: str
    used: float
    cap: float
    ok: bool

    @property
    def slack(self) -> float:
        return self.cap - self.used

    @property
    def utilisation(self) -> float:
        return self.used / self.cap if self.cap else float("inf")

    def summary(self) -> str:
        tag = "ok  " if self.ok else "FAIL"
        return (f"[{tag}] {self.name:10s} {self.used:>12,.6g} / "
                f"{self.cap:<12,.6g} ({self.utilisation:.1%} of cap, "
                f"slack {self.slack:,.6g})")


@dataclasses.dataclass
class BudgetReport:
    """The structured pass/fail verdict of one design-vs-budget check."""

    design: str
    budget: TriggerBudget
    checks: list[BudgetCheck]

    @property
    def passed(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def failures(self) -> list[str]:
        """Names of every violated constraint (``DSP``, ``latency_us``...)."""
        return [c.name for c in self.checks if not c.ok]

    def check(self, name: str) -> Optional[BudgetCheck]:
        for c in self.checks:
            if c.name == name:
                return c
        return None

    def summary(self) -> str:
        verdict = "PASS" if self.passed else \
            f"FAIL ({', '.join(self.failures)} over budget)"
        lines = [f"budget check [{verdict}] {self.design} vs "
                 f"{self.budget.describe()}"]
        lines += [f"  {c.summary()}" for c in self.checks]
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "design": self.design,
            "passed": self.passed,
            "failures": self.failures,
            "budget": self.budget.key(),
            "part": self.budget.part.name if self.budget.part else None,
            "checks": [{"name": c.name, "used": c.used, "cap": c.cap,
                        "ok": c.ok, "slack": c.slack,
                        "utilisation": round(c.utilisation, 4)}
                       for c in self.checks],
        }

    def raise_if_failed(self) -> "BudgetReport":
        """Hard-gate form: raises ``BudgetError`` naming the offenders."""
        if not self.passed:
            raise BudgetError(self)
        return self


class BudgetError(RuntimeError):
    """A design blew its trigger budget (carries the full report)."""

    def __init__(self, report: BudgetReport):
        self.report = report
        super().__init__(report.summary())


def check_design(design, budget: Optional[TriggerBudget] = None, *,
                 part: Optional[Union[str, Part]] = None) -> BudgetReport:
    """Check one compiled design against a budget -> :class:`BudgetReport`.

    ``design`` is anything with ``schedule.resources()``, ``stage_ii``,
    ``sample_latency_us``, ``makespan`` and ``name`` — a
    ``CompiledDesign`` or the ``repro.hls.Design`` wrapper.  ``part``
    is shorthand for a resource-caps-only budget; when both are given
    the part overrides the budget's own (so one budget template can be
    checked against several devices).
    """
    if budget is None and part is None:
        raise ValueError("give a TriggerBudget, a part, or both")
    if budget is None:
        budget = TriggerBudget(part=part)
    elif part is not None:
        budget = dataclasses.replace(budget, part=get_part(part))

    checks: list[BudgetCheck] = []
    if budget.max_latency_us is not None:
        used = float(design.sample_latency_us)
        checks.append(BudgetCheck(_LATENCY, used, float(budget.max_latency_us),
                                  used <= budget.max_latency_us))
    if budget.max_ii is not None:
        ii = design.stage_ii if design.stage_ii is not None \
            else design.makespan
        checks.append(BudgetCheck(_II, float(ii), float(budget.max_ii),
                                  ii <= budget.max_ii))
    used_res = design.schedule.resources()
    scale = 1.0 - budget.margin
    for name, cap in sorted(budget.resource_caps().items()):
        used = float(used_res.get(name, 0))
        eff = cap * scale
        checks.append(BudgetCheck(name, used, eff, used <= eff))
    return BudgetReport(design=design.name, budget=budget, checks=checks)
