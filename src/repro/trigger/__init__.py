"""Hard-real-time streaming trigger: budgets, parts, and the stream loop.

OpenHLS exists for the data-acquisition trigger setting — a fixed
µs-scale latency budget, streaming sensor input, no host in the loop.
This package makes that setting first-class instead of folklore:

  * :mod:`repro.trigger.parts` — a catalog of named FPGA parts
    (:data:`alveo_u280`, :data:`zcu102`, synthetic :func:`part`) whose
    resource pools speak the same vocabulary as
    ``Schedule.resources()``;
  * :mod:`repro.trigger.budget` — :class:`TriggerBudget` (max latency
    µs, max II, per-resource caps with headroom margins) and
    :func:`check_design` producing a structured :class:`BudgetReport`
    (``Design.check_budget`` / ``Design.report(budget=...)`` are the
    front doors; ``tune`` uses the same check as a feasibility gate);
  * :mod:`repro.trigger.stream` — :class:`DetectorFeed` (seeded
    Bragg-peak frames with pileup bursts), the drop-oldest ring, and
    :class:`TriggerLoop` emitting accept/reject decisions with
    per-window deadline accounting on any emission backend.

Quickstart::

    from repro import hls, trigger

    design = hls.compile(braggnn.bind(params), x)
    budget = trigger.TriggerBudget(max_latency_us=75.0, max_ii=4,
                                   part="alveo_u280", margin=0.1)
    design.check_budget(budget=budget).raise_if_failed()

    loop = trigger.TriggerLoop(design, budget=budget, backend="pallas")
    report = loop.run(trigger.DetectorFeed(img=11, frame_rate_hz=2000),
                      n_frames=1000, realtime=True)
    print(report.summary())     # sustained fps, miss %, drop %, p99 µs
"""

from repro.trigger.budget import (BudgetCheck, BudgetError, BudgetReport,
                                  TriggerBudget, check_design)
from repro.trigger.parts import (PARTS, Part, alveo_u280, get_part, part,
                                 zcu102)
from repro.trigger.stream import (DetectorFeed, Frame, TriggerDecision,
                                  TriggerLoop, TriggerReport,
                                  threshold_predicate)

__all__ = [
    "Part", "PARTS", "alveo_u280", "zcu102", "part", "get_part",
    "TriggerBudget", "BudgetCheck", "BudgetReport", "BudgetError",
    "check_design",
    "DetectorFeed", "Frame", "TriggerDecision", "TriggerLoop",
    "TriggerReport", "threshold_predicate",
]
