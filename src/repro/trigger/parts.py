"""Named FPGA parts: the resource envelopes budgets are checked against.

The collider-trigger synthesis study (PAPERS.md: 2411.11678) and hls4ml
(1804.06913) both frame deployment as "does the design fit the latency
AND resource envelope of a *named part*".  This catalog makes the part a
first-class value instead of a scattered constant: ``alveo_u280`` is the
paper's deployment device (its 9,024 DSP slices were previously the
hard-coded ``U280_DSP`` inside ``benchmarks/bench_braggnn.py``),
``zcu102`` is the embedded-class comparison point, and :func:`part`
builds a synthetic device for tests and what-if studies.

A :class:`Part` speaks the same resource vocabulary as
``Schedule.resources()`` — DSP units, FF (registered live values),
BRAM ports, LUT units — via :meth:`Part.caps`, so a budget check is a
straight per-resource comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union


@dataclasses.dataclass(frozen=True)
class Part:
    """One named device: its usable resource pools.

    ``bram`` counts 36 Kb block instances; the schedule's resource model
    accounts *ports* (dual-ported blocks), so the comparable cap is
    ``2 * bram`` — :meth:`caps` does that mapping.  A ``None`` pool means
    "unconstrained" (e.g. a synthetic test part capping only DSPs).
    """

    name: str
    dsp: Optional[int] = None
    ff: Optional[int] = None
    bram: Optional[int] = None
    lut: Optional[int] = None

    def caps(self) -> dict[str, int]:
        """Per-resource caps keyed like ``Schedule.resources()``.

        Only constrained pools appear; BRAM blocks are exposed as ports
        (2 per dual-ported block).
        """
        out: dict[str, int] = {}
        if self.dsp is not None:
            out["DSP"] = self.dsp
        if self.ff is not None:
            out["FF"] = self.ff
        if self.bram is not None:
            out["BRAM_ports"] = 2 * self.bram
        if self.lut is not None:
            out["LUT_units"] = self.lut
        return out

    def summary(self) -> str:
        pools = ", ".join(f"{k}={v:,}" for k, v in self.caps().items())
        return f"{self.name}: {pools or '(unconstrained)'}"


#: Xilinx Alveo U280 (the paper's deployment device, §4.2): 9,024 DSP
#: slices, 2.6 M flip-flops, 2,016 36Kb BRAM blocks, 1.3 M LUTs.
alveo_u280 = Part("alveo_u280", dsp=9024, ff=2_607_360, bram=2016,
                  lut=1_303_680)

#: Zynq UltraScale+ ZCU102 (XCZU9EG) — the embedded trigger-board class:
#: 2,520 DSPs, 548 K FFs, 912 36Kb BRAMs, 274 K LUTs.
zcu102 = Part("zcu102", dsp=2520, ff=548_160, bram=912, lut=274_080)

#: The catalog, by name.  ``part()`` makes synthetic entries; register
#: real devices here so budgets can name them.
PARTS: dict[str, Part] = {p.name: p for p in (alveo_u280, zcu102)}


def part(*, dsp: Optional[int] = None, ff: Optional[int] = None,
         bram: Optional[int] = None, lut: Optional[int] = None,
         name: str = "custom") -> Part:
    """A synthetic part with explicit pools (``None`` = unconstrained).

    The what-if device for tests and capacity studies::

        tiny = part(dsp=16)           # deliberately infeasible
        design.check_budget(part=tiny)
    """
    return Part(name, dsp=dsp, ff=ff, bram=bram, lut=lut)


def get_part(p: Union[str, Part, None]) -> Optional[Part]:
    """Resolve a part reference: a ``Part``, a catalog name, or ``None``."""
    if p is None or isinstance(p, Part):
        return p
    if p in PARTS:
        return PARTS[p]
    raise KeyError(f"unknown part {p!r}; catalog: {sorted(PARTS)} "
                   f"(or build one with trigger.part(dsp=..., ...))")
