"""Fixed-rate streaming trigger loop: detector feed -> ring -> decisions.

The deployment OpenHLS targets (and the collider-trigger study in
PAPERS.md frames explicitly): sensor frames arrive on the *experiment's*
clock, every frame must become an accept/reject decision within a fixed
latency budget, and the trigger must never back-pressure the detector —
when it falls behind, the stalest frames are dropped, not queued.

Three pieces:

  * :class:`DetectorFeed` — seeded synthetic Bragg-peak frame generator
    with a configurable event rate and periodic **pileup bursts**
    (several peaks per frame), so every backend and every PR sees the
    same stream bit-for-bit;
  * the bounded drop-oldest ring
    (:class:`repro.serving.common.DropOldestRing`) between producer and
    trigger — the explicit overrun policy;
  * :class:`TriggerLoop` — pulls fixed-size windows, runs them through a
    pre-warmed ``Design._runner`` (any emission backend), applies a
    threshold predicate, and emits :class:`TriggerDecision` records with
    per-window deadline accounting (met/missed, slack µs).

Two run modes: ``realtime=True`` paces arrivals on the wall clock with a
producer thread (drops and queueing latency are real); the default
deterministic mode processes every frame in order — decisions are then a
pure function of the seed, which is what the bit-identity tests and the
tuning gate rely on.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from repro import obs
from repro.serving.common import DropOldestRing, percentiles
from repro.trigger.budget import TriggerBudget

log = obs.get_logger(__name__)


# ---------------------------------------------------------------------------
# Synthetic detector feed
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Frame:
    """One detector frame: pixels plus its place in the stream."""

    frame_id: int
    data: np.ndarray              # the input memref, (1, 1, img, img)
    t_sched: float                # scheduled arrival offset from start (s)
    n_peaks: int                  # ground truth (feed bookkeeping only)
    arrival_t: float = 0.0        # wall-clock arrival (realtime mode)


@dataclasses.dataclass
class DetectorFeed:
    """Seeded Bragg-peak frame generator at a fixed frame rate.

    Each frame is Gaussian pixel noise; with probability ``event_rate``
    it carries one Gaussian peak (random sub-pixel centre, amplitude and
    width).  Every ``pileup_every`` frames, ``pileup_len`` consecutive
    frames are a **pileup burst** carrying ``pileup_peaks`` overlapping
    peaks each — the detector pathology a trigger must survive.  The
    stream is a pure function of ``seed``: same seed, same frames,
    bit-for-bit.
    """

    img: int = 11
    frame_rate_hz: float = 1000.0
    event_rate: float = 0.6
    pileup_every: int = 50
    pileup_len: int = 5
    pileup_peaks: int = 3
    noise: float = 0.05
    amplitude: tuple = (0.6, 1.4)
    sigma: tuple = (0.8, 1.6)
    seed: int = 0

    def _render(self, rng: np.random.Generator, n_peaks: int) -> np.ndarray:
        img = self.img
        frame = rng.normal(0.0, self.noise, (img, img)).astype(np.float32)
        yy, xx = np.mgrid[0:img, 0:img].astype(np.float32)
        for _ in range(n_peaks):
            cy, cx = rng.uniform(1.0, img - 2.0, 2)
            amp = rng.uniform(*self.amplitude)
            sig = rng.uniform(*self.sigma)
            frame += (amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2)
                                   / (2.0 * sig * sig))).astype(np.float32)
        return frame[None, None]       # the (1, 1, img, img) input memref

    def frames(self, n: int) -> Iterator[Frame]:
        """The first ``n`` frames of the seeded stream."""
        rng = np.random.default_rng(self.seed)
        dt = 1.0 / self.frame_rate_hz
        for i in range(n):
            if self.pileup_every and i % self.pileup_every < self.pileup_len:
                n_peaks = self.pileup_peaks
            else:
                n_peaks = int(rng.random() < self.event_rate)
            yield Frame(frame_id=i, data=self._render(rng, n_peaks),
                        t_sched=i * dt, n_peaks=n_peaks)

    def describe(self) -> dict:
        return {"img": self.img, "frame_rate_hz": self.frame_rate_hz,
                "event_rate": self.event_rate,
                "pileup_every": self.pileup_every,
                "pileup_len": self.pileup_len,
                "pileup_peaks": self.pileup_peaks, "seed": self.seed}


# ---------------------------------------------------------------------------
# Decisions + report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriggerDecision:
    """One frame's verdict plus its deadline accounting."""

    frame_id: int
    accept: bool
    score: float
    latency_us: float             # arrival (or window start) -> decision
    deadline_met: bool            # True when no deadline was configured
    slack_us: float               # budget - latency (negative = missed)


@dataclasses.dataclass
class TriggerReport:
    """Stream-level accounting of one :meth:`TriggerLoop.run`."""

    backend: str
    fmt: Optional[str]
    window: int
    realtime: bool
    frames: int = 0               # offered by the feed
    processed: int = 0            # reached a decision
    dropped: int = 0              # lost to ring overrun
    windows: int = 0
    accepts: int = 0
    rejects: int = 0
    deadline_misses: int = 0
    deadline_us: Optional[float] = None
    p50_us: float = 0.0
    p95_us: float = 0.0
    p99_us: float = 0.0
    max_us: float = 0.0
    wall_s: float = 0.0
    sustained_fps: float = 0.0
    warmup_s: float = 0.0
    decisions: list = dataclasses.field(default_factory=list)

    @property
    def drop_pct(self) -> float:
        return 100.0 * self.dropped / self.frames if self.frames else 0.0

    @property
    def miss_pct(self) -> float:
        return (100.0 * self.deadline_misses / self.processed
                if self.processed else 0.0)

    def summary(self) -> str:
        deadline = (f", deadline {self.deadline_us:g} us: "
                    f"{self.deadline_misses} missed ({self.miss_pct:.1f}%)"
                    if self.deadline_us is not None else "")
        return (f"triggered {self.processed}/{self.frames} frames "
                f"({self.accepts} accept / {self.rejects} reject, "
                f"{self.dropped} dropped = {self.drop_pct:.1f}%) @ "
                f"{self.sustained_fps:.0f} fps sustained, decision p50 "
                f"{self.p50_us:.0f} / p95 {self.p95_us:.0f} / p99 "
                f"{self.p99_us:.0f} us{deadline} "
                f"[{self.backend} backend, warm-up {self.warmup_s:.2f}s]")

    def to_json(self) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if k != "decisions"}
        d["drop_pct"] = round(self.drop_pct, 3)
        d["miss_pct"] = round(self.miss_pct, 3)
        return d


# ---------------------------------------------------------------------------
# The trigger loop
# ---------------------------------------------------------------------------


def threshold_predicate(threshold: float) -> Callable:
    """The stock predicate: accept when any output magnitude clears
    ``threshold``.  Batched: returns per-sample ``(accepts, scores)``."""
    def predicate(outputs) -> tuple[np.ndarray, np.ndarray]:
        vals = (outputs.values() if isinstance(outputs, dict)
                else (outputs,))
        score = None
        for v in vals:
            arr = np.abs(np.asarray(v, dtype=np.float32))
            s = arr.reshape(arr.shape[0], -1).max(axis=1)
            score = s if score is None else np.maximum(score, s)
        return score >= threshold, score
    return predicate


class TriggerLoop:
    """Streaming accept/reject over a pre-warmed compiled design.

    ``design`` is a ``repro.hls.Design``; the loop serves through the
    same ``Design._runner`` the sync/async serving paths use, so any
    emission backend (``tensor`` / ``simd`` / ``pallas``) triggers.
    ``window`` frames are stacked into one fixed-shape inference (the
    only shape warmed — no re-jits on the hot path); ``predicate``
    maps the window's outputs to per-frame ``(accepts, scores)``
    (default: :func:`threshold_predicate`).  ``budget.max_latency_us``
    is the per-frame decision deadline; metrics land in ``repro.obs``
    (``trigger.deadline_misses`` / ``trigger.dropped_frames`` counters,
    one ``trigger.window`` span per dispatched window).
    """

    def __init__(self, design, *, backend: Optional[str] = None,
                 fmt: Optional[str] = None,
                 budget: Optional[TriggerBudget] = None,
                 threshold: float = 0.75,
                 predicate: Optional[Callable] = None,
                 window: int = 1, capacity: int = 256,
                 pallas_kw: Optional[dict] = None, warm: bool = True):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if backend is None:
            module = design.module
            backend = ("tensor" if module is not None
                       and module.forward_fn is not None
                       and module.params is not None else "simd")
        self.design = design
        self.backend = backend
        self.fmt = fmt
        self.budget = budget
        self.window = window
        self.threshold = threshold
        self._user_predicate = predicate
        self.ring = DropOldestRing(capacity)
        self._input_name, self._input_shape = design._input_memref()
        self._run_one, self._served, _ = design._runner(
            backend, fmt, dict(pallas_kw or {}))
        self.warmup_s = 0.0
        if warm:
            self.warmup()

    # -- plumbing ------------------------------------------------------------

    @property
    def predicate(self) -> Callable:
        """The active predicate (user-supplied, or the stock threshold
        predicate at the *current* ``self.threshold`` — so
        :meth:`calibrate` takes effect without rebuilding the loop)."""
        return self._user_predicate or threshold_predicate(self.threshold)

    def calibrate(self, feed: DetectorFeed, n_frames: int = 64, *,
                  quantile: float = 0.5) -> float:
        """Set ``threshold`` to the ``quantile`` of the stock predicate's
        scores over the feed's first ``n_frames``.

        A deployment calibrates its threshold on beam data exactly like
        this; here it pins a deterministic accept fraction (~``1 -
        quantile``) whatever the bound params' output scale.  Returns
        the chosen threshold.  No-op guard: refuses when a custom
        predicate is installed.
        """
        if self._user_predicate is not None:
            raise ValueError("calibrate() tunes the stock threshold "
                             "predicate; a custom predicate is installed")
        import jax
        scores: list[float] = []
        score_of = threshold_predicate(float("inf"))
        batch: list[Frame] = []
        for frame in feed.frames(n_frames):
            batch.append(frame)
            if len(batch) == self.window:
                out = jax.block_until_ready(self._run_one(self._as_batch(
                    np.stack([f.data for f in batch]).astype(np.float32))))
                scores.extend(np.asarray(score_of(out)[1]).reshape(-1))
                batch = []
        if batch:
            n_real = len(batch)
            out = jax.block_until_ready(self._run_one(self._as_batch(
                np.stack([f.data for f in self._pad(batch)]
                         ).astype(np.float32))))
            scores.extend(np.asarray(score_of(out)[1]).reshape(-1)[:n_real])
        self.threshold = float(np.quantile(np.asarray(scores), quantile))
        return self.threshold

    def warmup(self) -> float:
        """Jit + warm the one window shape the hot loop will dispatch."""
        import jax
        t0 = time.perf_counter()
        zeros = np.zeros((self.window,) + tuple(self._input_shape),
                         np.float32)
        with obs.span("trigger.warmup", cat="trigger", backend=self.backend,
                      window=self.window):
            jax.block_until_ready(self._run_one(self._as_batch(zeros)))
        self.warmup_s = time.perf_counter() - t0
        return self.warmup_s

    def _as_batch(self, stacked: np.ndarray):
        if self.backend == "tensor":
            # fused forward batches over the memref's singleton axis
            return stacked.reshape(stacked.shape[0], *self._input_shape[1:])
        return stacked

    def _decide(self, frames: list[Frame], n_real: int, t_ref: list[float],
                report: TriggerReport) -> None:
        """One window: inference, predicate, deadline accounting."""
        import jax
        stacked = np.stack([f.data for f in frames]).astype(np.float32)
        idx = report.windows
        report.windows += 1
        with obs.span("trigger.window", cat="trigger", window=idx,
                      frames=n_real, backend=self.backend) as sp:
            out = jax.block_until_ready(self._run_one(self._as_batch(stacked)))
            accepts, scores = self.predicate(out)
            t_done = time.perf_counter()
            accepts = np.asarray(accepts).reshape(-1)[:n_real]
            scores = np.asarray(scores).reshape(-1)[:n_real]
            deadline = self.budget.max_latency_us \
                if self.budget is not None else None
            misses = 0
            for i in range(n_real):
                latency_us = (t_done - t_ref[i]) * 1e6
                met, slack = True, float("inf")
                if deadline is not None:
                    slack = deadline - latency_us
                    met = slack >= 0.0
                    misses += not met
                report.decisions.append(TriggerDecision(
                    frame_id=frames[i].frame_id, accept=bool(accepts[i]),
                    score=float(scores[i]), latency_us=latency_us,
                    deadline_met=met, slack_us=slack))
            n_acc = int(np.count_nonzero(accepts))
            report.processed += n_real
            report.accepts += n_acc
            report.rejects += n_real - n_acc
            report.deadline_misses += misses
            sp.set(accepts=n_acc, deadline_misses=misses)
        obs.inc("trigger.windows")
        obs.inc("trigger.accepts", n_acc)
        obs.inc("trigger.rejects", n_real - n_acc)
        if misses:
            obs.inc("trigger.deadline_misses", misses)

    def _pad(self, frames: list[Frame]) -> list[Frame]:
        """Zero-frames up to the warmed window shape (end of stream)."""
        pad = self.window - len(frames)
        zero = np.zeros(tuple(self._input_shape), np.float32)
        return frames + [Frame(frame_id=-1, data=zero, t_sched=0.0,
                               n_peaks=0)] * pad

    # -- run modes -----------------------------------------------------------

    def run(self, feed: DetectorFeed, n_frames: int, *,
            realtime: bool = False) -> TriggerReport:
        """Stream ``n_frames`` from ``feed`` through the trigger.

        Deterministic mode (default): every frame is processed in order —
        zero drops, decisions a pure function of the feed's seed, decision
        latency = the window's compute wall time.  ``realtime=True``
        paces arrivals at ``feed.frame_rate_hz`` on a producer thread
        through the drop-oldest ring; decision latency then includes real
        queueing, and a trigger slower than the feed *loses frames*
        (reported, never blocking the producer).
        """
        report = TriggerReport(backend=self.backend, fmt=self.fmt,
                               window=self.window, realtime=realtime,
                               frames=n_frames, warmup_s=self.warmup_s,
                               deadline_us=self.budget.max_latency_us
                               if self.budget is not None else None)
        if realtime:
            self._run_realtime(feed, n_frames, report)
        else:
            self._run_deterministic(feed, n_frames, report)
        lat = [d.latency_us for d in report.decisions]
        pct = percentiles(lat)
        report.p50_us = pct["p50"]
        report.p95_us = pct["p95"]
        report.p99_us = pct["p99"]
        report.max_us = max(lat, default=0.0)
        if report.wall_s > 0:
            report.sustained_fps = report.processed / report.wall_s
        return report

    def _run_deterministic(self, feed: DetectorFeed, n_frames: int,
                           report: TriggerReport) -> None:
        t_start = time.perf_counter()
        batch: list[Frame] = []
        for frame in feed.frames(n_frames):
            batch.append(frame)
            if len(batch) == self.window:
                t0 = time.perf_counter()
                self._decide(batch, len(batch), [t0] * len(batch), report)
                batch = []
        if batch:
            n_real = len(batch)
            t0 = time.perf_counter()
            self._decide(self._pad(batch), n_real, [t0] * n_real, report)
        report.wall_s = time.perf_counter() - t_start

    def _run_realtime(self, feed: DetectorFeed, n_frames: int,
                      report: TriggerReport) -> None:
        done = threading.Event()

        def produce():
            t0 = time.perf_counter()
            try:
                for frame in feed.frames(n_frames):
                    delay = frame.t_sched - (time.perf_counter() - t0)
                    if delay > 0:
                        time.sleep(delay)
                    frame.arrival_t = time.perf_counter()
                    self.ring.push(frame)
            finally:
                done.set()

        producer = threading.Thread(target=produce, name="detector-feed",
                                    daemon=True)
        t_start = time.perf_counter()
        producer.start()
        while True:
            frames = self.ring.pop_many(self.window)
            if not frames:
                if done.is_set() and not len(self.ring):
                    break
                time.sleep(1e-4)
                continue
            if len(frames) < self.window and not done.is_set():
                # partial window mid-stream: wait (bounded by the time the
                # feed needs to deliver the rest, plus slack) rather than
                # dispatching a padded window per straggler
                deadline = time.perf_counter() + \
                    (self.window - len(frames) + 1.0) / feed.frame_rate_hz
                while len(frames) < self.window and \
                        time.perf_counter() < deadline:
                    more = self.ring.pop_many(self.window - len(frames))
                    if more:
                        frames.extend(more)
                    else:
                        time.sleep(1e-4)
            n_real = len(frames)
            t_ref = [f.arrival_t for f in frames]
            if n_real < self.window:
                frames = self._pad(frames)
            self._decide(frames, n_real, t_ref, report)
        producer.join()
        report.wall_s = time.perf_counter() - t_start
        report.dropped = self.ring.dropped
