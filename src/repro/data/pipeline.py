"""Sharded, seekable, deterministic data pipeline.

Design constraints from the fault-tolerance story (runtime/fault.py):

  * **Seekable**: ``batch_at(step)`` is a pure function of (seed, step,
    shard) — restart from a checkpoint at step k reproduces the exact
    stream, bit for bit, with no state to persist beyond the step counter.
  * **Sharded**: each host materialises only its ``(host_id, num_hosts)``
    slice of the global batch (here exercised with one host; the slicing
    logic is the multi-host contract).
  * **Prefetched with a deadline**: a background thread keeps a bounded
    queue ahead of the consumer; if a fetch misses its deadline (straggler
    I/O), the pipeline substitutes the deterministic backup batch and
    records the event — decode of the batch never blocks the step loop.

Token content is a synthetic Zipf-ish mixture (hash-PRNG), which keeps the
container hermetic while exercising the real pipeline machinery.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    prefetch: int = 2
    deadline_s: float = 5.0


class SyntheticTokenPipeline:
    """Deterministic host-sharded token stream with prefetch."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        self._queue: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._next_step = 0
        self.straggler_substitutions = 0
        self.fetch_delay_s = 0.0          # test hook: injected latency

    # -- pure, seekable core -------------------------------------------------

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The global-step batch, host-sharded.  Pure in (seed, step)."""
        cfg = self.cfg
        lo = self.cfg.host_id * self.local_batch
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, lo]))
        # Zipf-ish unigram mixture; documents delimited by token 0
        z = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
        tokens = (z % (cfg.vocab_size - 1)) + 1
        doc_ends = rng.random((self.local_batch, cfg.seq_len + 1)) < 1e-3
        tokens = np.where(doc_ends, 0, tokens).astype(np.int32)
        return {"tokens": tokens[:, :-1],
                "targets": tokens[:, 1:].copy()}

    # -- prefetching ----------------------------------------------------------

    def _producer(self) -> None:
        step = self._next_step
        while not self._stop.is_set():
            if self.fetch_delay_s:
                time.sleep(self.fetch_delay_s)
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, step: int = 0) -> None:
        self.seek(step)

    def seek(self, step: int) -> None:
        """Restart the stream at ``step`` (checkpoint-restore path)."""
        self.stop()
        self._stop = threading.Event()
        self._queue = queue.Queue(maxsize=self.cfg.prefetch)
        self._next_step = step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None

    def get(self, step: int) -> dict[str, np.ndarray]:
        """Batch for ``step`` — from the prefetch queue when in sequence,
        recomputed on the spot otherwise.  Applies the straggler deadline."""
        if self._thread is None:
            return self.batch_at(step)
        deadline = time.monotonic() + self.cfg.deadline_s
        while True:
            try:
                got_step, batch = self._queue.get(
                    timeout=max(0.0, deadline - time.monotonic()))
            except queue.Empty:
                # straggler: deterministic backup (compute inline) and move on
                self.straggler_substitutions += 1
                return self.batch_at(step)
            if got_step == step:
                return batch
            if got_step > step:            # consumer rewound: recompute
                return self.batch_at(step)
            # got_step < step: drain stale entries
            if time.monotonic() > deadline:
                self.straggler_substitutions += 1
                return self.batch_at(step)
