"""Entry point for ``python -m repro.tune``."""

from repro.tune.cli import main

if __name__ == "__main__":
    main()
