"""Candidate evaluation: compile (cached), validate numerics, cost latency.

Every candidate accepted by the tuner passes through three gates here:

  1. **compile** — through ``CompilerDriver`` and its design cache, so a
     re-proposed candidate (or a rerun of the whole search) is free; the
     driver's pass-stage memo additionally lets candidates that differ only
     in schedule knobs share one pass-pipeline run.
  2. **numerics** — the candidate's optimised graph is functionally
     simulated (at the candidate's FloPoCo format, if any) and compared
     against the *interpreter reference*: the raw traced DFG evaluated in
     fp32, i.e. the symbolic-interpretation semantics of ``core.interp``.
     Candidates outside tolerance are marked invalid and can never win.
  3. **latency** — the objective.  The primary metric is the scheduled
     design's per-sample latency (initiation interval x 10 ns for
     stage-pipelined designs, else makespan x 10 ns — the paper's interval
     counts).  In ``measure`` mode the emitted SIMD design is additionally
     wall-clocked; in ``--dry`` mode a roofline-style cost model
     (``launch.roofline`` machine constants) estimates the CPU path
     instead.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np

from repro.core import emit, verify
from repro.core.interp import Context
from repro.core.ir import Graph
from repro.core.ir import OPCODES as ir_OPCODES
from repro.core.pipeline import CompiledDesign, CompilerDriver
from repro.tune.space import Candidate, SearchSpace

#: FLOPs per opcode (fmac counts two) for the roofline estimate, as a dense
#: per-opcode-id lookup aligned with ``ir.OPCODES``.  (The historical table
#: keyed on resource-class-style names — "add", "mul" — which never matched
#: the actual "addf"/"mulf" opcodes, so plain adds and muls were costed 0.)
_FLOPS_BY_NAME = {"addf": 1, "subf": 1, "mulf": 1, "divf": 1, "sqrtf": 1,
                  "fmac": 2, "maxf": 1, "minf": 1, "cmpugt": 1, "negf": 1,
                  "relu": 1, "select": 1}
_FLOPS_TABLE = np.array([_FLOPS_BY_NAME.get(name, 0) for name in ir_OPCODES],
                        dtype=np.int64)


@dataclasses.dataclass
class Trial:
    """The full record of one evaluated candidate."""

    candidate: Candidate
    design_hash: str
    latency_us: float             # objective: scheduled per-sample latency
    makespan: int
    stage_ii: Optional[int]
    err: float                    # vs the interpreter reference
    valid: bool                   # within tolerance -> eligible to win
    resources: dict[str, int]
    wire_bits: int                # per-value wire width at this precision
    #: Roofline-model estimate of the emitted tensor path on the repo's
    #: reference accelerator (v5e constants from ``launch.roofline``) —
    #: NOT a CPU prediction; compare roofline-to-roofline only.
    est_roofline_us: float
    measured_cpu_us: Optional[float]  # wall-clocked (measure mode only)
    compile_s: float
    cached: bool                  # design served from the design cache
    #: trigger-budget gate verdict (True when no budget was configured);
    #: an infeasible candidate scores ``None`` and can never win
    feasible: bool = True
    #: the named constraints the candidate blew (``DSP``, ``latency_us``...)
    budget_failures: list = dataclasses.field(default_factory=list)

    def score(self) -> Optional[tuple]:
        """Ordering key: lower is better; ``None`` = ineligible.

        Latency first, then DSP units, then wire bits (the SLL-crossing
        pressure that forced the paper's (5,4) -> (5,3) step).  Both
        gates bite here: numerics-invalid and budget-infeasible trials
        are ineligible.
        """
        if not self.valid or not self.feasible:
            return None
        return (self.latency_us, self.resources.get("DSP", 0),
                self.wire_bits)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidate"] = self.candidate.to_json()
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Trial":
        # tolerate schema drift (the DB's version gate discards truly
        # incompatible files; this guards same-version additive changes)
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        d["candidate"] = Candidate.from_json(d["candidate"])
        return cls(**d)

    def summary(self) -> str:
        tag = ("ok" if self.valid and self.feasible
               else "INVALID" if not self.valid
               else f"OVER BUDGET ({', '.join(self.budget_failures)})")
        cpu = (f", cpu={self.measured_cpu_us:.1f}us"
               if self.measured_cpu_us is not None else "")
        return (f"[{tag}] {self.latency_us:8.2f} us  "
                f"(makespan={self.makespan}, ii={self.stage_ii}, "
                f"err={self.err:.2e}, dsp={self.resources.get('DSP', 0)}"
                f"{cpu})  {self.candidate.label()}")


def roofline_estimate_us(design: CompiledDesign) -> float:
    """Roofline cost model of the emitted tensor path (``--dry`` fallback).

    max(compute term, memory term) over the optimised DFG, using the
    ``launch.roofline`` machine constants (the repo's v5e reference
    accelerator — so this estimates the deployed-accelerator path, not the
    local CPU): each arithmetic op is one FLOP (fmac: two) and every SSA
    value crosses memory once at 4 bytes.
    """
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    g = design.graph_opt
    flops = int(_FLOPS_TABLE[g.cols().opcode].sum())
    bytes_moved = 4.0 * g.n_values
    return max(flops / PEAK_FLOPS, bytes_moved / HBM_BW) * 1e6


class Evaluator:
    """Compile + validate + cost one candidate at a time.

    ``program`` is either a build callable (traced once, here) or an
    already-traced ``Graph`` — the trace is *shared* across all candidates,
    so per-candidate cost is passes + schedule only (and just schedule when
    the pass-stage memo hits).

    tolerances:
        ``tol_abs`` gates fp32 candidates (reassociation-level error);
        ``tol_rel`` gates quantised candidates on max relative error
        against the fp32 interpreter reference.

    ``budget`` (a :class:`repro.trigger.TriggerBudget`) adds the trigger
    feasibility gate: every candidate's compiled schedule is checked
    against the envelope and an over-budget trial is marked infeasible —
    ineligible to win, exactly like a numerics-invalid one.
    """

    def __init__(self, program: Union[Graph, "BuildFn"], space: SearchSpace,
                 *, driver: Optional[CompilerDriver] = None,
                 name: str = "design", batch: int = 2, seed: int = 0,
                 scale: float = 0.4, tol_abs: float = 1e-3,
                 tol_rel: float = 5e-2, measure: bool = False,
                 measure_reps: int = 5, budget=None):
        self.driver = driver or CompilerDriver()
        self.space = space
        self.name = name
        self.tol_abs = tol_abs
        self.tol_rel = tol_rel
        self.measure = measure
        self.measure_reps = measure_reps
        self.budget = budget
        self.batch = batch
        self.seed = seed
        self.scale = scale
        if isinstance(program, Graph):
            self.graph = program
        else:
            ctx = Context(forward=space.base.forward)
            program(ctx)
            self.graph = ctx.finalize()
        self.feeds = verify.random_feeds(self.graph, batch=batch, seed=seed,
                                         scale=scale)
        # the interpreter reference: raw traced DFG, fp32 — computed once
        self.ref = emit.evaluate(self.graph, self.feeds)
        self._ref_denom = max(
            (float(np.abs(v).max()) for v in self.ref.values()),
            default=0.0) + 1e-9
        # numerics depend only on (optimised graph, format): memoise
        self._err_memo: dict[tuple[str, str], float] = {}
        self._cpu_memo: dict[str, float] = {}
        self.n_evals = 0

    def settings(self) -> dict:
        """Everything that shapes a trial besides the candidate itself.

        Stored with each ``TuningDB`` entry: a rerun is only served from
        the DB when its evaluation settings match — a different feed
        scale, tolerance, or measure mode is a different experiment.
        """
        return {"batch": self.batch, "seed": self.seed, "scale": self.scale,
                "tol_abs": self.tol_abs, "tol_rel": self.tol_rel,
                "mode": "measure" if self.measure else "dry",
                "budget": self.budget.key() if self.budget is not None
                else None}

    # -- gates --------------------------------------------------------------

    def _numeric_err(self, design: CompiledDesign, fmt) -> float:
        key = (design.config.pass_key(), str(fmt) if fmt else "fp32")
        err = self._err_memo.get(key)
        if err is None:
            out = emit.evaluate(design.graph_opt, self.feeds, fmt=fmt)
            err = max(float(np.abs(out[k] - self.ref[k]).max())
                      for k in self.ref)
            self._err_memo[key] = err
        return err

    def _measure_cpu_us(self, design: CompiledDesign) -> float:
        """Wall-clock the emitted SIMD design (us per sample).

        Memoised on the pass key — the emitted function depends only on the
        optimised graph, never on the schedule knobs.
        """
        key = design.config.pass_key()
        cached = self._cpu_memo.get(key)
        if cached is not None:
            return cached
        import jax
        fn = jax.jit(design.jax_fn())
        batch = len(next(iter(self.feeds.values())))
        jax.block_until_ready(fn(self.feeds))        # compile + warm up
        t0 = time.perf_counter()
        for _ in range(self.measure_reps):
            out = fn(self.feeds)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / (self.measure_reps * batch) * 1e6
        self._cpu_memo[key] = us
        return us

    # -- the evaluation -----------------------------------------------------

    def evaluate(self, candidate: Candidate) -> Trial:
        cfg = self.space.to_config(candidate)
        fmt = self.space.to_format(candidate)

        misses = self.driver.cache.misses
        t0 = time.perf_counter()
        design = self.driver.compile(self.graph, name=self.name, config=cfg)
        compile_s = time.perf_counter() - t0
        cached = self.driver.cache.misses == misses

        err = self._numeric_err(design, fmt)
        tol = self.tol_abs if fmt is None else self.tol_rel * self._ref_denom
        valid = err <= tol

        feasible, failures = True, []
        if self.budget is not None:
            from repro.trigger.budget import check_design
            rep = check_design(design, self.budget)
            feasible, failures = rep.passed, rep.failures

        measured = self._measure_cpu_us(design) if self.measure else None
        self.n_evals += 1
        return Trial(
            candidate=candidate, design_hash=design.design_hash,
            latency_us=design.sample_latency_us, makespan=design.makespan,
            stage_ii=design.stage_ii, err=err, valid=valid,
            resources=design.schedule.resources(),
            wire_bits=fmt.wire_bits if fmt is not None else 32,
            est_roofline_us=roofline_estimate_us(design),
            measured_cpu_us=measured, compile_s=compile_s, cached=cached,
            feasible=feasible, budget_failures=failures)

    def compile_candidate(self, candidate: Candidate) -> CompiledDesign:
        """The design for a (stored) candidate — how serving loads a win."""
        return self.driver.compile(self.graph, name=self.name,
                                   config=self.space.to_config(candidate))
