"""``python -m repro.tune`` — design-space exploration from the shell.

    PYTHONPATH=src python -m repro.tune --config braggnn --budget 8
    PYTHONPATH=src python -m repro.tune --config braggnn --dry --budget 3
    PYTHONPATH=src python -m repro.tune --config braggnn --show

``--dry`` skips wall-clocking the emitted SIMD design and relies on the
scheduled-latency objective plus the roofline CPU estimate — the CI-safe
mode.  Results persist to the ``TuningDB`` (``--db`` overrides the shared
versioned cache root); a rerun whose budget is already covered is served
from the DB without searching (``--force`` re-searches).
"""

from __future__ import annotations

import argparse
import sys
from typing import Callable, Optional

from repro.tune.db import TuningDB
from repro.tune.space import SearchSpace, braggnn_space, conv2d_space
from repro.tune.strategies import STRATEGIES
from repro.tune.tuner import TuneResult


def _braggnn_build(s: int, img: int) -> Callable:
    from repro.core import frontend
    return lambda ctx: frontend.braggnn(ctx, s=s, img=img)


def _conv2d_build() -> Callable:
    from repro.core import frontend

    def build(ctx):
        x = ctx.memref("input", (1, 3, 8, 8), "input")
        w = ctx.memref("weight", (4, 3, 3, 3), "weight")
        b = ctx.memref("bias", (4,), "weight")
        out = ctx.memref("out", (1, 4, 6, 6), "output")
        frontend.conv2d(ctx, x, w, b, out)
    return build


def _configs() -> dict[str, tuple[Callable, SearchSpace, dict]]:
    """name -> (build fn, search space, evaluator defaults).

    BraggNN verifies at feed scale 0.2: the paper's trained weights are
    small, and at 0.4 the softmax's Taylor exp is chaotic enough that even
    (5,11) quantisation diverges from fp32 — every candidate would fail
    the numerics gate for a reason that is the test vectors' fault, not
    the design's.
    """
    from repro.configs import braggnn as bragg_cfg
    full, tiny = bragg_cfg.CONFIG, bragg_cfg.tiny()
    return {
        "braggnn": (_braggnn_build(full.scale, full.img), braggnn_space(),
                    {"scale": 0.2}),
        "braggnn-tiny": (_braggnn_build(tiny.scale, tiny.img),
                         braggnn_space(), {"scale": 0.2}),
        "conv2d": (_conv2d_build(), conv2d_space(), {}),
    }


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="OpenHLS design-space exploration")
    ap.add_argument("--config", default="braggnn",
                    choices=["braggnn", "braggnn-tiny", "conv2d"],
                    help="which design to tune")
    ap.add_argument("--strategy", default="hillclimb",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--budget", type=int, default=8,
                    help="max candidates to evaluate (incl. the baseline)")
    ap.add_argument("--dry", action="store_true",
                    help="skip wall-clocking the emitted design; use the "
                         "schedule latency + roofline cost model")
    ap.add_argument("--target-us", type=float, default=None,
                    help="latency target for --strategy bisect "
                         "(default: the baseline's own latency)")
    ap.add_argument("--db", default=None,
                    help="TuningDB path (default: shared versioned "
                         "cache root)")
    ap.add_argument("--force", action="store_true",
                    help="re-search even when the DB already covers "
                         "this budget")
    ap.add_argument("--show", action="store_true",
                    help="print the stored result for this design/space "
                         "and exit (no search)")
    ap.add_argument("--batch", type=int, default=2,
                    help="verification batch for the numerics gate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tol-rel", type=float, default=5e-2,
                    help="relative tolerance for quantised candidates")
    return ap


def main(argv: Optional[list[str]] = None) -> TuneResult:
    args = build_parser().parse_args(argv)
    build, space, eval_defaults = _configs()[args.config]
    db = TuningDB(args.db)

    if args.show:
        # inspect-only: a bare trace yields the fingerprint — skip the
        # evaluator's reference evaluation entirely
        import repro.hls as hls
        from repro.core.pipeline import graph_fingerprint
        from repro.tune.db import best_entry
        fp = graph_fingerprint(hls.trace(build, forward=space.base.forward))
        all_entries = db.entries_for(fp, space.space_hash())
        for ctx_hash, entry in sorted(all_entries.items()):
            c = entry.get("context", {})
            print(f"  [{ctx_hash}] strategy={c.get('strategy', '?')} "
                  f"mode={(c.get('eval') or {}).get('mode', '?')} "
                  f"budget={entry.get('budget')} "
                  f"best={(entry.get('best') or {}).get('latency_us')}us "
                  f"valid={(entry.get('best') or {}).get('valid')}")
        winner = best_entry(db, fp, space.space_hash())
        if winner is None:
            print(f"no servable tuning entry in {db.path}")
            sys.exit(1)
        result = TuneResult.from_entry(winner, design_fingerprint=fp,
                                       space_hash=space.space_hash())
        print(result.summary())
        return result

    print(f"tuning {args.config!r} with strategy={args.strategy} "
          f"budget={args.budget} mode={'dry' if args.dry else 'measure'}")
    print(space.describe())

    # trace + baseline compile through the public API; the tuner's own
    # baseline trial is then a design-cache hit inside the same session
    import repro.hls as hls
    print("tracing + compiling the baseline design ...", flush=True)
    design = hls.compile(build, name=args.config, config=space.base)

    n = [0]

    def on_trial(trial):
        n[0] += 1
        print(f"  trial {n[0]:3d}  {trial.summary()}", flush=True)

    result = design.tune(space, strategy=args.strategy, budget=args.budget,
                         db=db, dry=args.dry, force=args.force,
                         target_us=args.target_us, on_trial=on_trial,
                         batch=args.batch, seed=args.seed,
                         tol_rel=args.tol_rel, **eval_defaults)

    if result.from_db:
        print(f"served from tuning DB ({db.path}) — no search run; "
              f"use --force to re-search")
    print(result.summary())
    best = result.best
    if best.measured_cpu_us is not None:
        print(f"measured emitted-design CPU latency: "
              f"{best.measured_cpu_us:.1f} us/sample "
              f"(baseline {result.baseline.measured_cpu_us:.1f})")
    else:
        print(f"roofline estimate (v5e reference accelerator): "
              f"{best.est_roofline_us:.3f} us/sample (dry mode)")
    print(f"tuning DB: {db.path}")
    return result
