"""Declarative design spaces for the OpenHLS flow.

A ``SearchSpace`` names a set of *knobs*, each with a finite ordered domain.
Three families of knobs exist, mirroring the levers the paper actually
searched over (§4.2: bisection over unroll factors, precision stepping
(5,11) -> (5,4) -> (5,3)) and the ones hls4ml exposes as reuse-factor /
strategy:

  * **pass-pipeline knobs** — which registered passes run, in what order
    (``pipeline``), plus pass options (``tree_threshold``, ``max_rounds``);
  * **schedule knobs** — any field of ``core.schedule.ScheduleParams``
    (``unroll_factor``, ``binding``, ``pipelined_units``, ``alap_compact``,
    ``ports_per_array``, ``n_stages``);
  * **precision** — the FloPoCo (wE, wF) functional-model format the design
    is validated and deployed at (``"fp32"`` = no quantisation).

A ``Candidate`` is one assignment over the knobs.  It is hashable (the
tuner dedupes on it), JSON round-trippable (the ``TuningDB`` persists it),
and lowers to a ``CompilerConfig`` + optional ``FloatFormat`` via the
space.  The first value of every knob domain is, by convention, the
*baseline* — ``SearchSpace.default()`` is the config every search is
measured against.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Iterator, Optional

from repro.core.cachedir import CACHE_FORMAT_VERSION
from repro.core.pipeline import (DEFAULT_PIPELINE, PASS_REGISTRY,
                                 CompilerConfig)
from repro.core.precision import FORMATS, FloatFormat

#: Knob names that map 1:1 onto ``CompilerConfig`` fields.
CONFIG_KNOBS = ("pipeline", "tree_threshold", "max_rounds", "binding",
                "unroll_factor", "ports_per_array", "pipelined_units",
                "alap_compact", "n_stages")
#: The knob interpreted as a FloPoCo format key (``precision.FORMATS``).
PRECISION_KNOB = "precision"


@dataclasses.dataclass(frozen=True)
class Knob:
    """One searchable parameter: a name and its finite, ordered domain.

    ``values[0]`` is the baseline.  Order is meaningful to strategies:
    ``Bisection`` bisects the domain as given, and precision domains are
    conventionally widest-first (the paper's (5,11) -> (5,3) descent).
    """

    name: str
    values: tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"knob {self.name!r} has an empty domain")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"knob {self.name!r} has duplicate values")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of a search space: a (knob -> value) assignment.

    Stored as sorted items so equal assignments hash equally regardless of
    construction order.
    """

    items: tuple[tuple[str, Any], ...]

    @classmethod
    def of(cls, assignment: dict[str, Any]) -> "Candidate":
        return cls(tuple(sorted(assignment.items())))

    def get(self, name: str, default: Any = None) -> Any:
        for k, v in self.items:
            if k == name:
                return v
        return default

    def replace(self, name: str, value: Any) -> "Candidate":
        d = dict(self.items)
        d[name] = value
        return Candidate.of(d)

    def to_json(self) -> dict[str, Any]:
        return {k: list(v) if isinstance(v, tuple) else v
                for k, v in self.items}

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "Candidate":
        return cls.of({k: tuple(v) if isinstance(v, list) else v
                       for k, v in d.items()})

    def label(self) -> str:
        """Compact human-readable tag, e.g. ``unroll=64,precision=5_4``."""
        parts = []
        for k, v in self.items:
            if k == "pipeline":
                v = "+".join(v) if v else "none"
            parts.append(f"{k}={v}")
        return ",".join(parts)

    def __str__(self) -> str:
        return self.label()


class SearchSpace:
    """A named set of knobs over a base ``CompilerConfig``."""

    def __init__(self, knobs: tuple[Knob, ...] = (), *,
                 base: Optional[CompilerConfig] = None, name: str = "space"):
        names = [k.name for k in knobs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate knob names in {names}")
        for k in knobs:
            if k.name not in CONFIG_KNOBS and k.name != PRECISION_KNOB:
                raise ValueError(
                    f"unknown knob {k.name!r}; config knobs: {CONFIG_KNOBS}, "
                    f"or {PRECISION_KNOB!r}")
            if k.name == "pipeline":
                for pipe in k.values:
                    unknown = [p for p in pipe if p not in PASS_REGISTRY]
                    if unknown:
                        raise ValueError(f"pipeline variant {pipe} names "
                                         f"unregistered pass {unknown[0]!r}")
            if k.name == PRECISION_KNOB:
                bad = [v for v in k.values
                       if v != "fp32" and v not in FORMATS]
                if bad:
                    raise ValueError(f"unknown precision key {bad[0]!r}; "
                                     f"known: fp32, {sorted(FORMATS)}")
        self.knobs = tuple(knobs)
        self.base = base or CompilerConfig()
        self.name = name

    # -- candidates ---------------------------------------------------------

    def default(self) -> Candidate:
        """The baseline: every knob at the first value of its domain."""
        return Candidate.of({k.name: k.values[0] for k in self.knobs})

    def knob(self, name: str) -> Optional[Knob]:
        for k in self.knobs:
            if k.name == name:
                return k
        return None

    def contains(self, c: Candidate) -> bool:
        if {k for k, _ in c.items} != {k.name for k in self.knobs}:
            return False
        return all(c.get(k.name) in k.values for k in self.knobs)

    def size(self) -> int:
        n = 1
        for k in self.knobs:
            n *= len(k.values)
        return n

    def enumerate(self) -> Iterator[Candidate]:
        """All candidates, baseline-first lexicographic in knob order."""
        def rec(i: int, acc: dict):
            if i == len(self.knobs):
                yield Candidate.of(acc)
                return
            k = self.knobs[i]
            for v in k.values:
                acc[k.name] = v
                yield from rec(i + 1, acc)
            del acc[k.name]
        yield from rec(0, {})

    def random_candidate(self, rng) -> Candidate:
        """One uniform sample (``rng``: ``numpy.random.Generator``)."""
        return Candidate.of({
            k.name: k.values[int(rng.integers(len(k.values)))]
            for k in self.knobs})

    # -- lowering -----------------------------------------------------------

    def to_config(self, c: Candidate) -> CompilerConfig:
        """Lower a candidate onto the base ``CompilerConfig``."""
        over = {k: v for k, v in c.items if k in CONFIG_KNOBS}
        return dataclasses.replace(self.base, **over)

    def to_format(self, c: Candidate) -> Optional[FloatFormat]:
        key = c.get(PRECISION_KNOB, "fp32")
        return None if key in (None, "fp32") else FORMATS[key]

    # -- identity -----------------------------------------------------------

    def space_hash(self) -> str:
        """Content hash of the space definition: knob domains + base config.

        Keys the ``TuningDB`` together with the design's graph fingerprint,
        so a changed domain (or cache-format bump) never serves stale
        tuning results.
        """
        h = hashlib.sha256()
        h.update(f"v{CACHE_FORMAT_VERSION}|{self.name}|".encode())
        for k in self.knobs:
            h.update(f"{k.name}:{k.values!r};".encode())
        h.update(self.base.key().encode())
        return h.hexdigest()

    def describe(self) -> str:
        lines = [f"space {self.name!r} ({self.size()} candidates):"]
        for k in self.knobs:
            lines.append(f"  {k.name:16s} {list(k.values)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Stock spaces
# ---------------------------------------------------------------------------


def braggnn_space(*, base: Optional[CompilerConfig] = None) -> SearchSpace:
    """The BraggNN design space (paper §4.2's knobs, plus hls4ml's).

    Baselines reproduce the paper's deployment: the default §3.2 pass
    pipeline, full-capacity pool binding, a 3-stage pipeline, and the
    (5,11) half-precision starting point of the precision descent.
    """
    no_tree = tuple(p for p in DEFAULT_PIPELINE if p != "reduction_tree")
    return SearchSpace((
        Knob("pipeline", (DEFAULT_PIPELINE, no_tree, ("cse", "dce"))),
        Knob("tree_threshold", (4, 2, 8)),
        Knob("unroll_factor", (None, 2048, 512, 128, 32)),
        Knob("pipelined_units", (False, True)),
        Knob("alap_compact", (True, False)),
        Knob("n_stages", (3, 1, 4)),
        Knob(PRECISION_KNOB, ("5_11", "5_4", "5_3")),
    ), base=base or CompilerConfig(n_stages=3), name="braggnn")


def conv2d_space(*, base: Optional[CompilerConfig] = None) -> SearchSpace:
    """A small space for single-layer designs (and fast smoke tests)."""
    return SearchSpace((
        Knob("pipeline", (DEFAULT_PIPELINE, ("cse", "dce"))),
        Knob("unroll_factor", (None, 16, 4)),
        Knob("pipelined_units", (False, True)),
        Knob(PRECISION_KNOB, ("fp32", "5_4")),
    ), base=base, name="conv2d")


def trigger_space(*, base: Optional[CompilerConfig] = None) -> SearchSpace:
    """The deployment-envelope space for trigger tuning.

    An unroll/stage ladder that trades DSP pressure against latency:
    full-capacity unrolling is the fastest schedule but the heaviest
    footprint, so it is exactly the knob a part-level resource cap
    (``Design.tune(..., budget=TriggerBudget(part=...))``) bites on —
    under a tight DSP cap the winner slides down the ladder to the
    fastest *feasible* rung.
    """
    return SearchSpace((
        Knob("pipeline", (DEFAULT_PIPELINE, ("cse", "dce"))),
        Knob("unroll_factor", (None, 1024, 256, 64, 16, 4)),
        Knob("pipelined_units", (True, False)),
        Knob("n_stages", (3, 1)),
    ), base=base, name="trigger")
