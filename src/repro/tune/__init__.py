"""``repro.tune`` — design-space exploration over the OpenHLS flow.

The paper reaches its 4.8 us/sample BraggNN latency by *searching*:
bisection over unroll factors and a precision descent until the target is
met (§4.2); hls4ml ships the same idea as reuse-factor/strategy knobs.
This subsystem makes that search a first-class, persistent artifact on top
of the ``CompilerDriver``:

  * :mod:`repro.tune.space`      — declarative ``SearchSpace`` (pass
    pipelines, ``ScheduleParams`` knobs, FloPoCo precision ladder);
  * :mod:`repro.tune.evaluator`  — cached compile + interpreter-reference
    numerics gate + latency objective (wall-clocked, or roofline cost
    model in dry mode);
  * :mod:`repro.tune.strategies` — ``Bisection`` (paper-style),
    ``HillClimb`` (absorbs ``launch.hillclimb``'s manual rounds),
    ``RandomSearch``;
  * :mod:`repro.tune.db`         — ``TuningDB``: best configs persisted
    under the shared versioned cache root, keyed by
    (design content hash, space hash);
  * :mod:`repro.tune.tuner`      — the budgeted ask/tell loop;
  * ``python -m repro.tune``     — the CLI (:mod:`repro.tune.cli`).

Serving picks up wins via :func:`best_config_for` — see
``examples/braggnn_serve.py --tuned``.
"""

from typing import Optional

from repro.tune.db import TuningDB, lookup_best
from repro.tune.evaluator import Evaluator, Trial, roofline_estimate_us
from repro.tune.space import (Candidate, Knob, SearchSpace, braggnn_space,
                              conv2d_space, trigger_space)
from repro.tune.strategies import (STRATEGIES, Bisection, HillClimb,
                                   RandomSearch, Strategy, make_strategy,
                                   sweep_variants)
from repro.tune.tuner import TuneResult, Tuner

__all__ = [
    "TuningDB", "lookup_best", "Evaluator", "Trial", "roofline_estimate_us",
    "Candidate", "Knob", "SearchSpace", "braggnn_space", "conv2d_space",
    "trigger_space",
    "STRATEGIES", "Bisection", "HillClimb", "RandomSearch", "Strategy",
    "make_strategy", "sweep_variants", "TuneResult", "Tuner",
    "best_config_for",
]


def best_config_for(graph, space: SearchSpace, *,
                    db: Optional[TuningDB] = None):
    """The best-known ``(CompilerConfig, Candidate)`` for a traced design.

    Looks the (graph fingerprint, space hash) pair up in the ``TuningDB``;
    returns ``None`` when nothing has been tuned yet.  This is the hook
    serving and benchmarks use to auto-load tuned configurations.
    """
    from repro.core.pipeline import graph_fingerprint
    assignment = lookup_best(db or TuningDB(), graph_fingerprint(graph),
                             space.space_hash())
    if assignment is None:
        return None
    candidate = Candidate.from_json(assignment)
    return space.to_config(candidate), candidate
