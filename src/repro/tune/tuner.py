"""The search loop: strategy x evaluator x budget -> persisted best config.

``Tuner.run()``:

  1. keys the ``TuningDB`` on (graph fingerprint, space hash) and — unless
     forced — serves a previous result whose budget already covers the
     request, *without re-searching*;
  2. evaluates the baseline (the space's default assignment) first, so
     every search result is comparable against the stock configuration;
  3. drives the strategy ask/tell until the candidate budget is spent or
     the strategy exhausts itself, deduping re-proposals through a trial
     cache (the design cache below makes those free anyway);
  4. picks the best *valid* trial (numerics gate in the evaluator), and
     persists baseline + best + the full trial log to the DB.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.pipeline import graph_fingerprint
from repro.tune.db import TuningDB
from repro.tune.evaluator import Evaluator, Trial
from repro.tune.space import Candidate
from repro.tune.strategies import Strategy


@dataclasses.dataclass
class TuneResult:
    """What a tuning run (or a DB hit) returns."""

    best: Trial
    baseline: Trial
    trials: list[Trial]
    design_fingerprint: str
    space_hash: str
    strategy: str
    budget: int
    from_db: bool
    wall_s: float

    @property
    def speedup(self) -> float:
        """Baseline latency / best latency (>= 1.0 when the search won)."""
        return (self.baseline.latency_us / self.best.latency_us
                if self.best.latency_us else 1.0)

    def summary(self) -> str:
        src = "tuning DB" if self.from_db else \
            f"{len(self.trials)} trials in {self.wall_s:.1f}s"
        if not self.best.valid:
            note = " [NO candidate passed the numerics gate — baseline shown]"
        elif not getattr(self.best, "feasible", True):
            note = (" [NO candidate fit the trigger budget — baseline "
                    "shown, over on "
                    f"{', '.join(self.best.budget_failures) or '?'}]")
        else:
            note = ""
        return (f"best of {src}: {self.best.latency_us:.2f} us/sample "
                f"(baseline {self.baseline.latency_us:.2f} us, "
                f"{self.speedup:.2f}x)  {self.best.candidate.label()}{note}")

    def to_entry(self) -> dict:
        return {
            "strategy": self.strategy,
            "budget": self.budget,
            "n_trials": len(self.trials),
            "wall_s": round(self.wall_s, 3),
            "baseline": self.baseline.to_json(),
            "best": self.best.to_json(),
            "trials": [t.to_json() for t in self.trials],
        }

    @classmethod
    def from_entry(cls, entry: dict, *, design_fingerprint: str,
                   space_hash: str) -> "TuneResult":
        trials = [Trial.from_json(t) for t in entry.get("trials", [])]
        return cls(
            best=Trial.from_json(entry["best"]),
            baseline=Trial.from_json(entry["baseline"]),
            trials=trials, design_fingerprint=design_fingerprint,
            space_hash=space_hash, strategy=entry.get("strategy", "?"),
            budget=int(entry.get("budget", len(trials))), from_db=True,
            wall_s=float(entry.get("wall_s", 0.0)))


class Tuner:
    """Drives one search; see the module docstring for the contract."""

    def __init__(self, evaluator: Evaluator, strategy: Strategy, *,
                 db: Optional[TuningDB] = None, budget: int = 16,
                 on_trial: Optional[Callable[[Trial], None]] = None):
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.evaluator = evaluator
        self.strategy = strategy
        self.db = db
        self.budget = budget
        self.on_trial = on_trial

    # -- helpers ------------------------------------------------------------

    @property
    def space(self):
        return self.evaluator.space

    def _identity(self) -> tuple[str, str]:
        return (graph_fingerprint(self.evaluator.graph),
                self.space.space_hash())

    def context(self) -> dict:
        """What makes this run an experiment of its own: the strategy, its
        parameters, and the evaluation settings.  Part of the DB key — two
        runs with different contexts never overwrite or serve each other.
        """
        return {"strategy": self.strategy.name,
                "params": self.strategy.params(),
                "eval": self.evaluator.settings()}

    def _context_hash(self) -> str:
        from repro.tune.db import TuningDB
        return TuningDB.context_hash(self.context())

    def _serve_from_db(self) -> Optional[TuneResult]:
        if self.db is None:
            return None
        fp, sh = self._identity()
        entry = self.db.get(fp, sh, self._context_hash())
        if entry is None or int(entry.get("budget", 0)) < self.budget:
            return None
        return TuneResult.from_entry(entry, design_fingerprint=fp,
                                     space_hash=sh)

    # -- the loop -----------------------------------------------------------

    def run(self, *, force: bool = False) -> TuneResult:
        served = None if force else self._serve_from_db()
        if served is not None:
            return served

        t_start = time.perf_counter()
        trials: dict[Candidate, Trial] = {}

        def eval_once(c: Candidate) -> Trial:
            trial = trials.get(c)
            if trial is None:
                trial = self.evaluator.evaluate(c)
                trials[c] = trial
                if self.on_trial is not None:
                    self.on_trial(trial)
            return trial

        baseline_cand = self.space.default()
        baseline = eval_once(baseline_cand)
        self.strategy.reset(self.space, baseline_cand)
        self.strategy.observe(baseline_cand, baseline)

        # proposals are bounded: duplicates are served from the trial cache
        # and don't consume budget, but a strategy stuck re-proposing is
        # cut off rather than looping forever
        max_proposals = 50 * self.budget + 100
        proposals = 0
        while len(trials) < self.budget and proposals < max_proposals:
            proposals += 1
            cand = self.strategy.propose()
            if cand is None:
                break
            self.strategy.observe(cand, eval_once(cand))

        ranked = sorted((t for t in trials.values() if t.score() is not None),
                        key=Trial.score)
        best = ranked[0] if ranked else baseline
        result = TuneResult(
            best=best, baseline=baseline, trials=list(trials.values()),
            design_fingerprint=self._identity()[0],
            space_hash=self._identity()[1], strategy=self.strategy.name,
            budget=self.budget, from_db=False,
            wall_s=time.perf_counter() - t_start)

        if self.db is not None:
            fp, sh = self._identity()
            entry = result.to_entry()
            # single source of truth for the run's settings: the context
            # (strategy name/params + evaluator settings)
            entry["context"] = self.context()
            self.db.put(fp, sh, entry, self._context_hash())
        return result
