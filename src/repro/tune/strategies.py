"""Search strategies over a ``SearchSpace``.

The protocol is ask/tell: the ``Tuner`` calls ``reset(space, baseline)``
once, then alternates ``propose() -> Candidate | None`` (``None`` = the
strategy is exhausted) with ``observe(candidate, trial)``.  Proposals the
tuner has already evaluated are answered from its trial cache — strategies
may re-propose freely without burning budget.

Three strategies ship:

  * ``Bisection``   — the paper's §4.2 discipline: bisect the ordered
    unroll-factor domain for the smallest capacity that still meets the
    latency target, then descend the precision ladder while the design
    stays numerically valid.
  * ``HillClimb``   — coordinate descent with full line search per knob;
    this automates (and absorbs) the manual hypothesis -> change -> measure
    rounds that ``repro.launch.hillclimb`` ran as hand-written variant
    lists.
  * ``RandomSearch``— uniform without replacement; the honesty baseline.

``sweep_variants`` is the generic tagged-variant sweep loop the old
``launch.hillclimb`` driver re-implemented inline; it now lives here and
``launch.hillclimb`` imports it.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.tune.space import PRECISION_KNOB, Candidate, SearchSpace


class Strategy:
    """Base ask/tell strategy.  Subclasses override all three hooks."""

    name = "base"

    def reset(self, space: SearchSpace, baseline: Candidate) -> None:
        self.space = space
        self.baseline = baseline

    def propose(self) -> Optional[Candidate]:
        raise NotImplementedError

    def observe(self, candidate: Candidate, trial) -> None:  # noqa: B027
        pass

    def params(self) -> dict:
        """The strategy's own parameters — part of the TuningDB run
        context, so e.g. bisection runs toward different targets never
        serve each other's results."""
        return {}


class RandomSearch(Strategy):
    """Uniform sampling without replacement (after the baseline)."""

    name = "random"

    def __init__(self, seed: int = 0, max_draws: int = 200):
        self.seed = seed
        self.max_draws = max_draws

    def params(self):
        return {"seed": self.seed}

    def reset(self, space, baseline):
        super().reset(space, baseline)
        self.rng = np.random.default_rng(self.seed)
        self.seen = {baseline}
        self.draws = 0

    def propose(self):
        while self.draws < self.max_draws:
            self.draws += 1
            c = self.space.random_candidate(self.rng)
            if c not in self.seen:
                self.seen.add(c)
                return c
        return None


class HillClimb(Strategy):
    """Coordinate descent: line-search one knob at a time from the best
    point so far; stop after a full sweep of all knobs without improvement.
    """

    name = "hillclimb"

    def __init__(self, max_sweeps: int = 4):
        self.max_sweeps = max_sweeps

    def params(self):
        return {"max_sweeps": self.max_sweeps}

    def reset(self, space, baseline):
        super().reset(space, baseline)
        self.best = baseline
        self.best_score = None
        self.pending: list[Candidate] = []
        self.knob_idx = -1
        self.improved = False
        self.sweeps = 0
        self.done = not space.knobs

    def _refill(self) -> bool:
        """Queue the line search for the next knob; False when finished."""
        while not self.pending:
            self.knob_idx += 1
            if self.knob_idx >= len(self.space.knobs):
                self.sweeps += 1
                if not self.improved or self.sweeps >= self.max_sweeps:
                    return False
                self.knob_idx = 0
                self.improved = False
            knob = self.space.knobs[self.knob_idx]
            cur = self.best.get(knob.name)
            self.pending = [self.best.replace(knob.name, v)
                            for v in knob.values if v != cur]
        return True

    def propose(self):
        if self.done:
            return None
        if not self._refill():
            self.done = True
            return None
        return self.pending.pop(0)

    def observe(self, candidate, trial):
        score = trial.score()
        if score is None:
            return
        if self.best_score is None and candidate == self.best:
            self.best_score = score
            return
        if self.best_score is None or score < self.best_score:
            self.best, self.best_score = candidate, score
            self.improved = True


class Bisection(Strategy):
    """OpenHLS-style bisection-to-latency-target (paper §4.2).

    Phase 1 bisects ``knob`` (default ``unroll_factor``; the domain is
    sorted by capacity, ``None`` = the design's own K = largest) for the
    *smallest* capacity whose schedule still meets ``target_us``.  When no
    target is given, the baseline's own latency is the target — i.e. find
    the cheapest design that is no slower than the default.  Phase 2 then
    walks the precision ladder in domain order, keeping each narrower
    format while the design stays numerically valid and on target.
    """

    name = "bisect"

    def __init__(self, target_us: Optional[float] = None,
                 knob: str = "unroll_factor"):
        self.target_us = target_us
        self.knob_name = knob

    def params(self):
        return {"target_us": self.target_us, "knob": self.knob_name}

    def reset(self, space, baseline):
        super().reset(space, baseline)
        knob = space.knob(self.knob_name)
        if knob is None:
            raise ValueError(
                f"Bisection needs a {self.knob_name!r} knob; space "
                f"{space.name!r} has {[k.name for k in space.knobs]}")
        # ascending capacity; None (full K) is the largest
        self.domain = sorted(
            knob.values, key=lambda v: float("inf") if v is None else v)
        self.lo, self.hi = 0, len(self.domain) - 1
        self.target = self.target_us
        self.feasible: Optional[Candidate] = None
        self.phase = "baseline" if self.target is None else "bisect"
        self.prec_values = ()
        prec = space.knob(PRECISION_KNOB)
        if prec is not None:
            base_val = baseline.get(PRECISION_KNOB)
            vals = list(prec.values)
            if base_val in vals:            # descend from the baseline on
                vals = vals[vals.index(base_val) + 1:]
            self.prec_values = tuple(vals)
        self.prec_idx = 0
        self.pending: Optional[Candidate] = None

    def _at(self, i: int) -> Candidate:
        return self.baseline.replace(self.knob_name, self.domain[i])

    def propose(self):
        if self.pending is not None:
            return self.pending            # waiting on an observe
        if self.phase == "baseline":
            self.pending = self.baseline
        elif self.phase == "bisect":
            if self.lo > self.hi:
                self.phase = "precision"
                return self.propose()
            self.mid = (self.lo + self.hi) // 2
            self.pending = self._at(self.mid)
        elif self.phase == "precision":
            if self.feasible is None or self.prec_idx >= len(self.prec_values):
                self.phase = "done"
                return None
            self.pending = self.feasible.replace(
                PRECISION_KNOB, self.prec_values[self.prec_idx])
        else:
            return None
        return self.pending

    def observe(self, candidate, trial):
        if candidate != self.pending:
            return
        self.pending = None
        if self.phase == "baseline":
            self.target = trial.latency_us
            self.feasible = candidate if trial.score() is not None else None
            self.phase = "bisect"
            return
        meets = trial.score() is not None and trial.latency_us <= self.target
        if self.phase == "bisect":
            if meets:
                self.feasible = candidate
                self.hi = self.mid - 1     # try a smaller capacity
            else:
                self.lo = self.mid + 1
        elif self.phase == "precision":
            if meets:
                self.feasible = candidate  # keep the narrower format
                self.prec_idx += 1
            else:
                self.phase = "done"        # ladder ends at first failure


STRATEGIES: dict[str, Callable[..., Strategy]] = {
    RandomSearch.name: RandomSearch,
    HillClimb.name: HillClimb,
    Bisection.name: Bisection,
}


def make_strategy(name: str, **kw) -> Strategy:
    if name not in STRATEGIES:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"known: {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kw)


# ---------------------------------------------------------------------------
# The generic tagged-variant sweep (absorbed from launch.hillclimb)
# ---------------------------------------------------------------------------


def sweep_variants(variants: Sequence[tuple[str, object]],
                   evaluate: Callable[[str, object], object],
                   *, skip: Optional[Callable[[str, object], bool]] = None,
                   on_result: Optional[Callable[[str, object], None]] = None,
                   ) -> dict[str, object]:
    """Run ``evaluate(tag, payload)`` over ordered tagged variants.

    ``skip(tag, payload)`` short-circuits variants whose artifact already
    exists (the resumable-sweep discipline of ``launch.hillclimb``);
    skipped variants are not re-evaluated and do not appear in the result.
    """
    results: dict[str, object] = {}
    for tag, payload in variants:
        if skip is not None and skip(tag, payload):
            continue
        out = evaluate(tag, payload)
        results[tag] = out
        if on_result is not None:
            on_result(tag, out)
    return results
