"""The persistent tuning database.

One JSON file holding the best-known configuration (and the trial log that
produced it) per **(design content hash, search-space hash, run context)**
— the same content-addressing discipline as the design cache, so a
retrained model, an edited space, a cache-format bump, or a different
experiment (strategy, strategy parameters, evaluation settings — the
*context*) each get a fresh entry instead of overwriting another's.  The
default location is the shared versioned cache root
(``core.cachedir.cache_root("tune")``), next to the design cache and
subject to the same stale-version eviction.

Serving and benchmarks auto-load wins via :func:`lookup_best` /
``repro.tune.best_config_for`` — a tuned run is a file read, not a search.
The lookup scans every context recorded for the design, skips entries
whose best failed the numerics gate, and prefers wall-clocked (measure
mode) results over dry ones.
"""

from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path
from typing import Optional, Union

from repro.core.cachedir import CACHE_FORMAT_VERSION, cache_root

DB_FILENAME = "tuning_db.json"


class TuningDB:
    """Tiny persistent key-value store of tuning results.

    Entries are plain JSON (assignments, metrics, trial summaries) — never
    pickles — so the file is diffable and safe to share.  Writes are
    atomic (tmp + rename) and re-read the file first, so concurrent tuners
    lose at most their own entry, never the whole DB.
    """

    def __init__(self, path: Optional[Union[str, Path]] = None):
        self.path = (Path(path) if path is not None
                     else cache_root("tune") / DB_FILENAME)

    # -- storage ------------------------------------------------------------

    @staticmethod
    def key(design_fingerprint: str, space_hash: str,
            context: str = "") -> str:
        return f"{design_fingerprint}|{space_hash}|{context}"

    @staticmethod
    def context_hash(context: dict) -> str:
        """Stable digest of a run context (strategy, params, eval settings).

        Runs with different contexts are different experiments: they must
        not overwrite each other's entries or serve each other's reruns.
        """
        canon = json.dumps(context, sort_keys=True, default=str)
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    def _read(self) -> dict:
        if not self.path.exists():
            return {"version": CACHE_FORMAT_VERSION, "entries": {}}
        try:
            data = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return {"version": CACHE_FORMAT_VERSION, "entries": {}}
        if data.get("version") != CACHE_FORMAT_VERSION:
            # stale schema: discard rather than misread
            return {"version": CACHE_FORMAT_VERSION, "entries": {}}
        return data

    def _write(self, data: dict) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(data, indent=1, sort_keys=True))
        tmp.replace(self.path)

    # -- API ----------------------------------------------------------------

    def get(self, design_fingerprint: str, space_hash: str,
            context: str = "") -> Optional[dict]:
        return self._read()["entries"].get(
            self.key(design_fingerprint, space_hash, context))

    def put(self, design_fingerprint: str, space_hash: str,
            entry: dict, context: str = "") -> None:
        data = self._read()
        entry = dict(entry)
        entry.setdefault("created_at", time.strftime("%Y-%m-%dT%H:%M:%S"))
        entry["updated_at"] = time.strftime("%Y-%m-%dT%H:%M:%S")
        data["entries"][self.key(design_fingerprint, space_hash,
                                 context)] = entry
        self._write(data)

    def entries_for(self, design_fingerprint: str,
                    space_hash: str) -> dict[str, dict]:
        """All run-context entries for one (design, space) pair."""
        prefix = self.key(design_fingerprint, space_hash, "")
        return {k[len(prefix):]: v for k, v in self._read()["entries"].items()
                if k.startswith(prefix)}

    def entries(self) -> dict[str, dict]:
        return self._read()["entries"]

    def clear(self) -> None:
        if self.path.exists():
            self.path.unlink()

    def __len__(self) -> int:
        return len(self.entries())


def best_entry(db: TuningDB, design_fingerprint: str,
               space_hash: str) -> Optional[dict]:
    """The winning entry across every recorded run context, or ``None``.

    Entries whose best failed the numerics gate — or the trigger-budget
    feasibility gate — never win (the tuner logs them, but an invalid or
    over-budget config must not reach serving).  Wall-clocked
    (measure-mode) results beat dry ones; ties break on latency.
    """
    candidates = []
    for ctx, entry in db.entries_for(design_fingerprint, space_hash).items():
        best = entry.get("best") or {}
        if not best.get("valid") or "candidate" not in best:
            continue
        if best.get("feasible", True) is False:
            continue
        ev = (entry.get("context") or {}).get("eval") or {}
        candidates.append(((0 if ev.get("mode") == "measure" else 1,
                            float(best.get("latency_us", float("inf")))),
                           entry))
    if not candidates:
        return None
    return min(candidates, key=lambda t: t[0])[1]


def lookup_best(db: TuningDB, design_fingerprint: str,
                space_hash: str) -> Optional[dict]:
    """The stored best-candidate assignment (JSON form), or ``None``."""
    entry = best_entry(db, design_fingerprint, space_hash)
    if entry is None:
        return None
    return entry["best"]["candidate"]
