"""Public wrapper: GQA-aware flash attention over (B, S, H, D) tensors."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              logit_cap: float = 0.0, use_pallas: bool = False,
              interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D), k/v: (B, S, K, D) with H % K == 0."""
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, d)
    fn = flash_attention if use_pallas else flash_attention_ref
    kw = {"interpret": interpret} if use_pallas else {}
    of = fn(qf, kf, vf, causal=causal, window=window, logit_cap=logit_cap,
            **kw)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)
