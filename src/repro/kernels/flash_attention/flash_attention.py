"""Pallas TPU kernel: flash attention (causal / local-window / softcap).

Grid: (batch*kv_heads*groups, Sq/bq, Skv/bk) with the KV dimension
innermost.  Running (max, denom, accumulator) live in VMEM scratch across
KV steps; the output block is written once on the final KV step.  This is
the per-chip twin of the pure-JAX ``repro.nn.attention.blockwise_attention``
(which remains the XLA fallback the dry-run lowers): same math, same
masking contract, validated against the same oracle.

The fully static schedule — every (q-block, kv-block) pair visited at a
fixed grid step, no dynamic control flow — is the paper's "fully scheduled
design" discipline at kernel granularity.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, logit_cap, bq, bk, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (bq, d)
    k = k_ref[0].astype(jnp.float32)           # (bk, d)
    v = v_ref[0].astype(jnp.float32)           # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None and window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(s == NEG_INF, 0.0, p)
    corr = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - m_safe))
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_cap", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    logit_cap: float = 0.0, bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) — heads pre-flattened into BH.

    GQA is expressed by repeating kv head indices in the caller (ops.py).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    n_k = skv // bk
    grid = (bh, sq // bq, n_k)
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, logit_cap=logit_cap, bq=bq, bk=bk,
                          n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
