"""Pure-jnp oracle for the flash attention kernel (flattened-heads layout)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        logit_cap: float = 0.0) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(
                       jnp.asarray(d, jnp.float32))
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    sq, skv = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kp <= qp
    if window is not None and window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
