"""Public wrapper for the weights-in-VMEM conv kernel."""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.conv2d_vmem.conv2d_vmem import conv2d_vmem
from repro.kernels.conv2d_vmem.ref import conv2d_ref


def conv2d(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
           fmt: Optional[tuple[int, int]] = None, fuse_relu: bool = False,
           use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    if use_pallas:
        return conv2d_vmem(x, w, b, fmt=fmt, fuse_relu=fuse_relu,
                           interpret=interpret)
    return conv2d_ref(x, w, b, fmt=fmt, fuse_relu=fuse_relu)
