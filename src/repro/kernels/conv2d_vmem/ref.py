"""Pure-jnp oracle for conv2d_vmem (valid padding, stride 1, NCHW)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import FloatFormat, quantize


def conv2d_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
               fmt: Optional[tuple[int, int]] = None,
               fuse_relu: bool = False) -> jax.Array:
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    if fmt is not None:
        ff = FloatFormat(*fmt)
        x = quantize(x, ff)
        w = quantize(w, ff)
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    if b is not None:
        out = out + b.astype(jnp.float32)[None, :, None, None]
    if fuse_relu:
        out = jnp.maximum(out, 0.0)
    return out
